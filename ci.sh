#!/usr/bin/env bash
# CI gate: build, tests, formatting, lints, docs. Run from anywhere.
#
#   ./ci.sh          # full gate (what the repo considers green)
#   ./ci.sh --fast   # build + tests only (skip fmt/clippy/doc)
#
# Each stage prints its wall-clock time; .github/workflows/ci.yml runs
# both modes on every push/PR.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: 'cargo' not found on PATH." >&2
    echo "Install a Rust toolchain (see rust-version in Cargo.toml, e.g." >&2
    echo "via https://rustup.rs) and re-run ./ci.sh." >&2
    exit 1
fi

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

# The full gate shells out to python3 (trace validation, bench
# regression gate); fail up front rather than 10 minutes in.
if [[ "$fast" == 0 ]] && ! command -v python3 >/dev/null 2>&1; then
    echo "error: 'python3' not found on PATH (needed by the full gate's" >&2
    echo "trace-validation and bench-regression stages). Install python3" >&2
    echo "or run ./ci.sh --fast." >&2
    exit 1
fi

stage() {
    echo "==> $*"
    local t0=$SECONDS
    "$@"
    echo "    [$* took $((SECONDS - t0))s]"
}

stage cargo build --release
stage cargo test -q
stage cargo bench --no-run

if [[ "$fast" == 0 ]]; then
    # Calibration property tests (seeded round-trips over uniform /
    # nvlink-islands / two-tier ground truths) — already part of
    # `cargo test`, re-run by name so a calibration regression fails
    # with a dedicated stage in the log.
    stage cargo test -q --test prop_invariants calibration
    # Flow-simulator suite (event-driven bandwidth-sharing comm model):
    # fair-sharing unit tests, the sequential/flow compatibility
    # property tests, and the parallel-comm contention acceptance test
    # all carry "flow" in their names. Already part of `cargo test`;
    # re-run by name so a comm-model regression gets its own stage.
    stage cargo test -q flow
    # Placement-as-a-service suite: the concurrency stress tests
    # (responses bit-identical to sequential engine.place) and the
    # incremental-placement property tests (memory capacity + makespan
    # tolerance). Named stages so a serving regression is attributable.
    stage cargo test -q serve
    stage cargo test -q incremental
    # Serving bench smoke run: a shrunken Fig. 12 sweep whose in-bench
    # assertions gate hit rate and incremental-vs-full latency, emitting
    # bench-json/BENCH_serving.json for the CI artifact upload.
    stage env BAECHI_BENCH_JSON=bench-json cargo bench --bench fig12_serving -- --smoke
    # Telemetry suite: span collection through engine + service, Chrome
    # trace-event export, Prometheus exposition, and the trace-off
    # bit-identity / schedule-reconstruction property tests.
    stage cargo test -q --test telemetry
    stage cargo test -q --test prop_invariants trace
    # Trace-export smoke run: `baechi trace` must emit a file that
    # validates as trace-event JSON with every stage span nested inside
    # its request span (uploaded as the trace-smoke CI artifact).
    stage ./target/release/baechi trace --model linreg --placer m-etf --out trace-smoke.json
    stage python3 tools/test_validate_trace.py
    stage python3 tools/validate_trace.py trace-smoke.json
    # Explainability suite: decision records, critical-path attribution
    # (sums to the makespan within 1e-9), explain-off bit-identity for
    # every registered placer, run-history JSONL round-trip.
    stage cargo test -q --test explain
    # Explain smoke run: `baechi explain --json` must emit an artifact
    # whose attribution sums to the simulated makespan and whose
    # decision records are well-formed (uploaded as the explain-smoke
    # CI artifact). The validator's own tests gate the validator first.
    stage python3 tools/test_validate_explain.py
    stage sh -c './target/release/baechi explain --model inception --placer m-sct --json > explain-smoke.json'
    stage python3 tools/validate_explain.py --require-decisions explain-smoke.json
    # Hierarchical placement suite: coarsen/refine unit tests plus the
    # hier property tests (contraction acyclicity, super-op aggregation,
    # expand/coarsen identity, zero-coarsening ≡ m-SCT, memory safety).
    stage cargo test -q hier
    # Scaling bench smoke run: 100K-op synthetic graph through flat
    # m-SCT and the hier placer; the in-bench assertion requires hier to
    # be strictly faster, and the run emits
    # bench-json/BENCH_table3_placement_time.json for the gate below.
    stage env BAECHI_BENCH_JSON=bench-json cargo bench --bench table3_placement_time -- --smoke
    # Bench regression gate: compare the fresh bench JSON written above
    # against committed baselines (bench-baselines/), with tolerances
    # from bench-baselines/tolerances.json. Gate the gate's own tests
    # first so a checker bug can't masquerade as a green bench run.
    stage python3 tools/test_check_bench.py
    stage python3 tools/check_bench.py --fresh bench-json --baselines bench-baselines
    stage cargo fmt --check
    stage cargo clippy --all-targets -- -D warnings
    stage cargo doc --no-deps
else
    echo "fast mode: skipped stages: named test suites (calibration, flow, serve, incremental, telemetry, trace, explain, hier), bench smoke runs (fig12_serving, table3_placement_time), bench regression gate (check_bench), trace smoke + validation, explain smoke + validation, fmt, clippy, doc"
fi

echo "CI green."
