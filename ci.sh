#!/usr/bin/env bash
# CI gate: build, tests, formatting, lints, docs. Run from anywhere.
#
#   ./ci.sh          # full gate (what the repo considers green)
#   ./ci.sh --fast   # build + tests only (skip fmt/clippy/doc)
#
# Each stage prints its wall-clock time; .github/workflows/ci.yml runs
# both modes on every push/PR.
set -euo pipefail
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: 'cargo' not found on PATH." >&2
    echo "Install a Rust toolchain (see rust-version in Cargo.toml, e.g." >&2
    echo "via https://rustup.rs) and re-run ./ci.sh." >&2
    exit 1
fi

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

stage() {
    echo "==> $*"
    local t0=$SECONDS
    "$@"
    echo "    [$* took $((SECONDS - t0))s]"
}

stage cargo build --release
stage cargo test -q
stage cargo bench --no-run

if [[ "$fast" == 0 ]]; then
    # Calibration property tests (seeded round-trips over uniform /
    # nvlink-islands / two-tier ground truths) — already part of
    # `cargo test`, re-run by name so a calibration regression fails
    # with a dedicated stage in the log.
    stage cargo test -q --test prop_invariants calibration
    # Flow-simulator suite (event-driven bandwidth-sharing comm model):
    # fair-sharing unit tests, the sequential/flow compatibility
    # property tests, and the parallel-comm contention acceptance test
    # all carry "flow" in their names. Already part of `cargo test`;
    # re-run by name so a comm-model regression gets its own stage.
    stage cargo test -q flow
    # Placement-as-a-service suite: the concurrency stress tests
    # (responses bit-identical to sequential engine.place) and the
    # incremental-placement property tests (memory capacity + makespan
    # tolerance). Named stages so a serving regression is attributable.
    stage cargo test -q serve
    stage cargo test -q incremental
    # Serving bench smoke run: a shrunken Fig. 12 sweep whose in-bench
    # assertions gate hit rate and incremental-vs-full latency, emitting
    # bench-json/BENCH_serving.json for the CI artifact upload.
    stage env BAECHI_BENCH_JSON=bench-json cargo bench --bench fig12_serving -- --smoke
    # Telemetry suite: span collection through engine + service, Chrome
    # trace-event export, Prometheus exposition, and the trace-off
    # bit-identity / schedule-reconstruction property tests.
    stage cargo test -q --test telemetry
    stage cargo test -q --test prop_invariants trace
    # Trace-export smoke run: `baechi trace` must emit a file that
    # validates as trace-event JSON with every stage span nested inside
    # its request span (uploaded as the trace-smoke CI artifact).
    stage ./target/release/baechi trace --model linreg --placer m-etf --out trace-smoke.json
    stage python3 tools/validate_trace.py trace-smoke.json
    stage cargo fmt --check
    stage cargo clippy --all-targets -- -D warnings
    stage cargo doc --no-deps
fi

echo "CI green."
