#!/usr/bin/env bash
# CI gate: build, tests, formatting, lints. Run from anywhere.
#
#   ./ci.sh          # full gate (what the repo considers green)
#   ./ci.sh --fast   # build + tests only (skip fmt/clippy)
set -euo pipefail
cd "$(dirname "$0")"

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo bench --no-run"
cargo bench --no-run

if [[ "$fast" == 0 ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "CI green."
