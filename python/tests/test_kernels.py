"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle,
swept over shapes/configs with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.elementwise import bias_act
from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.matmul import matmul, vmem_bytes
from compile.kernels import ref

DIMS = [1, 2, 3, 4, 8, 16, 24, 32, 64, 96, 128, 160, 256]


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from(DIMS),
        k=st.sampled_from(DIMS),
        n=st.sampled_from(DIMS),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, k, n, seed):
        x = rand(seed, m, k)
        y = rand(seed + 1, k, n)
        got = matmul(x, y)
        want = ref.matmul_ref(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        bm=st.sampled_from([16, 32, 128]),
        bn=st.sampled_from([16, 64, 128]),
        bk=st.sampled_from([16, 32, 128]),
    )
    def test_tile_shapes_equivalent(self, bm, bn, bk):
        x = rand(7, 64, 96)
        y = rand(8, 96, 32)
        got = matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_vmem_estimate_under_budget(self):
        # Default tiles must fit a 16 MiB VMEM with generous headroom.
        assert vmem_bytes(4096, 4096, 4096) < 4 << 20

    def test_rejects_mismatched_inner(self):
        with pytest.raises(AssertionError):
            matmul(rand(0, 4, 5), rand(1, 6, 4))


class TestBiasAct:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from(DIMS),
        n=st.sampled_from(DIMS),
        act=st.sampled_from(["relu", "gelu", "none"]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, m, n, act, seed):
        x = rand(seed, m, n)
        b = rand(seed + 1, n)
        got = bias_act(x, b, act=act)
        want = ref.bias_act_ref(x, b, act)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_clamps(self):
        x = jnp.array([[-1.0, 2.0]], jnp.float32)
        b = jnp.zeros((2,), jnp.float32)
        out = np.asarray(bias_act(x, b, act="relu"))
        assert out[0, 0] == 0.0 and out[0, 1] == 2.0


class TestLstmCell:
    @settings(max_examples=15, deadline=None)
    @given(
        bsz=st.sampled_from([1, 4, 16, 64]),
        inp=st.sampled_from([8, 32, 128]),
        hid=st.sampled_from([8, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, bsz, inp, hid, seed):
        x = rand(seed, bsz, inp)
        h = rand(seed + 1, bsz, hid)
        c = rand(seed + 2, bsz, hid)
        wx = rand(seed + 3, inp, 4 * hid) * 0.1
        wh = rand(seed + 4, hid, 4 * hid) * 0.1
        b = rand(seed + 5, 4 * hid) * 0.1
        h2, c2 = lstm_cell(x, h, c, wx, wh, b)
        hr, cr = ref.lstm_cell_ref(x, h, c, wx, wh, b)
        np.testing.assert_allclose(h2, hr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c2, cr, rtol=1e-4, atol=1e-5)

    def test_state_bounded(self):
        # h' = o·tanh(c') ∈ (-1, 1)
        h2, _ = lstm_cell(
            rand(0, 8, 16), rand(1, 8, 32), rand(2, 8, 32),
            rand(3, 16, 128), rand(4, 32, 128), rand(5, 128),
        )
        assert np.all(np.abs(np.asarray(h2)) < 1.0)


class TestAttention:
    @settings(max_examples=15, deadline=None)
    @given(
        l=st.sampled_from([4, 16, 50, 64, 128]),
        d=st.sampled_from([8, 16, 64]),
        bq=st.sampled_from([8, 16, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, l, d, bq, seed):
        q = rand(seed, l, d)
        k = rand(seed + 1, l, d)
        v = rand(seed + 2, l, d)
        got = attention(q, k, v, block_q=bq)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_rows_are_convex_combinations(self):
        # attention output rows lie within [min(v), max(v)] per column
        v = rand(3, 16, 8)
        out = np.asarray(attention(rand(1, 16, 8), rand(2, 16, 8), v))
        v = np.asarray(v)
        assert np.all(out <= v.max(axis=0) + 1e-5)
        assert np.all(out >= v.min(axis=0) - 1e-5)
