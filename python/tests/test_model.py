"""Layer-2 correctness: per-layer backward against jax.vjp, loss gradient
against jax.grad, and the fused train step actually learns."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def test_layer_bwd_matches_vjp():
    for li, (din, dout, relu) in enumerate(model.LAYER_DIMS):
        key = jax.random.PRNGKey(li)
        kx, kw, kb, kd = jax.random.split(key, 4)
        x = jax.random.normal(kx, (model.BATCH, din), jnp.float32)
        w = jax.random.normal(kw, (din, dout), jnp.float32) * 0.3
        b = jax.random.normal(kb, (dout,), jnp.float32) * 0.1
        dy = jax.random.normal(kd, (model.BATCH, dout), jnp.float32)

        # jax.vjp cannot trace through the interpret-mode Pallas
        # accumulation kernel, so take the VJP of the jnp reference
        # forward — the kernel tests already pin pallas == ref.
        def ref_fwd(x, w, b):
            z = x @ w + b
            return jnp.maximum(z, 0.0) if relu else z

        y_ref, vjp = jax.vjp(ref_fwd, x, w, b)
        dx_ref, dw_ref, db_ref = vjp(dy)
        (y,) = model.layer_fwd(x, w, b, relu=relu)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
        dx, dw, db = model.layer_bwd(x, w, y, dy, relu=relu)
        np.testing.assert_allclose(dx, dx_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(dw, dw_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(db, db_ref, rtol=1e-4, atol=1e-4)


def test_loss_bwd_matches_grad():
    key = jax.random.PRNGKey(9)
    logits = jax.random.normal(key, (model.BATCH, model.CLASSES), jnp.float32)
    labels = jax.random.randint(key, (model.BATCH,), 0, model.CLASSES)
    onehot = jax.nn.one_hot(labels, model.CLASSES, dtype=jnp.float32)

    loss_of = lambda lg: model.loss_fwd(lg, onehot)[0]
    dl_ref = jax.grad(loss_of)(logits)
    _, probs = model.loss_fwd(logits, onehot)
    (dl,) = model.loss_bwd(probs, onehot)
    np.testing.assert_allclose(dl, dl_ref, rtol=1e-4, atol=1e-5)


def test_train_step_decreases_loss():
    params = model.init_params(seed=0)
    x, onehot = model.synthetic_batch(0)
    lr = jnp.float32(0.1)
    losses = []
    for step in range(30):
        x, onehot = model.synthetic_batch(step % 4)  # small cycling set
        out = model.train_step(params, x, onehot, lr)
        losses.append(float(out[0]))
        params = list(out[1:])
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]} -> {losses[-1]}"


def test_train_step_matches_manual_composition():
    """The fused step equals composing the per-layer artifacts — the same
    equivalence the Rust distributed executor relies on."""
    params = model.init_params(seed=3)
    x, onehot = model.synthetic_batch(17)
    lr = jnp.float32(0.05)
    fused = model.train_step(params, x, onehot, lr)

    # manual composition
    acts = [x]
    for li, (_, _, relu) in enumerate(model.LAYER_DIMS):
        (y,) = model.layer_fwd(acts[-1], params[2 * li], params[2 * li + 1], relu=relu)
        acts.append(y)
    loss, probs = model.loss_fwd(acts[-1], onehot)
    (dy,) = model.loss_bwd(probs, onehot)
    new_params = list(params)
    for li in reversed(range(model.num_layers())):
        _, _, relu = model.LAYER_DIMS[li]
        dx, dw, db = model.layer_bwd(
            acts[li], params[2 * li], acts[li + 1], dy, relu=relu
        )
        new_params[2 * li] = params[2 * li] - lr * dw
        new_params[2 * li + 1] = params[2 * li + 1] - lr * db
        dy = dx

    np.testing.assert_allclose(float(fused[0]), float(loss), rtol=1e-6)
    for a, b in zip(fused[1:], new_params):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_synthetic_batch_deterministic():
    x1, o1 = model.synthetic_batch(5)
    x2, o2 = model.synthetic_batch(5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (model.BATCH, model.CLASSES)
    np.testing.assert_allclose(np.asarray(o1).sum(axis=1), 1.0)
