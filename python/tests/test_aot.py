"""AOT emission smoke tests: HLO text parses, manifest is consistent."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # run in-process for speed
    old_argv = sys.argv
    sys.argv = ["aot", "--out", str(out)]
    try:
        aot.main()
    finally:
        sys.argv = old_argv
    return out


def test_manifest_complete(artifacts):
    meta = json.loads((artifacts / "manifest.json").read_text())
    names = {a["name"] for a in meta["artifacts"]}
    for li in range(model.num_layers()):
        assert f"layer{li}_fwd" in names
        assert f"layer{li}_bwd" in names
    for required in ["loss_fwd", "loss_bwd", "train_step", "predict",
                     "kernel_matmul", "kernel_lstm_cell", "kernel_attention"]:
        assert required in names
    assert meta["batch"] == model.BATCH


def test_hlo_text_is_parseable_hlo(artifacts):
    meta = json.loads((artifacts / "manifest.json").read_text())
    for a in meta["artifacts"]:
        text = (artifacts / a["file"]).read_text()
        assert "HloModule" in text, a["name"]
        assert "ENTRY" in text, a["name"]


def test_shapes_recorded(artifacts):
    meta = json.loads((artifacts / "manifest.json").read_text())
    by_name = {a["name"]: a for a in meta["artifacts"]}
    l0 = by_name["layer0_fwd"]
    din, dout, _ = model.LAYER_DIMS[0]
    assert l0["input_shapes"] == [[model.BATCH, din], [din, dout], [dout]]
    assert by_name["layer0_bwd"]["num_outputs"] == 3
    assert by_name["train_step"]["num_outputs"] == 1 + 2 * model.num_layers()


def test_pallas_lowered_to_plain_hlo(artifacts):
    # interpret=True must not leave custom-calls the CPU client can't run
    text = (artifacts / "kernel_matmul.hlo.txt").read_text()
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
