"""AOT lowering: JAX/Pallas → HLO **text** → artifacts/ + manifest.json.

Run once via ``make artifacts``; the Rust runtime loads the HLO text with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.
HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.attention import attention
from .kernels.lstm_cell import lstm_cell
from .kernels.matmul import matmul


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def exports():
    """(name, fn, arg_specs, num_outputs) for every artifact."""
    b = model.BATCH
    out = []
    # Per-layer forward/backward modules.
    for li, (din, dout, relu) in enumerate(model.LAYER_DIMS):
        fwd = functools.partial(model.layer_fwd, relu=relu)
        bwd = functools.partial(model.layer_bwd, relu=relu)
        out.append((f"layer{li}_fwd", fwd, [f32(b, din), f32(din, dout), f32(dout)], 1))
        out.append(
            (
                f"layer{li}_bwd",
                bwd,
                [f32(b, din), f32(din, dout), f32(b, dout), f32(b, dout)],
                3,
            )
        )
    # Loss forward/backward.
    c = model.CLASSES
    out.append(("loss_fwd", model.loss_fwd, [f32(b, c), f32(b, c)], 2))
    out.append(("loss_bwd", model.loss_bwd, [f32(b, c), f32(b, c)], 1))
    # Fused oracle train step + prediction.
    nparams = 2 * model.num_layers()
    param_specs = []
    for din, dout, _ in model.LAYER_DIMS:
        param_specs += [f32(din, dout), f32(dout)]

    def train_step_flat(*args):
        params = list(args[:nparams])
        x, onehot, lr = args[nparams], args[nparams + 1], args[nparams + 2]
        return model.train_step(params, x, onehot, lr)

    out.append(
        (
            "train_step",
            train_step_flat,
            param_specs + [f32(b, model.LAYER_DIMS[0][0]), f32(b, c), f32()],
            1 + nparams,
        )
    )

    def predict_flat(*args):
        params = list(args[:nparams])
        return model.predict(params, args[nparams])

    out.append(
        ("predict", predict_flat, param_specs + [f32(b, model.LAYER_DIMS[0][0])], 1)
    )
    # Standalone kernel demos (profiling + integration tests).
    out.append(
        ("kernel_matmul", lambda x, y: (matmul(x, y),), [f32(128, 128), f32(128, 128)], 1)
    )
    out.append(
        (
            "kernel_lstm_cell",
            lambda x, h, cc, wx, wh, bb: lstm_cell(x, h, cc, wx, wh, bb),
            [f32(64, 128), f32(64, 128), f32(64, 128), f32(128, 512), f32(128, 512), f32(512)],
            2,
        )
    )
    out.append(
        (
            "kernel_attention",
            lambda q, k, v: (attention(q, k, v),),
            [f32(64, 64), f32(64, 64), f32(64, 64)],
            1,
        )
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, fn, specs, num_outputs in exports():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(
            {
                "name": name,
                "file": fname,
                "input_shapes": [list(s.shape) for s in specs],
                "num_outputs": num_outputs,
            }
        )
        print(f"  lowered {name}: {len(text)} chars, inputs={len(specs)}")

    meta = {
        "batch": model.BATCH,
        "classes": model.CLASSES,
        "layer_dims": [list(d) for d in model.LAYER_DIMS],
        "artifacts": manifest,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
