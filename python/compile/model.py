"""Layer 2: the end-to-end MLP's forward/backward compute graph in JAX,
built on the Layer-1 Pallas kernels.

Layer shapes must match ``rust/src/models/mlp.rs`` (`MlpConfig::default`):
batch 64, dims 64→128→128→64→10. Each layer's forward and backward are
exported as separate AOT artifacts so the Rust multi-device executor can
*place* them independently (forward/backward co-placement, paper §3.1.3);
``train_step`` is the fused single-module oracle the distributed execution
is validated against.
"""

import jax
import jax.numpy as jnp

from .kernels.elementwise import bias_act
from .kernels.matmul import matmul

BATCH = 64
# (din, dout, relu?)
LAYER_DIMS = [(64, 128, True), (128, 128, True), (128, 64, True), (64, 10, False)]
CLASSES = 10


def num_layers():
    return len(LAYER_DIMS)


def init_params(seed=0):
    """He-initialized parameters, a flat list [w0, b0, w1, b1, ...]."""
    key = jax.random.PRNGKey(seed)
    params = []
    for din, dout, _ in LAYER_DIMS:
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
        params += [w, jnp.zeros((dout,), jnp.float32)]
    return params


# --------------------------------------------------------------------------
# Per-layer forward/backward (the placeable modules).
# --------------------------------------------------------------------------


def layer_fwd(x, w, b, *, relu):
    """y = act(x @ w + b). Residuals for backward: (x, w, y)."""
    z = matmul(x, w)
    y = bias_act(z, b, act="relu" if relu else "none")
    return (y,)


def layer_bwd(x, w, y, dy, *, relu):
    """Gradients given the forward residuals.

    Returns (dx, dw, db). Uses `y > 0` for the ReLU mask (valid because
    y = relu(z) ⇒ y > 0 ⇔ z > 0).
    """
    if relu:
        dz = dy * (y > 0).astype(jnp.float32)
    else:
        # keep `y` in the lowered signature: the stablehlo→XLA conversion
        # prunes unused parameters, which would desync the artifact arity
        dz = dy + 0.0 * y
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    dx = matmul(dz, w.T)
    return (dx, dw, db)


def loss_fwd(logits, onehot):
    """Softmax cross-entropy. Returns (loss, probs) — probs is the
    backward residual."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    probs = jax.nn.softmax(logits, axis=-1)
    return (loss, probs)


def loss_bwd(probs, onehot):
    """dlogits of the mean cross-entropy."""
    bsz = probs.shape[0]
    return ((probs - onehot) / bsz,)


# --------------------------------------------------------------------------
# Fused train step (oracle; also the single-device execution path).
# --------------------------------------------------------------------------


def forward_all(params, x):
    """Forward pass returning activations [x, a1, ..., logits]."""
    acts = [x]
    for li, (_, _, relu) in enumerate(LAYER_DIMS):
        (y,) = layer_fwd(acts[-1], params[2 * li], params[2 * li + 1], relu=relu)
        acts.append(y)
    return acts


def train_step(params, x, onehot, lr):
    """One SGD step. Returns (loss, *new_params)."""
    acts = forward_all(params, x)
    loss, probs = loss_fwd(acts[-1], onehot)
    (dy,) = loss_bwd(probs, onehot)
    new_params = list(params)
    for li in reversed(range(len(LAYER_DIMS))):
        _, _, relu = LAYER_DIMS[li]
        dx, dw, db = layer_bwd(acts[li], params[2 * li], acts[li + 1], dy, relu=relu)
        new_params[2 * li] = params[2 * li] - lr * dw
        new_params[2 * li + 1] = params[2 * li + 1] - lr * db
        dy = dx
    return (loss, *new_params)


def predict(params, x):
    """Logits for evaluation."""
    return (forward_all(params, x)[-1],)


# --------------------------------------------------------------------------
# Synthetic dataset (deterministic): a teacher projection labels random
# inputs, giving the e2e example a learnable task with a real loss curve.
# --------------------------------------------------------------------------


def synthetic_batch(step, seed=1234):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    x = jax.random.normal(key, (BATCH, LAYER_DIMS[0][0]), jnp.float32)
    teacher = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (LAYER_DIMS[0][0], CLASSES), jnp.float32
    )
    labels = jnp.argmax(x @ teacher, axis=-1)
    onehot = jax.nn.one_hot(labels, CLASSES, dtype=jnp.float32)
    return x, onehot
