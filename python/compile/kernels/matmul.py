"""Tiled Pallas matmul kernel (Layer 1).

TPU-adapted (DESIGN.md §3): tiles are sized for the 128×128 MXU and the
HBM↔VMEM schedule is expressed with a 3-D grid + BlockSpec index maps —
the K dimension is innermost so each (i, j) output tile stays resident in
VMEM while partial products accumulate (the Pallas revolving-buffer
pattern), replacing the CUDA shared-memory tiling the paper's GPU
operators rely on.

Runs with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tile sizes; shrunk automatically for small dims.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _tile(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (≥ 1)."""
    t = min(preferred, dim)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: accumulate x_tile @ y_tile into o_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=TILE_M, bn=TILE_N, bk=TILE_K):
    """``x @ y`` via the tiled Pallas kernel.

    x: f32[M, K], y: f32[K, N] → f32[M, N].
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm = _tile(m, bm)
    bn = _tile(n, bn)
    bk = _tile(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def vmem_bytes(m, n, k, bm=TILE_M, bn=TILE_N, bk=TILE_K):
    """Estimated VMEM footprint of one grid step (perf analysis, §Perf)."""
    bm, bn, bk = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    return 4 * (bm * bk + bk * bn + bm * bn)
