"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
pytest checks every kernel against (build-time gate for the AOT path)."""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def bias_act_ref(x, b, act="relu"):
    z = x + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "gelu":
        return 0.5 * z * (1.0 + jnp.tanh(0.7978845608 * (z + 0.044715 * z**3)))
    return z


def lstm_cell_ref(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    hsize = h.shape[1]
    i = jax.nn.sigmoid(gates[:, 0 * hsize : 1 * hsize])
    f = jax.nn.sigmoid(gates[:, 1 * hsize : 2 * hsize])
    g = jnp.tanh(gates[:, 2 * hsize : 3 * hsize])
    o = jax.nn.sigmoid(gates[:, 3 * hsize : 4 * hsize])
    c_new = f * c + i * g
    return o * jnp.tanh(c_new), c_new


def attention_ref(q, k, v):
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(s, axis=-1)
    return p @ v
