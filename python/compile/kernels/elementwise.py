"""Fused bias + activation Pallas kernel (Layer 1).

Fusing the bias add and the activation into one VMEM-resident kernel
avoids two HBM round-trips — the TPU analogue of the paper's operator
co-placement goal of keeping cheap elementwise ops next to their
producers (§3.1.2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bias_act_kernel(x_ref, b_ref, o_ref, *, act):
    z = x_ref[...] + b_ref[...]
    if act == "relu":
        z = jnp.maximum(z, 0.0)
    elif act == "gelu":
        z = 0.5 * z * (1.0 + jnp.tanh(0.7978845608 * (z + 0.044715 * z**3)))
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("act", "block_rows"))
def bias_act(x, b, *, act="relu", block_rows=128):
    """``act(x + b)`` with x: f32[M, N], b: f32[N]."""
    m, n = x.shape
    assert b.shape == (n,), f"bias shape {b.shape} vs {n}"
    br = min(block_rows, m)
    while m % br != 0:
        br -= 1
    kernel = functools.partial(_bias_act_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=(m // br,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, b)
