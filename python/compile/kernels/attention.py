"""Row-blocked scaled-dot-product attention Pallas kernel (Layer 1).

The Transformer benchmark's hot-spot. The paper's PyTorch implementation
treats attention as "one large matrix multiplication … a single module"
(§5.1); on TPU we stream query row-blocks through VMEM against the full
K/V for the sequence — a FlashAttention-style HBM↔VMEM schedule expressed
with BlockSpec index maps instead of CUDA thread-blocks (DESIGN.md §3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    # q: [bl, D]; k, v: [L, D] — one query block vs the full sequence.
    d = q_ref.shape[-1]
    s = jnp.dot(q_ref[...], k_ref[...].T, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.float32(d))
    # numerically-stable softmax in VMEM
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_q",))
def attention(q, k, v, *, block_q=64):
    """Single-head attention: q, k, v: f32[L, D] → f32[L, D]."""
    l, d = q.shape
    bq = min(block_q, l)
    while l % bq != 0:
        bq -= 1
    return pl.pallas_call(
        _attn_kernel,
        grid=(l // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, d), jnp.float32),
        interpret=True,
    )(q, k, v)
