"""Fused LSTM cell Pallas kernel (Layer 1).

The GNMT benchmark's hot-spot (paper §5.1). A TF-granularity LSTM cell is
~25 kernel launches (two matmuls, bias adds, four activations, elementwise
state updates); on TPU we fuse the whole cell so the 4H-wide gate block
stays in VMEM between the MXU matmuls and the VPU elementwise tail —
exactly the fusion Baechi's co-placement approximates at placement level.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    # gates: [B, 4H] resident in VMEM.
    gates = (
        jnp.dot(x_ref[...], wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h_ref[...], wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    hsize = h_ref.shape[1]
    i = jax.nn.sigmoid(gates[:, 0 * hsize : 1 * hsize])
    f = jax.nn.sigmoid(gates[:, 1 * hsize : 2 * hsize])
    g = jnp.tanh(gates[:, 2 * hsize : 3 * hsize])
    o = jax.nn.sigmoid(gates[:, 3 * hsize : 4 * hsize])
    c_new = f * c_ref[...] + i * g
    h_out[...] = o * jnp.tanh(c_new)
    c_out[...] = c_new


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    """One fused LSTM step.

    x: f32[B, I], h/c: f32[B, H], wx: f32[I, 4H], wh: f32[H, 4H],
    b: f32[4H] → (h', c').
    """
    bsz, hidden = h.shape
    return pl.pallas_call(
        _lstm_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        ),
        interpret=True,
    )(x, h, c, wx, wh, b)
