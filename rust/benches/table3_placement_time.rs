//! Paper Table 3: time to generate a placement for the 4-GPU target —
//! Baechi's algorithmic placers (measured) vs the learning-based
//! baseline (RL episodes × per-episode step-evaluation cost, the
//! normalized metric the paper uses for HierarchicalRL/Placeto) — plus
//! the scaled-up section: `hier` (coarsen→place→refine) vs flat m-SCT
//! on synthetic 100K–1M-op graphs, where placement *speed* is the whole
//! point.
//!
//! The algorithmic placers are served through the `PlacementEngine`
//! (one engine per benchmark, one request per placer, served
//! sequentially for measurement isolation), so the numbers measure
//! exactly the serving path the crate exposes.
//!
//! Asserted: at every synthetic size ≥ 100K ops the hierarchical placer
//! is strictly faster than flat m-SCT on the same graph — the coarse
//! graph m-SCT sees is orders of magnitude smaller, and the refine
//! sweep is linear.
//!
//! `--smoke` (or BAECHI_BENCH_SMOKE=1) runs only the 100K-op scale
//! comparison (what the CI bench gate checks); the full run adds the
//! paper table, the RL projection, and the 300K / 1M sizes.

use baechi::baselines::rl::{RlConfig, RlPlacer};
use baechi::coordinator::{engine_for, BaechiConfig, PlacerKind};
use baechi::engine::PlacementRequest;
use baechi::models::Benchmark;
use baechi::optimizer::{optimize, OptConfig};
use baechi::util::bench::maybe_write_json;
use baechi::util::json::Json;
use baechi::util::table::{fmt_secs, Table};

fn paper_table(json_rows: &mut Vec<Json>) {
    let benchmarks = [
        Benchmark::InceptionV3 { batch: 32 },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 40,
        },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 50,
        },
        Benchmark::Transformer { batch: 64 },
    ];
    // The real RL systems run 35 800 (HierarchicalRL) – 94 000 (Placeto)
    // samples; we run a small fleet and extrapolate linearly, exactly
    // like the paper normalizes the published numbers.
    const MEASURED_EPISODES: usize = 50;
    const PAPER_SAMPLES: f64 = 35_800.0;

    let mut t = Table::new(
        "Table 3 — placement generation time (4 devices)",
        &[
            "model",
            "m-topo",
            "m-etf",
            "m-sct",
            "rl (50 episodes, measured)",
            "rl @35.8k samples (projected)",
            "speedup m-sct vs rl",
        ],
    );

    for b in benchmarks {
        let mut row = vec![b.name()];
        let mut msct_time = f64::NAN;
        let cfg = BaechiConfig::paper_default(b, PlacerKind::MSct);
        let engine = engine_for(&cfg).expect("engine");
        // Serve each placer sequentially through the engine: the table
        // reports self-timed placement wall-clock, which concurrent
        // batch members would inflate through CPU contention.
        for placer in ["m-topo", "m-etf", "m-sct"] {
            let req = PlacementRequest::for_benchmark(b, placer).without_simulation();
            let r = engine.place(&req).expect("placement");
            row.push(fmt_secs(r.placement.placement_time));
            if placer == "m-sct" {
                msct_time = r.placement.placement_time;
            }
            let mut j = Json::obj();
            j.set("name", format!("{placer}/{}", b.name()).as_str())
                .set("placement_time_s", r.placement.placement_time)
                .set("ops", r.placement.device_of.len());
            json_rows.push(j);
        }
        // RL baseline on the optimized graph (sane action space).
        let g = b.graph();
        let opt = optimize(&g, &OptConfig::default());
        let cluster = cfg.cluster().expect("cluster");
        let t0 = std::time::Instant::now();
        let rl = RlPlacer::new(RlConfig {
            episodes: MEASURED_EPISODES,
            ..Default::default()
        });
        let (_, stats) = rl.place_with_stats(&opt.graph, &cluster).expect("rl");
        let measured = t0.elapsed().as_secs_f64();
        // Projection: what a *real* learning placer pays — each sample
        // executes a step on the cluster (simulated step time total),
        // scaled to the paper's sample count.
        let per_sample_real = stats.simulated_step_time_total / MEASURED_EPISODES as f64;
        let projected = PAPER_SAMPLES * (per_sample_real + measured / MEASURED_EPISODES as f64);
        row.push(fmt_secs(measured));
        row.push(fmt_secs(projected));
        row.push(format!("{:.0}×", projected / msct_time));
        t.row(&row);
    }
    t.print();
    println!(
        "paper: Inception 1.8–11.8 h (RL) vs 1–10 s (Baechi); GNMT 1.9–2.9 days vs ≤48 s;\n\
         shape check = Baechi orders of magnitude faster."
    );
}

fn scale_table(sizes: &[usize], json_rows: &mut Vec<Json>) {
    let mut t = Table::new(
        "Scale — hier (coarsen→place→refine) vs flat m-SCT (4 devices)",
        &["ops", "m-sct", "hier", "speedup"],
    );
    for &ops in sizes {
        let b = Benchmark::Synthetic { ops };
        let cfg = BaechiConfig::paper_default(
            b,
            PlacerKind::Hier {
                enabled: true,
                max_members: 0,
            },
        );
        let engine = engine_for(&cfg).expect("engine");
        // One graph build, shared by both requests: at 1M ops the
        // generator itself is non-trivial and must not skew either side.
        let g = b.graph();
        let mut times = [f64::NAN; 2];
        for (i, placer) in ["m-sct", "hier"].into_iter().enumerate() {
            let req = PlacementRequest::new(g.clone(), placer).without_simulation();
            let r = engine.place(&req).expect("placement");
            assert_eq!(
                r.placement.device_of.len(),
                ops,
                "{placer}: every op must be placed"
            );
            times[i] = r.placement.placement_time;
            let mut j = Json::obj();
            j.set("name", format!("{placer}/{}", b.name()).as_str())
                .set("placement_time_s", r.placement.placement_time)
                .set("ops", ops);
            json_rows.push(j);
        }
        let [msct, hier] = times;
        assert!(
            hier < msct,
            "hier must beat flat m-SCT at {ops} ops ({hier}s vs {msct}s)"
        );
        t.row(&[
            ops.to_string(),
            fmt_secs(msct),
            fmt_secs(hier),
            format!("{:.1}×", msct / hier),
        ]);
    }
    t.print();
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BAECHI_BENCH_SMOKE").is_ok();
    let mut json_rows: Vec<Json> = Vec::new();
    if !smoke {
        paper_table(&mut json_rows);
    }
    let sizes: &[usize] = if smoke {
        &[100_000]
    } else {
        &[100_000, 300_000, 1_000_000]
    };
    scale_table(sizes, &mut json_rows);
    let mut summary = Json::obj();
    summary.set("smoke", smoke);
    maybe_write_json("table3_placement_time", json_rows, Some(summary));
}
