//! Paper Figure 8: sensitivity to profiling errors — perturb every
//! compute/communication profile by up to ±20 %, place from the
//! *unperturbed* profile, and measure the perturbed step time relative
//! to the unperturbed one. Expected shape: ratios within ~0.97–1.3×
//! (m-SCT/m-ETF are resilient to profile noise).

use baechi::coordinator::{BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::optimizer::{expand_placement, optimize};
use baechi::profile::perturb::perturb_graph;
use baechi::sim::{simulate, SimConfig};
use baechi::util::rng::Pcg;
use baechi::util::stats::Summary;
use baechi::util::table::Table;

fn main() {
    let rows = [
        (Benchmark::InceptionV3 { batch: 32 }, 1.0),
        (Benchmark::InceptionV3 { batch: 32 }, 0.3),
        (
            Benchmark::Gnmt {
                batch: 128,
                seq_len: 40,
            },
            1.0,
        ),
        (Benchmark::Transformer { batch: 64 }, 1.0),
    ];
    const TRIALS: usize = 10;

    let mut t = Table::new(
        "Fig. 8 — step-time ratio under ±20% profile perturbation",
        &[
            "model (fraction)",
            "placer",
            "base step",
            "mean ratio",
            "min",
            "max",
        ],
    );
    for (b, fraction) in rows {
        for placer in [PlacerKind::MEtf, PlacerKind::MSct] {
            let cfg = BaechiConfig::paper_default(b, placer).with_memory_fraction(fraction);
            let graph = b.graph();
            let cluster = cfg.cluster().expect("cluster");
            let opt = optimize(&graph, &cfg.opt);
            let p = placer
                .build(b)
                .place(&opt.graph, &cluster)
                .expect("placement");
            let full = expand_placement(&graph, &opt, &p.device_of);
            let base = simulate(&graph, &cluster, &full, cfg.sim);
            assert!(base.ok(), "base run OOM");

            let mut rng = Pcg::seed(0xf18 + fraction.to_bits());
            let ratios: Vec<f64> = (0..TRIALS)
                .map(|_| {
                    let pg = perturb_graph(&graph, 0.2, &mut rng);
                    let r = simulate(&pg, &cluster, &full, cfg.sim);
                    assert!(r.ok(), "perturbed run OOM");
                    r.makespan / base.makespan
                })
                .collect();
            let s = Summary::of(&ratios);
            t.row(&[
                format!("{} ({fraction})", b.name()),
                placer.name().to_string(),
                format!("{:.3}", base.makespan),
                format!("{:.3}", s.mean),
                format!("{:.3}", s.min),
                format!("{:.3}", s.max),
            ]);
        }
    }
    t.print();
    println!("paper: ratios 0.99–1.3 (TF) and 0.97–1.08 (PyTorch).");
}

trait FractionBits {
    fn to_bits(&self) -> u64;
}
impl FractionBits for f64 {
    fn to_bits(&self) -> u64 {
        f64::to_bits(*self)
    }
}
