//! Paper Table 5: step times with insufficient memory (devices capped at
//! a fraction of their 8 GiB). Expected shape: single GPU always OOMs;
//! the expert OOMs on Inception but survives on GNMT/Transformer; all
//! three Baechi placers succeed everywhere, paying a small step-time
//! overhead vs sufficient memory.

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::util::table::Table;

fn main() {
    // (benchmark, memory fraction) rows of Table 5.
    let rows = [
        (Benchmark::InceptionV3 { batch: 32 }, 0.3),
        (
            Benchmark::Gnmt {
                batch: 128,
                seq_len: 40,
            },
            0.3,
        ),
        (Benchmark::InceptionV3 { batch: 64 }, 0.4),
        (Benchmark::Transformer { batch: 64 }, 0.3),
    ];

    let mut t = Table::new(
        "Table 5 — step times (s) with insufficient memory, 4 GPUs",
        &[
            "model",
            "fraction",
            "single",
            "expert",
            "m-topo",
            "m-etf",
            "m-sct",
            "m-sct slowdown vs full-mem",
        ],
    );

    for (b, fraction) in rows {
        let mut cells = vec![b.name(), format!("{fraction}")];
        let mut msct_step = None;
        for placer in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
        ] {
            let cfg =
                BaechiConfig::paper_default(b, placer).with_memory_fraction(fraction);
            let cell = match run(&cfg) {
                Ok(r) => match r.step_time() {
                    Some(s) => {
                        if placer == PlacerKind::MSct {
                            msct_step = Some(s);
                        }
                        format!("{s:.3}")
                    }
                    None => "OOM".to_string(),
                },
                Err(_) => "OOM".to_string(), // placement-time OOM
            };
            cells.push(cell);
        }
        // Slowdown vs the sufficient-memory m-SCT run.
        let full = run(&BaechiConfig::paper_default(b, PlacerKind::MSct)).expect("full mem");
        let slowdown = match (msct_step, full.step_time()) {
            (Some(a), Some(b)) => format!("{:+.1}%", (a / b - 1.0) * 100.0),
            _ => "-".into(),
        };
        cells.push(slowdown);
        t.row(&cells);
    }
    t.print();
    println!(
        "paper shape: single always OOM; expert OOMs on Inception only;\n\
         m-* always place, with ≤ ~16% step-time overhead vs sufficient memory."
    );
}
