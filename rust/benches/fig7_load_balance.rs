//! Paper Figure 7: per-GPU peak memory usage under m-SCT, normalized to
//! the (fractional) memory limit. Expected shape: Inception leans on a
//! subset of GPUs (barriers limit parallelism); GNMT/Transformer are
//! spread more evenly.

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::util::table::{fmt_bytes, Table};

fn main() {
    let rows = [
        (Benchmark::InceptionV3 { batch: 32 }, 0.3),
        (
            Benchmark::Gnmt {
                batch: 128,
                seq_len: 40,
            },
            0.3,
        ),
        (Benchmark::Transformer { batch: 64 }, 0.3),
    ];

    for (b, fraction) in rows {
        let cfg = BaechiConfig::paper_default(b, PlacerKind::MSct).with_memory_fraction(fraction);
        let r = run(&cfg).expect("pipeline");
        let mut t = Table::new(
            &format!(
                "Fig. 7 — m-SCT peak memory, {} at {:.0}% cap ({} per GPU)",
                b.name(),
                fraction * 100.0,
                fmt_bytes(r.device_capacity)
            ),
            &["device", "peak", "normalized", "bar"],
        );
        for (i, &p) in r.peak_memory.iter().enumerate() {
            let frac = p as f64 / r.device_capacity as f64;
            t.row(&[
                format!("gpu{i}"),
                fmt_bytes(p),
                format!("{frac:.2}"),
                "█".repeat((frac * 40.0).round() as usize),
            ]);
        }
        t.print();
        if let Some(oom) = &r.sim.oom {
            println!("  note: {oom}");
        }
    }
}
