//! Paper Table 4: step times with sufficient memory (4 × 8 GiB), all
//! placers vs single-GPU and expert, plus speedup columns.
//!
//! Expected shape: m-ETF/m-SCT ≥ single GPU on Inception (barrier-heavy,
//! little to parallelize), faster than single on GNMT/Transformer
//! (enc/dec parallelism), within single-digit % of the expert; m-TOPO
//! consistently worst.

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::util::table::Table;

fn main() {
    let benchmarks = [
        Benchmark::InceptionV3 { batch: 32 },
        Benchmark::InceptionV3 { batch: 64 },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 40,
        },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 50,
        },
        Benchmark::Transformer { batch: 64 },
        Benchmark::Transformer { batch: 128 },
    ];

    let mut t = Table::new(
        "Table 4 — step times (s), sufficient memory, 4 GPUs",
        &[
            "model",
            "single",
            "expert",
            "m-topo",
            "m-etf",
            "m-sct",
            "m-etf vs single",
            "m-sct vs single",
            "m-etf vs expert",
            "m-sct vs expert",
        ],
    );

    for b in benchmarks {
        let mut step = std::collections::BTreeMap::new();
        for placer in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
        ] {
            let cfg = BaechiConfig::paper_default(b, placer);
            let r = run(&cfg).expect("pipeline");
            step.insert(
                placer.name(),
                r.step_time().unwrap_or(f64::NAN), // NaN renders as OOM-ish
            );
        }
        let pct = |base: f64, x: f64| format!("{:+.1}%", (base / x - 1.0) * 100.0);
        t.row(&[
            b.name(),
            format!("{:.3}", step["single-gpu"]),
            format!("{:.3}", step["expert"]),
            format!("{:.3}", step["m-topo"]),
            format!("{:.3}", step["m-etf"]),
            format!("{:.3}", step["m-sct"]),
            pct(step["single-gpu"], step["m-etf"]),
            pct(step["single-gpu"], step["m-sct"]),
            pct(step["expert"], step["m-etf"]),
            pct(step["expert"], step["m-sct"]),
        ]);
    }
    t.print();
    println!(
        "paper shape: GNMT m-ETF +12–34% over single, within ±6.2% of expert;\n\
         Inception m-* ≈ single (expert = single GPU); m-TOPO slowest everywhere."
    );
}
