//! Micro-benchmarks of the hot paths (§Perf): graph generation,
//! optimizer, each placer, ES throughput, LP solve, and the PJRT
//! kernel-execution path. These are the before/after numbers for the
//! EXPERIMENTS.md §Perf iteration log.

use baechi::models::Benchmark;
use baechi::optimizer::{optimize, OptConfig};
use baechi::placer::{metf::MEtf, msct::MSct, mtopo::MTopo, Placer};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::util::bench::Bench;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new("perf_micro")
        .budget(Duration::from_millis(200), Duration::from_millis(1500))
        .iters(3, 50);

    // Graph generation.
    bench.run("gen/gnmt:128:40", || {
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 40,
        }
        .graph()
    });
    bench.run("gen/inception:32", || {
        Benchmark::InceptionV3 { batch: 32 }.graph()
    });

    // Optimizer.
    let gnmt = Benchmark::Gnmt {
        batch: 128,
        seq_len: 40,
    }
    .graph();
    bench.run("optimize/gnmt", || optimize(&gnmt, &OptConfig::default()));

    // Placers on the fused graph.
    let opt = optimize(&gnmt, &OptConfig::default());
    let cluster = Cluster::homogeneous(4, 8 << 30, CommModel::pcie_via_host());
    bench.run("place/m-topo/gnmt-fused", || {
        MTopo.place(&opt.graph, &cluster).unwrap()
    });
    bench.run("place/m-etf/gnmt-fused", || {
        MEtf.place(&opt.graph, &cluster).unwrap()
    });
    bench.run("place/m-sct/gnmt-fused", || {
        MSct::default().place(&opt.graph, &cluster).unwrap()
    });
    // m-ETF on the raw 18k-op graph (placement-scalability hot path).
    bench.run("place/m-etf/gnmt-raw-18k", || {
        MEtf.place(&gnmt, &cluster).unwrap()
    });

    // ES throughput on the raw graph.
    let placement = MEtf.place(&gnmt, &cluster).unwrap();
    let m = bench.run("sim/gnmt-raw-18k", || {
        simulate(&gnmt, &cluster, &placement.device_of, SimConfig::default())
    });
    let events = simulate(&gnmt, &cluster, &placement.device_of, SimConfig::default()).events;
    let evps = events as f64 / m.summary.p50;
    println!("ES throughput: {events} events in {:.1} ms → {:.2} M events/s", m.summary.p50 * 1e3, evps / 1e6);

    // LP on the fused transformer.
    let tf = Benchmark::Transformer { batch: 64 }.graph();
    let tf_opt = optimize(&tf, &OptConfig::default());
    let comm = CommModel::pcie_via_host();
    bench.run("lp/sct-favorites/transformer-fused", || {
        baechi::lp::sct::lp_favorites(&tf_opt.graph, &comm).unwrap()
    });

    // PJRT kernel execution (requires artifacts).
    let dir = baechi::runtime::artifact::ArtifactRegistry::default_dir();
    if dir.join("manifest.json").exists() {
        let rt = baechi::runtime::Runtime::cpu().unwrap();
        let reg = baechi::runtime::artifact::ArtifactRegistry::open(rt, &dir).unwrap();
        let exec = reg.load("kernel_matmul").unwrap();
        let x = baechi::runtime::artifact::literal_f32(&vec![1.0; 128 * 128], &[128, 128]).unwrap();
        let y = baechi::runtime::artifact::literal_f32(&vec![0.5; 128 * 128], &[128, 128]).unwrap();
        bench.run("pjrt/kernel_matmul-128", || exec.run(&[x.clone(), y.clone()]).unwrap());
    } else {
        eprintln!("(skipping pjrt benches: run `make artifacts`)");
    }

    bench.finish();
}
