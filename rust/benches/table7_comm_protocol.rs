//! Paper Table 7: benefit of the Baechi-PY communication protocol —
//! greedy-push tx/rx streams overlapping compute (§3.2.2) vs the naive
//! blocking `.to()` baseline where a transfer stalls both endpoint
//! devices.
//!
//! Expected shape: a few-% step-time win, larger where the placement
//! crosses devices more (memory-constrained Inception), near zero for
//! models with a strong linear spine (Transformer).

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::sim::SimConfig;
use baechi::util::table::Table;

fn main() {
    // (model, memory fraction) rows of Table 7.
    let rows = [
        (Benchmark::InceptionV3 { batch: 32 }, 0.3),
        (Benchmark::InceptionV3 { batch: 64 }, 0.4),
        (Benchmark::Transformer { batch: 64 }, 1.0),
    ];

    let mut t = Table::new(
        "Table 7 — communication-protocol benefit (PyTorch semantics)",
        &[
            "model (fraction)",
            "placer",
            "without protocol",
            "with protocol",
            "% change",
        ],
    );
    for (b, fraction) in rows {
        for placer in [PlacerKind::MEtf, PlacerKind::MSct] {
            let base = BaechiConfig::paper_default(b, placer).with_memory_fraction(fraction);
            let mut blocking_cfg = base.clone();
            blocking_cfg.sim = SimConfig {
                overlap_comm: false,
                ..base.sim
            };
            let with = run(&base).expect("with protocol");
            let without = run(&blocking_cfg).expect("without protocol");
            let (ws, wos) = (
                with.step_time().unwrap_or(f64::NAN),
                without.step_time().unwrap_or(f64::NAN),
            );
            t.row(&[
                format!("{} ({fraction})", b.name()),
                placer.name().to_string(),
                format!("{wos:.3}"),
                format!("{ws:.3}"),
                format!("{:+.1}%", (wos / ws - 1.0) * 100.0),
            ]);
        }
    }
    t.print();
    println!("paper: up to 5.5% on memory-constrained Inception, ~0% on Transformer.");
}
