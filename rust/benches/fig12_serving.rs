//! Figure 12 (extension): placement-as-a-service throughput.
//!
//! Sweeps mutation rate × cache shard count over closed-loop streams of
//! mutated GNMT / Inception / Transformer graphs served by
//! `serve::PlacementService`, and reports placements/sec, latency
//! percentiles, cache hit rate, and the incremental-vs-full split.
//! The streams model the serving workload: users iterating on a model,
//! most requests exact repeats or one-tweak deltas of the previous
//! version.
//!
//! Asserted: every cell completes its whole stream error-free, repeats
//! hit the cache (aggregate hit rate > 0), and on small-delta streams
//! incremental placements are strictly cheaper wall-clock than full
//! pipeline runs.
//!
//! `--smoke` (or BAECHI_BENCH_SMOKE=1) shrinks the streams for CI.

use baechi::coordinator::{run_serve_bench, BaechiConfig, PlacerKind, ServeBenchOpts};
use baechi::models::Benchmark;
use baechi::util::bench::maybe_write_json;
use baechi::util::json::Json;
use baechi::util::table::Table;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BAECHI_BENCH_SMOKE").is_ok();
    let requests = if smoke { 24 } else { 120 };

    let models = [
        Benchmark::Gnmt {
            batch: 16,
            seq_len: 8,
        },
        Benchmark::InceptionV3 { batch: 16 },
        Benchmark::Transformer { batch: 32 },
    ];
    let mutation_rates = [0.1, 0.5];
    let shard_counts = [1usize, 8];

    let mut t = Table::new(
        "Fig. 12 — serving throughput: mutation rate x cache shards",
        &[
            "model",
            "mut rate",
            "shards",
            "placements/s",
            "p50",
            "p99",
            "hit rate",
            "inc/full",
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let (mut hits, mut completed) = (0u64, 0u64);
    // Latency sums weighted by counts, aggregated over small-delta
    // (low mutation rate) cells only — the acceptance comparison.
    let (mut inc_n, mut inc_sum) = (0u64, 0.0f64);
    let (mut full_n, mut full_sum) = (0u64, 0.0f64);

    for model in models {
        for &mutation_rate in &mutation_rates {
            for &shards in &shard_counts {
                let cfg = BaechiConfig::paper_default(model, PlacerKind::MEtf);
                let opts = ServeBenchOpts {
                    requests,
                    clients: 4,
                    mutation_rate,
                    cache_shards: shards,
                    workers: 2,
                    ..ServeBenchOpts::default()
                };
                let r = run_serve_bench(&cfg, &opts).expect("serve bench cell");
                let m = &r.metrics;
                assert_eq!(
                    m.completed, requests as u64,
                    "{}: stream not fully served",
                    r.benchmark
                );
                assert_eq!(m.errors, 0, "{}: serving errors", r.benchmark);
                hits += m.cache_hits;
                completed += m.completed;
                if mutation_rate <= 0.1 {
                    inc_n += m.incremental;
                    inc_sum += m.incremental_mean_latency_s * m.incremental as f64;
                    full_n += m.full;
                    full_sum += m.full_mean_latency_s * m.full as f64;
                }
                t.row(&[
                    r.benchmark.clone(),
                    format!("{:.0}%", mutation_rate * 100.0),
                    shards.to_string(),
                    format!("{:.1}", r.placements_per_sec),
                    format!("{:.2}ms", m.p50_latency_s * 1e3),
                    format!("{:.2}ms", m.p99_latency_s * 1e3),
                    format!("{:.0}%", m.cache_hit_rate() * 100.0),
                    format!("{}/{}", m.incremental, m.full),
                ]);
                let mut row = Json::obj();
                row.set("model", r.benchmark.as_str())
                    .set("mutation_rate", mutation_rate)
                    .set("cache_shards", shards)
                    .set("requests", requests)
                    .set("placements_per_sec", r.placements_per_sec)
                    .set("p50_latency_s", m.p50_latency_s)
                    .set("p99_latency_s", m.p99_latency_s)
                    .set("cache_hit_rate", m.cache_hit_rate())
                    .set("incremental", m.incremental)
                    .set("full", m.full)
                    .set("incremental_mean_latency_s", m.incremental_mean_latency_s)
                    .set("full_mean_latency_s", m.full_mean_latency_s)
                    .set("engine_cache_evictions", m.engine_cache.evictions);
                json_rows.push(row);
            }
        }
    }
    t.print();

    let agg_hit_rate = hits as f64 / completed.max(1) as f64;
    assert!(
        agg_hit_rate > 0.0,
        "streams with repeats must produce cache hits"
    );
    let inc_mean = inc_sum / inc_n.max(1) as f64;
    let full_mean = full_sum / full_n.max(1) as f64;
    if inc_n > 0 && full_n > 0 {
        assert!(
            inc_mean < full_mean,
            "incremental placements must be strictly cheaper than full on \
             small-delta streams ({inc_mean}s vs {full_mean}s)"
        );
    }

    let mut summary = Json::obj();
    summary
        .set("aggregate_cache_hit_rate", agg_hit_rate)
        .set("small_delta_incremental_count", inc_n)
        .set("small_delta_full_count", full_n)
        .set("small_delta_incremental_mean_latency_s", inc_mean)
        .set("small_delta_full_mean_latency_s", full_mean)
        .set("smoke", smoke);
    maybe_write_json("serving", json_rows, Some(summary));
    println!(
        "takeaway: the placement service turns a {:.0}% cache hit rate out of \
         mutation streams, and serves small deltas incrementally at {:.2}ms \
         mean vs {:.2}ms for full pipeline runs.",
        agg_hit_rate * 100.0,
        inc_mean * 1e3,
        full_mean * 1e3
    );
}
