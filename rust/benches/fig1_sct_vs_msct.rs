//! Paper Figure 1: classical SCT OOMs under a per-device memory cap;
//! m-SCT succeeds with a slightly longer makespan (8 → 9 time units in
//! the paper; we reproduce exactly that).

use baechi::models::linreg::{fig1_graph, FIG1_MEM_UNIT};
use baechi::placer::{msct::MSct, Placer};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::util::table::Table;

fn main() {
    let g = fig1_graph();
    let unit_comm = CommModel::new(0.0, 1.0).unwrap();
    let cap = 4 * FIG1_MEM_UNIT + 12; // 4 units + transfer-buffer headroom
    let free = Cluster::homogeneous(3, 1_000_000 * FIG1_MEM_UNIT, unit_comm);
    let capped = Cluster::homogeneous(3, cap, unit_comm);

    let sct = MSct::with_lp().place(&g, &free).expect("sct placement");
    let sct_run = simulate(&g, &capped, &sct.device_of, SimConfig::default());
    let msct = MSct::with_lp().place(&g, &capped).expect("m-sct placement");
    let msct_run = simulate(&g, &capped, &msct.device_of, SimConfig::default());

    let mut t = Table::new(
        "Fig. 1 — SCT vs m-SCT, per-device memory = 4 units (paper: 8 → OOM, 9 → ok)",
        &["algorithm", "makespan (time units)", "on capped devices"],
    );
    t.row(&[
        "SCT (infinite-memory schedule)".into(),
        format!("{:.0}", sct.predicted_makespan),
        match &sct_run.oom {
            Some(o) => format!("OOM on gpu{}", o.device),
            None => "fits".into(),
        },
    ]);
    t.row(&[
        "m-SCT (memory-constrained)".into(),
        format!("{:.0}", msct_run.makespan),
        "succeeds".into(),
    ]);
    t.print();

    assert!(msct_run.ok());
    assert!(msct_run.makespan >= sct.predicted_makespan);
}
