//! Paper Table 6: benefit of the Baechi-TF graph optimizations
//! (co-placement §3.1.2 + operator fusion & forward-only §3.1.3):
//! operators to place, placement time, and step time — un-optimized vs
//! optimized, for m-SCT.
//!
//! Expected shape: op count reduced by 1–2 orders of magnitude,
//! placement time by ≥10×, step time improved (ρ ≫ 1 graphs suffer
//! badly from scattering tiny ops). Uses the heuristic favorite-child
//! variant in both columns so placement time isolates the graph-size
//! effect (the LP-vs-heuristic cost is covered by Table 3).

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::optimizer::OptConfig;
use baechi::util::table::{fmt_secs, Table};

fn main() {
    let benchmarks = [
        Benchmark::InceptionV3 { batch: 32 },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 40,
        },
        Benchmark::Gnmt {
            batch: 128,
            seq_len: 50,
        },
    ];

    let mut t = Table::new(
        "Table 6 — optimization benefit (m-SCT, 4 GPUs, sufficient memory)",
        &[
            "model",
            "ops (unopt)",
            "place t (unopt)",
            "step (unopt)",
            "ops (opt)",
            "place t (opt)",
            "step (opt)",
            "place speedup",
            "step speedup",
        ],
    );

    for b in benchmarks {
        let unopt = run(&BaechiConfig::paper_default(b, PlacerKind::MSctHeuristic)
            .with_opt(OptConfig::none()))
        .expect("unoptimized run");
        let opt = run(&BaechiConfig::paper_default(b, PlacerKind::MSctHeuristic)).expect("optimized run");
        t.row(&[
            b.name(),
            unopt.placed_ops.to_string(),
            fmt_secs(unopt.placement_time),
            format!("{:.3}", unopt.step_time().unwrap_or(f64::NAN)),
            opt.placed_ops.to_string(),
            fmt_secs(opt.placement_time),
            format!("{:.3}", opt.step_time().unwrap_or(f64::NAN)),
            format!("{:.1}×", unopt.placement_time / opt.placement_time),
            format!(
                "{:.2}×",
                unopt.step_time().unwrap_or(f64::NAN) / opt.step_time().unwrap_or(f64::NAN)
            ),
        ]);
    }
    t.print();
    println!(
        "paper: Inception 6884→17 ops, 68 s→0.9 s placement, 0.302→0.269 step;\n\
         GNMT 18050→542 / 22340→706 ops, 275→1.2 s / 406→2.4 s, 0.580→0.212 / 0.793→0.267."
    );
}
