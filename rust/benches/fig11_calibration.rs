//! Figure 11 (extension): calibration fit error vs measurement noise.
//!
//! The calibration subsystem (`baechi::calibrate`) learns the cluster
//! model — per-link CommModels, island partitions, device speeds — from
//! pairwise transfer and op-probe measurements, the way the paper's
//! Profiler (§4.1) learns its single linear model. This bench sweeps
//! the measurement noise level (multiplicative log-normal sigma) across
//! the three built-in ground-truth topology families and reports the
//! mean relative error of the recovered all-pairs effective matrix
//! against the ground truth, plus the fitter's own self-assessment
//! (its residual against the measurements).
//!
//! Asserted: at zero noise every family recovers the pair matrix within
//! 5% mean relative error (the repo's acceptance bar — in practice it
//! is ~1e-9), and recovery degrades gracefully (≤ 5% + 8·noise).

use baechi::calibrate::{collect, fit_cluster, pair_matrix_error, CalibrationPlan, SyntheticSource};
use baechi::profile::CommModel;
use baechi::topology::Topology;
use baechi::util::bench::maybe_write_json;
use baechi::util::json::Json;
use baechi::util::table::Table;

fn main() {
    let comm = |lat: f64, bw: f64| CommModel::new(lat, bw).unwrap();
    let truths: Vec<(&str, Topology)> = vec![
        ("uniform/4", Topology::uniform(4, comm(5e-5, 6e9))),
        (
            "nvlink-islands/4x2",
            Topology::nvlink_islands(4, 2, comm(5e-6, 48e9), comm(5e-5, 6e9)).unwrap(),
        ),
        (
            "two-tier/2x3",
            Topology::two_tier(2, 3, comm(1e-5, 10e9), comm(8e-5, 1.25e9)).unwrap(),
        ),
    ];
    let noise_levels = [0.0, 0.005, 0.01, 0.02, 0.05, 0.1];
    // Fits averaged per (truth, noise) cell — seeded, so deterministic.
    const SEEDS: u64 = 5;

    let mut t = Table::new(
        "Fig. 11 — calibration fit error vs measurement noise (synthetic source)",
        &[
            "ground truth",
            "noise",
            "pair err vs truth",
            "self-residual",
            "islands ok",
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut zero_noise_worst = 0.0f64;
    for (label, truth) in &truths {
        for &noise in &noise_levels {
            let mut err_sum = 0.0;
            let mut residual_sum = 0.0;
            let mut islands_ok = 0usize;
            for seed in 0..SEEDS {
                let mut src =
                    SyntheticSource::new(truth.clone(), noise, 0x11f + seed).expect("source");
                let m = collect(&mut src, &CalibrationPlan::default()).expect("collect");
                let cal = fit_cluster(&m).expect("fit");
                err_sum += pair_matrix_error(&cal.topology, truth);
                residual_sum += cal.report.mean_rel_error;
                islands_ok += (cal.topology.islands() == truth.islands()) as usize;
            }
            let err = err_sum / SEEDS as f64;
            let residual = residual_sum / SEEDS as f64;
            if noise == 0.0 {
                zero_noise_worst = zero_noise_worst.max(err);
            }
            assert!(
                err <= 0.05 + 8.0 * noise,
                "{label} @ noise {noise}: pair error {err} degraded beyond the bound"
            );
            t.row(&[
                label.to_string(),
                format!("{:.1}%", noise * 100.0),
                format!("{:.3}%", err * 100.0),
                format!("{:.3}%", residual * 100.0),
                format!("{islands_ok}/{SEEDS}"),
            ]);
            let mut row = Json::obj();
            row.set("truth", *label)
                .set("noise", noise)
                .set("pair_error_vs_truth", err)
                .set("self_residual", residual)
                .set("islands_recovered", islands_ok)
                .set("seeds", SEEDS);
            json_rows.push(row);
        }
    }
    t.print();
    let mut summary = Json::obj();
    summary.set("zero_noise_worst_pair_error", zero_noise_worst);
    maybe_write_json("fig11_calibration", json_rows, Some(summary));
    assert!(
        zero_noise_worst < 0.05,
        "zero-noise calibration must recover the pair matrix within 5% \
         (worst: {:.3}%)",
        zero_noise_worst * 100.0
    );
    println!(
        "takeaway: measurement-driven calibration reproduces the ground-truth \
         pair matrix to {:.2e} mean relative error at zero noise, and stays \
         within 5% + 8x the measurement noise as noise grows.",
        zero_noise_worst
    );
}
