//! Figure 10 (extension): contention-driven dynamic re-placement.
//!
//! The ROADMAP's "dynamic re-placement under contention" payoff, made
//! measurable: on a two-tier cluster (2 machines × 2 GPUs behind shared
//! NIC trunks) a single-shot placement commits cross-machine transfers
//! one at a time and never sees the aggregate trunk queueing — nor, in
//! blocking-communication mode (Table 7's "without protocol" baseline),
//! the compute stalls — that its own decisions cause. The iterative
//! loop (`PlacementEngine::place_iterative`) simulates, degrades the
//! saturated links by the observed queueing delay, and re-places.
//!
//! Swept here: NIC trunk slowdown ratio × communication protocol ×
//! comm model (sequential queueing vs bandwidth-sharing flows) ×
//! placer, over a wide fan-out graph (the trunk worst case: every chain
//! landing on the remote machine queues its input tensor behind the
//! others) and GNMT. Reported per row: single-shot vs iterative
//! simulated step time, rounds used, and the recovered makespan.
//! Iterative keeps the best round, so it can never lose; the bench
//! asserts it strictly wins somewhere in the sweep, and that the flow
//! simulator reports real contention (non-empty `ContentionReport`)
//! under parallel comm — the signal the feedback loop runs on.

use baechi::engine::{PlacementEngine, PlacementRequest};
use baechi::feedback::ReplacementPolicy;
use baechi::graph::{OpGraph, OpKind};
use baechi::models::Benchmark;
use baechi::profile::{Cluster, CommModel};
use baechi::sim::SimConfig;
use baechi::topology::Topology;
use baechi::util::bench::maybe_write_json;
use baechi::util::json::Json;
use baechi::util::table::Table;

/// `width` parallel chains of `len` ops fanning out of one source and
/// joining at one sink, with `bytes`-sized tensors on every edge.
fn fanout_graph(width: usize, len: usize, compute: f64, bytes: u64) -> OpGraph {
    let mut g = OpGraph::new("fanout");
    let src = g.add_node("src", OpKind::MatMul);
    g.node_mut(src).compute = compute;
    g.node_mut(src).mem.output = bytes;
    g.node_mut(src).output_bytes = bytes;
    let sink = g.add_node("sink", OpKind::MatMul);
    g.node_mut(sink).compute = compute;
    for c in 0..width {
        let mut prev = src;
        for l in 0..len {
            let id = g.add_node(&format!("c{c}_{l}"), OpKind::MatMul);
            g.node_mut(id).compute = compute;
            g.node_mut(id).mem.output = bytes;
            g.node_mut(id).output_bytes = bytes;
            g.add_edge(prev, id, bytes);
            prev = id;
        }
        g.add_edge(prev, sink, bytes);
    }
    g
}

/// 2 machines × 2 GPUs; the NIC trunk runs `ratio`× slower than the
/// intra-machine PCIe links. `sequential` picks the comm model:
/// one-at-a-time link queues vs max-min fair bandwidth-sharing flows.
fn two_tier_cluster(ratio: f64, mem: u64, sequential: bool) -> Cluster {
    let intra = CommModel::new(1e-5, 10e9).unwrap();
    let inter = CommModel::new(1e-5 * ratio, 10e9 / ratio).unwrap();
    Cluster::homogeneous(4, mem, inter)
        .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
        .unwrap()
        .with_sequential_comm(sequential)
}

fn main() {
    let policy = ReplacementPolicy::rounds(4).with_threshold(0.4);
    let mem = 32u64 << 30;
    let fanout = fanout_graph(12, 2, 0.3, 512 << 20);
    let gnmt = Benchmark::Gnmt { batch: 32, seq_len: 10 }.graph();

    // (label, graph, trunk ratios, overlap_comm, sequential_comm)
    let scenarios: Vec<(&str, &OpGraph, Vec<f64>, bool, bool)> = vec![
        ("fanout/overlap", &fanout, vec![4.0, 8.0, 16.0], true, true),
        ("fanout/blocking", &fanout, vec![4.0, 16.0], false, true),
        ("fanout/flow", &fanout, vec![4.0, 16.0], true, false),
        ("gnmt/overlap", &gnmt, vec![8.0, 16.0], true, true),
        ("gnmt/blocking", &gnmt, vec![8.0], false, true),
        ("gnmt/flow", &gnmt, vec![8.0], true, false),
    ];

    let mut t = Table::new(
        "Fig. 10 — single-shot vs contention-driven iterative placement (two-tier 2×2)",
        &[
            "scenario",
            "placer",
            "trunk ratio",
            "step (single)",
            "step (iterative)",
            "rounds",
            "recovered",
        ],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut best_gain = 0.0f64;
    let mut flow_busy = 0.0f64;
    let mut flow_blocked = 0.0f64;
    for (label, graph, ratios, overlap, sequential) in &scenarios {
        for &ratio in ratios {
            let engine = PlacementEngine::builder()
                .cluster(two_tier_cluster(ratio, mem, *sequential))
                .sim(SimConfig {
                    overlap_comm: *overlap,
                    ..SimConfig::default()
                })
                .build()
                .expect("engine");
            for placer in ["m-etf", "m-sct"] {
                let req = PlacementRequest::new((*graph).clone(), placer);
                let single = engine.place(&req).expect("single-shot placement");
                let sim = single.sim.as_ref().expect("sim");
                let single_step = sim.makespan;
                if !sequential {
                    flow_busy = flow_busy.max(sim.contention.busy_seconds);
                    flow_blocked = flow_blocked.max(sim.contention.blocked_seconds);
                }
                let it = engine.place_iterative(&req, &policy).expect("iterative");
                let iter_step = it.final_makespan();
                assert!(
                    iter_step <= single_step + 1e-9,
                    "{label} {placer} {ratio}x: iterative (best-of-rounds) regressed \
                     {iter_step} vs {single_step}"
                );
                let gain = it.improvement();
                best_gain = best_gain.max(gain);
                t.row(&[
                    label.to_string(),
                    placer.to_string(),
                    format!("{ratio}x"),
                    format!("{single_step:.4}"),
                    format!("{iter_step:.4}"),
                    format!("{}", it.rounds.len().saturating_sub(1)),
                    format!("{:.1}%", gain * 100.0),
                ]);
                let mut row = Json::obj();
                row.set("scenario", *label)
                    .set("placer", placer)
                    .set("trunk_ratio", ratio)
                    .set("overlap_comm", *overlap)
                    .set("sequential_comm", *sequential)
                    .set("blocked_fraction", sim.contention.blocked_fraction())
                    .set("step_single_s", single_step)
                    .set("step_iterative_s", iter_step)
                    .set("rounds", it.rounds.len().saturating_sub(1))
                    .set("gain", gain);
                json_rows.push(row);
            }
        }
    }
    t.print();
    let mut summary = Json::obj();
    summary.set("best_gain", best_gain);
    maybe_write_json("fig10_replacement", json_rows, Some(summary));
    assert!(
        best_gain > 0.005,
        "iterative re-placement should recover makespan in at least one contended \
         two-tier scenario (best gain: {:.2}%)",
        best_gain * 100.0
    );
    assert!(
        flow_busy > 0.0 && flow_blocked > 0.0,
        "the flow simulator should populate the contention report under parallel \
         comm (busy {flow_busy} s, slowdown {flow_blocked} s)"
    );
    println!(
        "takeaway: feeding observed trunk queueing back into the placer recovers \
         up to {:.1}% of the simulated step time that single-shot placement loses.",
        best_gain * 100.0
    );
}
