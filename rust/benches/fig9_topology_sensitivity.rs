//! Figure 9 (extension): topology sensitivity — sweep the intra-island /
//! inter-island bandwidth ratio of a 2×2 NVLink-islands cluster and
//! watch the placers shift cut edges onto the fast links.
//!
//! For each model × placer the uniform-PCIe placement is the baseline;
//! each ratio re-places against `nvlink_islands(4, 2)` whose intra
//! links are `ratio`× the PCIe bandwidth (and `1/ratio`× the latency).
//! Reported per row: simulated step time under the uniform placement vs
//! the topology-aware one, the same topology-aware placement re-priced
//! by the bandwidth-sharing flow simulator (parallel comm — concurrent
//! transfers split each link max-min fairly instead of queueing one at
//! a time), how many ops moved relative to the uniform placement, and
//! the fraction of cut (cross-device) traffic that stays on fast
//! intra-island links.
//!
//! Expected shape: at ratio 1 the islands cluster is cost-equivalent to
//! uniform and placements barely move; from a ≥4× gap m-SCT visibly
//! re-places onto islands and the cross-island traffic fraction drops.

use baechi::engine::{PlacementEngine, PlacementRequest};
use baechi::models::Benchmark;
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::topology::Topology;
use baechi::util::bench::maybe_write_json;
use baechi::util::json::Json;
use baechi::util::table::Table;

fn main() {
    let inter = CommModel::pcie_via_host();
    let benchmarks = [
        Benchmark::Transformer { batch: 8 },
        Benchmark::Gnmt {
            batch: 32,
            seq_len: 10,
        },
    ];
    let placers = ["m-etf", "m-sct"];
    let ratios = [1.0, 2.0, 4.0, 8.0, 16.0];
    let mem = 8u64 << 30;

    let mut t = Table::new(
        "Fig. 9 — m-ETF/m-SCT vs intra/inter island bandwidth ratio (4 devices, islands of 2)",
        &[
            "model",
            "placer",
            "ratio",
            "step (uniform)",
            "step (islands)",
            "step (flow)",
            "ops moved",
            "intra-island cut",
        ],
    );
    let mut msct_moved_at_gap = false;
    let mut json_rows: Vec<Json> = Vec::new();
    for b in benchmarks {
        let engine = PlacementEngine::builder()
            .cluster(Cluster::homogeneous(4, mem, inter))
            .build()
            .expect("engine");
        let graph = b.graph();
        for placer in placers {
            let base = engine
                .place(&PlacementRequest::for_benchmark(b, placer))
                .expect("uniform placement");
            let base_step = base.sim.as_ref().expect("sim").makespan;
            for ratio in ratios {
                let intra =
                    CommModel::new(inter.latency / ratio, inter.bandwidth * ratio)
                        .expect("intra model");
                let topo = Topology::nvlink_islands(4, 2, intra, inter).expect("topology");
                let resp = engine
                    .place(
                        &PlacementRequest::for_benchmark(b, placer)
                            .with_topology(topo.clone()),
                    )
                    .expect("islands placement");
                let moved = resp
                    .placement
                    .device_of
                    .iter()
                    .filter(|&(id, d)| base.placement.device_of.get(id) != Some(d))
                    .count();
                let (mut cut_intra, mut cut_cross) = (0u64, 0u64);
                for e in graph.edges() {
                    let ds = resp.placement.device_of[&e.src];
                    let dd = resp.placement.device_of[&e.dst];
                    if ds != dd {
                        if topo.is_cross_island(ds.0, dd.0) {
                            cut_cross += e.bytes;
                        } else {
                            cut_intra += e.bytes;
                        }
                    }
                }
                let cut = cut_intra + cut_cross;
                let intra_frac = if cut > 0 {
                    cut_intra as f64 / cut as f64
                } else {
                    1.0
                };
                if placer == "m-sct" && ratio >= 4.0 && moved > 0 {
                    msct_moved_at_gap = true;
                }
                let islands_step = resp.sim.as_ref().expect("sim").makespan;
                // Same placement, re-priced by the flow simulator:
                // concurrent transfers share each link max-min fairly
                // instead of queueing one at a time.
                let flow_cluster = Cluster::homogeneous(4, mem, inter)
                    .with_topology(topo.clone())
                    .expect("flow cluster")
                    .with_sequential_comm(false);
                let flow = simulate(
                    &graph,
                    &flow_cluster,
                    &resp.placement.device_of,
                    SimConfig::default(),
                );
                assert!(
                    flow.ok() && flow.makespan.is_finite() && flow.makespan > 0.0,
                    "flow-model re-simulation should run to completion"
                );
                let flow_step = flow.makespan;
                t.row(&[
                    b.name(),
                    placer.to_string(),
                    format!("{ratio}x"),
                    format!("{:.4}", base_step),
                    format!("{:.4}", islands_step),
                    format!("{:.4}", flow_step),
                    moved.to_string(),
                    format!("{:.0}%", intra_frac * 100.0),
                ]);
                let mut row = Json::obj();
                row.set("model", b.name())
                    .set("placer", placer)
                    .set("ratio", ratio)
                    .set("step_uniform_s", base_step)
                    .set("step_islands_s", islands_step)
                    .set("step_flow_s", flow_step)
                    .set("flow_blocked_fraction", flow.contention.blocked_fraction())
                    .set("ops_moved", moved)
                    .set("intra_island_cut_fraction", intra_frac);
                json_rows.push(row);
            }
        }
    }
    t.print();
    let mut summary = Json::obj();
    summary.set("msct_moved_at_gap", msct_moved_at_gap);
    maybe_write_json("fig9_topology_sensitivity", json_rows, Some(summary));
    assert!(
        msct_moved_at_gap,
        "m-SCT should re-place at a ≥4x inter-island bandwidth gap"
    );
    println!(
        "takeaway: a >=4x island bandwidth gap re-routes m-SCT's cut edges onto NVLink."
    );
}
