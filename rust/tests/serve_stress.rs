//! Concurrency stress tests for the `serve::PlacementService`: many
//! client threads hammer one service with a mix of repeated, mutated, and
//! fresh graphs across several placers, and every response must be
//! bit-identical to what a sequential `engine.place` produces on a fresh
//! engine. This pins the service's whole concurrent path — bounded queue,
//! worker pool, micro-batching, sharded cache — to the single-threaded
//! semantics.

use baechi::engine::{PlacementEngine, PlacementRequest};
use baechi::graph::delta::{mutate, MutationSpec};
use baechi::graph::{MemorySpec, NodeId, OpGraph, OpKind};
use baechi::models::Benchmark;
use baechi::profile::{Cluster, CommModel};
use baechi::serve::{PlacementService, ServeMode, ServiceConfig};
use baechi::util::rng::Pcg;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

fn stress_cluster() -> Cluster {
    Cluster::homogeneous(4, 1 << 30, CommModel::new(1e-5, 1e9).unwrap())
}

/// Small random layered DAG (a "fresh" request no cache can have seen).
fn fresh_dag(rng: &mut Pcg, tag: usize) -> OpGraph {
    let n = rng.range(6, 18);
    let mut g = OpGraph::new(&format!("fresh{tag}"));
    let mut ids: Vec<NodeId> = Vec::new();
    for i in 0..n {
        let id = g.add_node(&format!("f{tag}_op{i}"), OpKind::Generic(0));
        g.node_mut(id).compute = rng.uniform(0.2, 2.0);
        g.node_mut(id).mem = MemorySpec {
            params: rng.below(512) + 1,
            output: rng.below(256) + 1,
            ..Default::default()
        };
        g.node_mut(id).output_bytes = g.node(id).mem.output;
        if !ids.is_empty() {
            let p = *rng.choose(&ids);
            let bytes = g.node(id).mem.output;
            g.add_edge(p, id, bytes);
        }
        ids.push(id);
    }
    g
}

/// Deterministic workload: repeated, mutated, and fresh graphs.
fn graph_mix(seed: u64) -> Vec<OpGraph> {
    let mut rng = Pcg::seed(seed);
    let base = Benchmark::Mlp.graph();
    let mut current = base.clone();
    let mut out = Vec::new();
    for i in 0..12 {
        match i % 3 {
            0 => out.push(current.clone()), // repeat → cache hits
            1 => {
                let mut m = current.clone();
                mutate(&mut m, &mut rng, &MutationSpec::small());
                current = m.clone();
                out.push(m);
            }
            _ => out.push(fresh_dag(&mut rng, i)),
        }
    }
    out
}

#[test]
fn serve_stress_concurrent_responses_bit_identical_to_sequential() {
    const PLACERS: [&str; 3] = ["m-etf", "m-topo", "m-sct"];
    const CLIENTS: usize = 8;
    let graphs = graph_mix(0x5eed);

    // Sequential reference on a fresh engine with the identical cluster.
    let reference_engine = PlacementEngine::builder()
        .cluster(stress_cluster())
        .build()
        .unwrap();
    let mut reference: BTreeMap<(usize, &str), _> = BTreeMap::new();
    for (gi, g) in graphs.iter().enumerate() {
        for placer in PLACERS {
            let r = reference_engine
                .place(&PlacementRequest::new(g.clone(), placer))
                .unwrap();
            reference.insert((gi, placer), r);
        }
    }

    // The service under stress: incremental off so every response is
    // either the full pipeline or a cache hit of it — the modes that
    // promise bit-identity.
    let engine = Arc::new(
        PlacementEngine::builder()
            .cluster(stress_cluster())
            .build()
            .unwrap(),
    );
    let mut cfg = ServiceConfig::default();
    cfg.workers = 4;
    cfg.incremental.enabled = false;
    let service = PlacementService::new(engine, cfg).unwrap();

    let results: Mutex<Vec<((usize, &str), Arc<baechi::engine::PlacementResponse>)>> =
        Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let service = &service;
            let graphs = &graphs;
            let results = &results;
            s.spawn(move || {
                // Each client walks the workload in a different order so
                // hits and misses interleave across threads.
                for k in 0..graphs.len() * PLACERS.len() {
                    let j = (k + c * 5) % (graphs.len() * PLACERS.len());
                    let (gi, pi) = (j / PLACERS.len(), j % PLACERS.len());
                    let out = service
                        .place(PlacementRequest::new(graphs[gi].clone(), PLACERS[pi]))
                        .unwrap();
                    results.lock().unwrap().push(((gi, PLACERS[pi]), out.response));
                }
            });
        }
    });

    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), CLIENTS * graphs.len() * PLACERS.len());
    for (key, resp) in &results {
        let want = &reference[key];
        assert_eq!(
            resp.placement.device_of, want.placement.device_of,
            "{key:?}: concurrent placement diverged from sequential"
        );
        assert_eq!(
            resp.placement.predicted_makespan.to_bits(),
            want.placement.predicted_makespan.to_bits(),
            "{key:?}: predicted makespan not bit-identical"
        );
        let (a, b) = (resp.sim.as_ref().unwrap(), want.sim.as_ref().unwrap());
        assert_eq!(
            a.makespan.to_bits(),
            b.makespan.to_bits(),
            "{key:?}: simulated makespan not bit-identical"
        );
    }

    let m = service.metrics();
    assert_eq!(m.errors, 0);
    assert_eq!(m.completed, results.len() as u64);
    assert!(m.cache_hits > 0, "repeated requests must hit: {m:?}");
    assert_eq!(m.incremental, 0, "incremental path was disabled");
    assert_eq!(m.cache_hits + m.full, m.completed);
}

#[test]
fn serve_stress_incremental_stream_stays_valid_under_concurrency() {
    // With the incremental path on, bit-identity to a fresh engine no
    // longer holds (patched plans are a different, cheaper answer), but
    // every response must still cover all ops and simulate OOM-free, and
    // the mode counters must account for every completed request.
    let engine = Arc::new(
        PlacementEngine::builder()
            .cluster(stress_cluster())
            .build()
            .unwrap(),
    );
    let mut cfg = ServiceConfig::default();
    cfg.workers = 4;
    cfg.incremental.enabled = true;
    let service = PlacementService::new(engine, cfg).unwrap();

    let graphs = graph_mix(0xfeed);
    std::thread::scope(|s| {
        for c in 0..4usize {
            let service = &service;
            let graphs = &graphs;
            s.spawn(move || {
                for (gi, g) in graphs.iter().enumerate() {
                    let out = service
                        .place(PlacementRequest::new(g.clone(), "m-etf"))
                        .unwrap();
                    assert_eq!(
                        out.response.placement.device_of.len(),
                        g.len(),
                        "client {c} graph {gi}: incomplete coverage"
                    );
                    let sim = out.response.sim.as_ref().expect("service simulates");
                    assert!(sim.ok(), "client {c} graph {gi}: served plan OOMs");
                    if let ServeMode::Incremental { dirty_ops } = out.mode {
                        assert!(dirty_ops <= g.len());
                    }
                }
            });
        }
    });
    let m = service.metrics();
    assert_eq!(m.errors, 0);
    assert_eq!(m.completed, 4 * graphs.len() as u64);
    assert_eq!(m.cache_hits + m.incremental + m.full, m.completed);
}
