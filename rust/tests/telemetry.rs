//! Integration tests for the telemetry layer: span collection through
//! the engine and service, Chrome trace-event export (pipeline tracks +
//! simulated-plan tracks), and Prometheus metrics exposition.

use baechi::coordinator::{run_serve_bench, run_traced, BaechiConfig, PlacerKind, ServeBenchOpts};
use baechi::engine::{PlacementEngine, PlacementRequest};
use baechi::graph::{MemorySpec, OpGraph, OpKind};
use baechi::models::Benchmark;
use baechi::profile::{Cluster, CommModel};
use baechi::serve::{PlacementService, ServiceConfig};
use baechi::telemetry::prometheus::parse_text;
use baechi::telemetry::{MetricsServer, SpanRecord};
use baechi::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn unit_cluster(n: usize, mem: u64) -> Cluster {
    Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
}

fn traced_engine() -> PlacementEngine {
    PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 30))
        .tracing(true)
        .build()
        .unwrap()
}

const STAGES: [&str; 4] = ["optimize", "place", "expand", "simulate"];

fn spans_named<'a>(spans: &'a [SpanRecord], name: &str) -> Vec<&'a SpanRecord> {
    spans.iter().filter(|s| s.name == name).collect()
}

#[test]
fn tracing_disabled_engine_is_inert() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 30))
        .tracing(false)
        .build()
        .unwrap();
    assert!(!engine.tracer().is_live());
    let r = engine
        .place(&PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf"))
        .unwrap();
    assert!(r.sim.is_some());
    let stats = engine.tracer().stats();
    assert_eq!(stats.recorded, 0);
    assert_eq!(stats.dropped, 0);
    assert!(!stats.collecting);
    assert!(engine.tracer().drain().is_empty());
}

#[test]
fn stage_spans_nest_inside_request_span() {
    let engine = traced_engine();
    engine
        .place(&PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf"))
        .unwrap();
    let spans = engine.tracer().drain();
    let requests = spans_named(&spans, "request");
    assert_eq!(requests.len(), 1);
    let root = requests[0];
    for stage in STAGES {
        let found = spans_named(&spans, stage);
        assert_eq!(found.len(), 1, "exactly one {stage} span: {spans:?}");
        let s = found[0];
        assert_eq!(s.trace, root.trace, "{stage} shares the request trace");
        assert_eq!(s.parent, Some(root.span), "{stage} parented to request");
        assert!(s.start_s >= root.start_s - 1e-9, "{stage} starts inside request");
        assert!(s.end_s <= root.end_s + 1e-9, "{stage} ends inside request");
        assert!(s.end_s >= s.start_s, "{stage} well-formed interval");
        assert_eq!(s.detail, "m-etf");
    }
    assert!(spans_named(&spans, "cache_hit").is_empty());
}

#[test]
fn cache_hit_span_rides_its_own_request_span() {
    let engine = traced_engine();
    let req = PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf");
    engine.place(&req).unwrap();
    engine.tracer().drain();
    engine.place(&req).unwrap();
    let spans = engine.tracer().drain();
    let requests = spans_named(&spans, "request");
    assert_eq!(requests.len(), 1);
    let hits = spans_named(&spans, "cache_hit");
    assert_eq!(hits.len(), 1, "second place is a cache hit: {spans:?}");
    assert_eq!(hits[0].trace, requests[0].trace);
    assert_eq!(hits[0].parent, Some(requests[0].span));
    // The hit skipped the pipeline: no stage spans.
    for stage in STAGES {
        assert!(spans_named(&spans, stage).is_empty(), "no {stage} on a hit");
    }
}

#[test]
fn failed_placement_cancels_the_stage_span() {
    // 3 × 800-byte ops on a 2 × 1000-byte cluster: the placer must fail.
    let mut g = OpGraph::new("big");
    for i in 0..3 {
        let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
        g.node_mut(id).mem = MemorySpec {
            params: 800,
            ..Default::default()
        };
    }
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1000))
        .tracing(true)
        .build()
        .unwrap();
    assert!(engine.place(&PlacementRequest::new(g, "m-etf")).is_err());
    let spans = engine.tracer().drain();
    // The optimizer ran and the request envelope closed, but the failed
    // place stage (and everything after it) emitted nothing — observers
    // see the same silence they did pre-telemetry.
    assert_eq!(spans_named(&spans, "optimize").len(), 1);
    assert_eq!(spans_named(&spans, "request").len(), 1);
    assert!(spans_named(&spans, "place").is_empty());
    assert!(spans_named(&spans, "expand").is_empty());
    assert!(spans_named(&spans, "simulate").is_empty());
}

#[test]
fn service_stamps_trace_ids_and_books_queue_waits() {
    let engine = Arc::new(traced_engine());
    let mut scfg = ServiceConfig::default();
    scfg.workers = 2;
    let service = PlacementService::new(Arc::clone(&engine), scfg).unwrap();
    for _ in 0..3 {
        service
            .place(PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf"))
            .unwrap();
    }
    drop(service);
    let spans = engine.tracer().drain();
    let queued = spans_named(&spans, "queued");
    assert_eq!(queued.len(), 3, "one queue-wait span per request: {spans:?}");
    for q in &queued {
        assert_ne!(q.trace.0, 0, "intake minted a real trace id");
        assert!(q.end_s >= q.start_s);
    }
    // Every queued span's trace id connects to spans from the serving
    // path of the same request (request envelope or cache-hit lookup).
    for q in &queued {
        assert!(
            spans
                .iter()
                .any(|s| s.trace == q.trace && s.name != "queued"),
            "trace {:?} has serving-side spans",
            q.trace
        );
    }
    // Distinct requests got distinct trace ids.
    let mut ids: Vec<u64> = queued.iter().map(|q| q.trace.0).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3);
}

/// Pull the `ph:"X"` complete events of one pid out of an exported doc.
fn complete_events(doc: &Json, pid: u64) -> Vec<&Json> {
    doc.get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array")
        .iter()
        .filter(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("X")
                && e.get("pid").and_then(|p| p.as_u64()) == Some(pid)
        })
        .collect()
}

#[test]
fn serve_bench_trace_export_nests_every_stage_in_its_request() {
    let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
    let opts = ServeBenchOpts {
        requests: 16,
        clients: 2,
        mutation_rate: 0.4,
        workers: 2,
        trace: true,
        ..ServeBenchOpts::default()
    };
    let report = run_serve_bench(&cfg, &opts).unwrap();
    let doc = report.trace.as_ref().expect("trace requested");
    // The export is valid JSON end to end (what the CLI writes to disk).
    let parsed = Json::parse(&doc.pretty()).unwrap();
    let events = complete_events(&parsed, 1);
    assert!(!events.is_empty(), "pipeline track has events");

    let ev_trace = |e: &Json| e.get("args").and_then(|a| a.get("trace")).and_then(|t| t.as_u64());
    let ts = |e: &Json| e.get("ts").unwrap().as_f64().unwrap();
    let dur = |e: &Json| e.get("dur").unwrap().as_f64().unwrap();
    let mut stage_events = 0;
    for e in &events {
        let name = e.get("name").unwrap().as_str().unwrap();
        if !STAGES.contains(&name) {
            continue;
        }
        stage_events += 1;
        let trace = ev_trace(e).expect("stage events carry their trace id");
        let req = events
            .iter()
            .find(|r| {
                r.get("name").unwrap().as_str() == Some("request") && ev_trace(r) == Some(trace)
            })
            .unwrap_or_else(|| panic!("stage {name} (trace {trace}) has a request event"));
        // Nesting, in exported microseconds (0.5 µs rounding slack).
        assert!(ts(e) >= ts(req) - 0.5, "{name} starts inside its request");
        assert!(
            ts(e) + dur(e) <= ts(req) + dur(req) + 0.5,
            "{name} ends inside its request"
        );
    }
    assert!(stage_events > 0, "the stream ran full pipelines");
    // The service stamped queue waits into the same document.
    assert!(
        events
            .iter()
            .any(|e| e.get("name").unwrap().as_str() == Some("queued")),
        "queued spans exported"
    );
}

#[test]
fn run_traced_sim_track_reconstructs_the_simulated_makespan() {
    let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
    let (report, doc) = run_traced(&cfg).unwrap();
    assert!(report.sim.ok());
    // The recorded schedule reproduces the makespan to the exact bit.
    assert_eq!(
        report.sim.schedule.max_end().to_bits(),
        report.sim.makespan.to_bits(),
        "schedule max end {} vs makespan {}",
        report.sim.schedule.max_end(),
        report.sim.makespan
    );
    let parsed = Json::parse(&doc.pretty()).unwrap();
    // Pipeline track exists (the traced run collected spans) …
    assert!(!complete_events(&parsed, 1).is_empty());
    // … and the simulated-plan track's latest interval end equals the
    // makespan in exported microseconds.
    let sim_events = complete_events(&parsed, 2);
    assert!(!sim_events.is_empty(), "simulated plan track has events");
    let max_end_us = sim_events
        .iter()
        .map(|e| e.get("ts").unwrap().as_f64().unwrap() + e.get("dur").unwrap().as_f64().unwrap())
        .fold(0.0, f64::max);
    assert!(
        (max_end_us - report.sim.makespan * 1e6).abs() < 1e-3,
        "track max end {max_end_us} µs vs makespan {} µs",
        report.sim.makespan * 1e6
    );
    // Every simulated interval is well-formed and inside the step.
    for e in &sim_events {
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let dur = e.get("dur").unwrap().as_f64().unwrap();
        assert!(ts >= 0.0 && dur >= 0.0);
        assert!(ts + dur <= report.sim.makespan * 1e6 + 1e-3);
    }
}

#[test]
fn metrics_text_is_valid_prometheus_exposition() {
    let engine = Arc::new(traced_engine());
    let mut scfg = ServiceConfig::default();
    scfg.workers = 1;
    let service = PlacementService::new(Arc::clone(&engine), scfg).unwrap();
    let req = PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf");
    for _ in 0..3 {
        service.place(req.clone()).unwrap();
    }
    let text = service.metrics_text();
    let samples = parse_text(&text).unwrap_or_else(|e| panic!("must parse: {e}\n{text}"));
    let find = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing {name}"))
            .value
    };
    assert_eq!(find("baechi_requests_submitted_total"), 3.0);
    assert_eq!(find("baechi_requests_completed_total"), 3.0);
    assert_eq!(find("baechi_request_errors_total"), 0.0);
    assert_eq!(find("baechi_trace_collecting"), 1.0);
    assert!(find("baechi_trace_spans_recorded_total") > 0.0);
    // Mode-labelled family: the repeats hit the cache.
    let hit = samples
        .iter()
        .find(|s| {
            s.name == "baechi_served_total"
                && s.labels.iter().any(|(k, v)| k == "mode" && v == "cache_hit")
        })
        .expect("served_total{mode=cache_hit}");
    assert!(hit.value >= 1.0, "repeats must hit the cache: {text}");
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn metrics_server_scrapes_the_live_service() {
    let engine = Arc::new(traced_engine());
    let service =
        Arc::new(PlacementService::new(Arc::clone(&engine), ServiceConfig::default()).unwrap());
    let svc = Arc::clone(&service);
    let server = MetricsServer::bind("127.0.0.1:0", move || svc.metrics_text()).unwrap();
    service
        .place(PlacementRequest::new(Benchmark::LinReg.graph(), "m-etf"))
        .unwrap();
    let ok = http_get(server.addr(), "/metrics");
    assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
    assert!(ok.contains("version=0.0.4"), "content-type advertises 0.0.4");
    let body = ok.split("\r\n\r\n").nth(1).expect("body");
    let samples = parse_text(body).unwrap_or_else(|e| panic!("scrape must parse: {e}"));
    assert!(samples
        .iter()
        .any(|s| s.name == "baechi_requests_completed_total" && s.value == 1.0));
    let missing = http_get(server.addr(), "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
}
