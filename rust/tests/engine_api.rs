//! Integration tests for the `PlacementEngine` service API: registry
//! round-trips (including a custom placer registered by name), typed
//! `BaechiError` handling, cache hit/miss behavior, batched serving, and
//! stage observers.

use baechi::engine::{
    PlacementEngine, PlacementRequest, PlacerRegistration, RecordingObserver, Stage,
};
use baechi::graph::{DeviceId, MemorySpec, NodeId, OpGraph, OpKind};
use baechi::models::Benchmark;
use baechi::placer::{Placement, Placer};
use baechi::profile::{Cluster, CommModel};
use baechi::BaechiError;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn unit_cluster(n: usize, mem: u64) -> Cluster {
    Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
}

/// A graph that cannot fit the 2×1000-byte cluster (3 × 800-byte ops,
/// no edges, no groups — the optimizer leaves it untouched).
fn oom_graph() -> OpGraph {
    let mut g = OpGraph::new("big");
    for i in 0..3 {
        let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
        g.node_mut(id).mem = MemorySpec {
            params: 800,
            ..Default::default()
        };
    }
    g
}

/// Trivial custom placer: round-robin by node index, counting every
/// invocation so tests can prove the cache skipped it.
struct CountingRoundRobin {
    calls: Arc<AtomicUsize>,
}

impl Placer for CountingRoundRobin {
    fn name(&self) -> String {
        "round-robin".to_string()
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> baechi::Result<Placement> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        let t0 = std::time::Instant::now();
        let device_of: BTreeMap<NodeId, DeviceId> = graph
            .node_ids()
            .enumerate()
            .map(|(k, id)| (id, DeviceId(k % cluster.n())))
            .collect();
        Ok(Placement {
            algorithm: self.name(),
            predicted_makespan: graph.total_compute(),
            placement_time: t0.elapsed().as_secs_f64(),
            peak_memory: vec![0; cluster.n()],
            device_of,
        })
    }
}

#[test]
fn registry_round_trip_register_resolve_place() {
    let calls = Arc::new(AtomicUsize::new(0));
    let factory_calls = calls.clone();
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .register_placer(
            "round-robin",
            PlacerRegistration::new(move |_| {
                Ok(Box::new(CountingRoundRobin {
                    calls: factory_calls.clone(),
                }))
            }),
        )
        .build()
        .unwrap();

    assert!(engine.registry().contains("round-robin"));
    assert!(engine.registry().contains("m-sct"), "builtins still there");

    let g = baechi::models::linreg::linreg_graph();
    let n_ops = g.len();
    let resp = engine
        .place(&PlacementRequest::new(g, "round-robin").without_simulation())
        .unwrap();
    assert_eq!(resp.placer, "round-robin");
    assert_eq!(resp.placement.device_of.len(), n_ops, "expanded coverage");
    assert_eq!(calls.load(Ordering::SeqCst), 1);
}

#[test]
fn typed_oom_error_carries_deficit() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1000))
        .build()
        .unwrap();
    match engine.place(&PlacementRequest::new(oom_graph(), "m-etf")) {
        Err(BaechiError::Oom {
            op,
            best_device,
            deficit,
        }) => {
            assert!(op.starts_with("op"), "failing op name, got '{op}'");
            assert!(best_device.is_some(), "closest device reported");
            // Both devices hold one 800-byte op; the third needs 800
            // against 200 free.
            assert_eq!(deficit, 600);
        }
        Ok(_) => panic!("2400 bytes cannot fit a 2000-byte cluster"),
        Err(e) => panic!("expected Oom, got {e}"),
    }
    // The typed error still renders the paper's phrasing.
    let err = engine
        .place(&PlacementRequest::new(oom_graph(), "m-etf"))
        .unwrap_err();
    assert!(err.to_string().contains("out of memory"), "{err}");
}

#[test]
fn typed_unknown_placer_error() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .build()
        .unwrap();
    let g = baechi::models::linreg::linreg_graph();
    match engine.place(&PlacementRequest::new(g, "placeto")) {
        Err(BaechiError::UnknownPlacer { name, known }) => {
            assert_eq!(name, "placeto");
            assert!(known.contains(&"m-sct".to_string()));
            assert!(known.contains(&"single".to_string()));
        }
        Ok(_) => panic!("'placeto' is not registered"),
        Err(e) => panic!("expected UnknownPlacer, got {e}"),
    }
}

#[test]
fn cache_hit_returns_same_placement_without_rerunning() {
    let calls = Arc::new(AtomicUsize::new(0));
    let factory_calls = calls.clone();
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .register_placer(
            "counting",
            PlacerRegistration::new(move |_| {
                Ok(Box::new(CountingRoundRobin {
                    calls: factory_calls.clone(),
                }))
            }),
        )
        .build()
        .unwrap();

    let req = PlacementRequest::new(baechi::models::linreg::linreg_graph(), "counting");
    let first = engine.place(&req).unwrap();
    let second = engine.place(&req).unwrap();
    assert!(Arc::ptr_eq(&first, &second), "cached Arc re-served");
    assert_eq!(first.placement.device_of, second.placement.device_of);
    assert_eq!(
        calls.load(Ordering::SeqCst),
        1,
        "placer must not re-run on a cache hit"
    );
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
}

#[test]
fn cache_distinguishes_graph_changes() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .build()
        .unwrap();
    let g1 = baechi::models::linreg::linreg_graph();
    let mut g2 = baechi::models::linreg::linreg_graph();
    // Perturb one profile value: must be a distinct cache entry.
    let id = g2.node_ids().next().unwrap();
    g2.node_mut(id).compute += 1.0;
    engine.place(&PlacementRequest::new(g1, "m-etf")).unwrap();
    engine.place(&PlacementRequest::new(g2, "m-etf")).unwrap();
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (0, 2));
    assert_eq!(engine.cache_len(), 2);
}

#[test]
fn place_batch_matches_sequential() {
    let specs = ["m-topo", "m-etf", "m-sct", "single"];
    let mk_reqs = || -> Vec<PlacementRequest> {
        specs
            .iter()
            .map(|p| PlacementRequest::for_benchmark(Benchmark::Mlp, p).without_simulation())
            .collect()
    };

    let batch_engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 30))
        .build()
        .unwrap();
    let batch = batch_engine.place_batch(&mk_reqs());

    let seq_engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 30))
        .build()
        .unwrap();
    for (spec, b) in specs.iter().zip(batch) {
        let b = b.unwrap_or_else(|e| panic!("{spec} in batch: {e}"));
        let s = seq_engine
            .place(&PlacementRequest::for_benchmark(Benchmark::Mlp, spec).without_simulation())
            .unwrap_or_else(|e| panic!("{spec} sequential: {e}"));
        assert_eq!(
            b.placement.device_of, s.placement.device_of,
            "{spec}: batch and sequential placements must agree"
        );
    }
}

/// Acceptance scenario: a custom placer registered by name serves a
/// cached batch of ≥3 requests, with typed-error handling for an
/// OOM-inducing request in the same batch.
#[test]
fn serves_cached_batch_with_typed_oom_handling() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1000))
        .register_placer(
            "round-robin",
            PlacerRegistration::new(|_| {
                Ok(Box::new(CountingRoundRobin {
                    calls: Arc::new(AtomicUsize::new(0)),
                }))
            }),
        )
        .build()
        .unwrap();

    let small = || {
        let mut g = OpGraph::new("small");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        for id in [a, b] {
            g.node_mut(id).mem = MemorySpec {
                params: 100,
                ..Default::default()
            };
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, b, 10);
        g
    };

    // Warm the cache with the first request.
    let warm_req = PlacementRequest::new(small(), "m-etf").without_simulation();
    let warm = engine.place(&warm_req).unwrap();

    let reqs = vec![
        warm_req.clone(),
        PlacementRequest::new(small(), "round-robin").without_simulation(),
        PlacementRequest::new(small(), "m-topo").without_simulation(),
        // OOM-inducing member of the same batch.
        PlacementRequest::new(oom_graph(), "m-etf").without_simulation(),
    ];
    let results = engine.place_batch(&reqs);
    assert_eq!(results.len(), 4);

    // Request 0 is served from the cache (same Arc as the warm-up).
    let r0 = results[0].as_ref().unwrap();
    assert!(Arc::ptr_eq(r0, &warm), "batch must reuse the cached response");

    // Requests 1–2 succeed with full coverage.
    for r in &results[1..3] {
        let r = r.as_ref().unwrap();
        assert_eq!(r.placement.device_of.len(), 2);
    }

    // Request 3 fails with the typed OOM, not a stringly error.
    match &results[3] {
        Err(BaechiError::Oom { op, deficit, .. }) => {
            assert!(op.starts_with("op"));
            assert!(*deficit > 0);
        }
        Err(e) => panic!("expected Oom, got {e}"),
        Ok(_) => panic!("oversized graph placed unexpectedly"),
    }

    let stats = engine.cache_stats();
    assert!(stats.hits >= 1, "cached batch member must hit: {stats:?}");
}

#[test]
fn cache_distinguishes_topology() {
    use baechi::topology::Topology;
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(4, 1 << 20))
        .build()
        .unwrap();
    let islands = Topology::nvlink_islands(
        4,
        2,
        CommModel::nvlink_like(),
        CommModel::pcie_via_host(),
    )
    .unwrap();
    let g = baechi::models::linreg::linreg_graph();

    // Two requests differing only in topology must both miss.
    let r_uniform = engine
        .place(&PlacementRequest::new(g.clone(), "m-etf"))
        .unwrap();
    let r_islands = engine
        .place(&PlacementRequest::new(g.clone(), "m-etf").with_topology(islands.clone()))
        .unwrap();
    assert!(
        !Arc::ptr_eq(&r_uniform, &r_islands),
        "topology must be part of the cache key"
    );
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (0, 2));

    // The same topology again must hit.
    let r_again = engine
        .place(&PlacementRequest::new(g, "m-etf").with_topology(islands))
        .unwrap();
    assert!(Arc::ptr_eq(&r_islands, &r_again), "same topology must hit");
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (1, 2));

    // An override identical to the engine's own topology is served from
    // the plain request's entry — no redundant placer run.
    let same = Topology::uniform(4, CommModel::new(0.0, 1.0).unwrap());
    let r_same = engine
        .place(
            &PlacementRequest::new(baechi::models::linreg::linreg_graph(), "m-etf")
                .with_topology(same),
        )
        .unwrap();
    assert!(
        Arc::ptr_eq(&r_uniform, &r_same),
        "no-op override must share the cache entry"
    );
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (2, 2));
}

#[test]
fn topology_override_with_wrong_device_count_is_typed() {
    use baechi::topology::Topology;
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(4, 1 << 20))
        .build()
        .unwrap();
    let g = baechi::models::linreg::linreg_graph();
    let two_dev = Topology::uniform(2, CommModel::pcie_via_host());
    match engine.place(&PlacementRequest::new(g, "m-etf").with_topology(two_dev)) {
        Err(BaechiError::InvalidRequest(msg)) => {
            assert!(msg.contains("devices"), "{msg}")
        }
        Ok(_) => panic!("2-device topology on a 4-device engine must fail"),
        Err(e) => panic!("expected InvalidRequest, got {e}"),
    }
}

#[test]
fn observer_sees_all_stages_in_order() {
    let obs = RecordingObserver::new();
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .observer(obs.clone())
        .build()
        .unwrap();
    engine
        .place(&PlacementRequest::new(
            baechi::models::linreg::linreg_graph(),
            "m-etf",
        ))
        .unwrap();
    let stages: Vec<Stage> = obs.events().iter().map(|(s, _)| *s).collect();
    assert_eq!(
        stages,
        vec![Stage::Optimize, Stage::Place, Stage::Expand, Stage::Simulate]
    );
    for (_, st) in obs.events() {
        assert!(st.duration >= 0.0);
        assert_eq!(st.placer, "m-etf");
        assert!(st.ops_in > 0);
    }
    // A cache hit re-runs no pipeline stage; it emits a single
    // `cache_hit` event instead.
    engine
        .place(&PlacementRequest::new(
            baechi::models::linreg::linreg_graph(),
            "m-etf",
        ))
        .unwrap();
    let events = obs.events();
    assert_eq!(events.len(), 5, "hit adds exactly one event");
    let (stage, st) = &events[4];
    assert_eq!(*stage, Stage::CacheHit);
    assert_eq!(st.placer, "m-etf");
    assert!(st.duration >= 0.0);
    assert_eq!(st.ops_in, st.ops_out, "hit reports the served plan size");
    assert!(st.ops_out > 0);
}

#[test]
fn lookup_peeks_without_counting_misses() {
    let obs = RecordingObserver::new();
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .observer(obs.clone())
        .build()
        .unwrap();
    let req = PlacementRequest::new(baechi::models::linreg::linreg_graph(), "m-etf");

    // Unknown request: lookup returns None and counts nothing.
    assert!(engine.lookup(&req).unwrap().is_none());
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (0, 0), "peek never counts a miss");
    assert!(obs.events().is_empty(), "a lookup miss emits no event");

    // After one real placement, lookup hits and emits Stage::CacheHit.
    let placed = engine.place(&req).unwrap();
    let events_after_place = obs.events().len();
    let hit = engine.lookup(&req).unwrap().expect("warm entry must hit");
    assert!(Arc::ptr_eq(&placed, &hit));
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
    let events = obs.events();
    assert_eq!(events.len(), events_after_place + 1);
    assert_eq!(events.last().unwrap().0, Stage::CacheHit);
}

/// Regression for the bounded cache: with a capacity of ~2 entries and a
/// single shard, a third distinct graph must evict the least recently
/// used entry, counters must stay consistent with the request count, and
/// re-placing an evicted graph must miss (and re-run the pipeline).
#[test]
fn bounded_cache_evicts_lru_and_keeps_counters_consistent() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(2, 1 << 20))
        .cache_shards(1)
        // Entry cost is ops + 1 = 3 for the 2-op graphs below, so
        // capacity 7 holds two entries but not three.
        .cache_capacity(7)
        .build()
        .unwrap();

    let mk = |name: &str| {
        let mut g = OpGraph::new(name);
        let a = g.add_node(&format!("{name}_a"), OpKind::MatMul);
        let b = g.add_node(&format!("{name}_b"), OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 1.0;
        g.add_edge(a, b, 8);
        g
    };
    let req = |name: &str| PlacementRequest::new(mk(name), "m-etf").without_simulation();

    engine.place(&req("g1")).unwrap(); // miss → {g1}
    engine.place(&req("g2")).unwrap(); // miss → {g1, g2}
    engine.place(&req("g1")).unwrap(); // hit, g1 now most recent
    engine.place(&req("g3")).unwrap(); // miss, evicts LRU g2 → {g1, g3}
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses, stats.evictions), (1, 3, 1));
    assert_eq!(engine.cache_len(), 2);

    engine.place(&req("g2")).unwrap(); // miss again: g2 was evicted
    engine.place(&req("g3")).unwrap(); // hit: g3 survived the g2 re-insert
    let stats = engine.cache_stats();
    assert_eq!((stats.hits, stats.misses, stats.evictions), (2, 4, 2));
    assert_eq!(engine.cache_len(), 2);
    assert_eq!(stats.hits + stats.misses, 6, "every request counted once");
}

#[test]
fn expert_benchmark_flows_through_requests() {
    let engine = PlacementEngine::builder()
        .cluster(unit_cluster(4, 64 << 30))
        .build()
        .unwrap();
    // for_benchmark carries the identity the expert needs.
    let ok = engine.place(&PlacementRequest::for_benchmark(
        Benchmark::Transformer { batch: 8 },
        "expert",
    ));
    assert!(ok.is_ok(), "{:?}", ok.err());
    // A bare graph request without the identity is a typed error.
    let g = Benchmark::Transformer { batch: 8 }.graph();
    match engine.place(&PlacementRequest::new(g, "expert")) {
        Err(BaechiError::InvalidRequest(msg)) => {
            assert!(msg.contains("benchmark"), "{msg}")
        }
        Ok(_) => panic!("expert without benchmark must fail"),
        Err(e) => panic!("expected InvalidRequest, got {e}"),
    }
}

/// Fan-out graph that saturates a two-tier NIC trunk: one source feeds
/// `width` chains with `bytes`-sized tensors.
fn fanout_graph(width: usize, bytes: u64) -> OpGraph {
    let mut g = OpGraph::new("fanout");
    let src = g.add_node("src", OpKind::MatMul);
    g.node_mut(src).compute = 0.3;
    g.node_mut(src).mem.output = bytes;
    g.node_mut(src).output_bytes = bytes;
    for c in 0..width {
        let head = g.add_node(&format!("h{c}"), OpKind::MatMul);
        g.node_mut(head).compute = 0.3;
        g.node_mut(head).mem.output = bytes;
        g.node_mut(head).output_bytes = bytes;
        let tail = g.add_node(&format!("t{c}"), OpKind::MatMul);
        g.node_mut(tail).compute = 0.3;
        g.add_edge(src, head, bytes);
        g.add_edge(head, tail, bytes);
    }
    g
}

/// 2 machines × 2 devices with a slow shared NIC trunk.
fn contended_engine() -> PlacementEngine {
    use baechi::topology::Topology;
    let intra = CommModel::new(1e-5, 10e9).unwrap();
    let inter = CommModel::new(1e-4, 625e6).unwrap();
    PlacementEngine::builder()
        .cluster(
            Cluster::homogeneous(4, 32 << 30, inter)
                .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
                .unwrap(),
        )
        .build()
        .unwrap()
}

#[test]
fn iterative_with_zero_rounds_is_exactly_place() {
    use baechi::feedback::ReplacementPolicy;
    let engine = contended_engine();
    let req = PlacementRequest::new(fanout_graph(8, 256 << 20), "m-etf");
    let it = engine
        .place_iterative(&req, &ReplacementPolicy::rounds(0))
        .unwrap();
    let plain = engine.place(&req).unwrap();
    assert!(
        Arc::ptr_eq(&it.response, &plain),
        "0 rounds must serve the same cached response as place()"
    );
    assert!(it.rounds.is_empty());
    let plain_makespan = plain.sim.as_ref().unwrap().makespan;
    assert_eq!(it.baseline_makespan.to_bits(), plain_makespan.to_bits());
}

#[test]
fn iterative_records_rounds_and_never_regresses() {
    use baechi::feedback::ReplacementPolicy;
    let engine = contended_engine();
    let req = PlacementRequest::new(fanout_graph(8, 256 << 20), "m-etf");
    let policy = ReplacementPolicy::rounds(3).with_threshold(0.3);
    let it = engine.place_iterative(&req, &policy).unwrap();
    assert!(!it.rounds.is_empty());
    assert_eq!(it.rounds[0].round, 0);
    assert_eq!(it.rounds[0].makespan.to_bits(), it.baseline_makespan.to_bits());
    assert!(!it.rounds[0].improved, "round 0 is the baseline");
    // Best-of-rounds cannot be worse than single-shot.
    assert!(it.final_makespan() <= it.baseline_makespan + 1e-9);
    assert!(it.improvement() >= 0.0);
    // The returned response was judged on the real topology.
    let sim = it.response.sim.as_ref().expect("iterative simulates");
    assert!(sim.ok());
}

#[test]
fn iterative_on_parallel_comm_cluster_sees_flow_contention() {
    // Regression: before the flow simulator, a parallel-comm cluster
    // produced an all-zero ContentionReport, so place_iterative
    // silently returned the single-shot placement as "best of N".
    //
    // The scenario is built so contention is certain: device 0 is alone
    // on one side of a thin trunk, devices 1 and 2 on the other, and a
    // source fans out to three equal heads. ETF puts the source and one
    // head on device 0 and one head on each remote device, so the two
    // cross-trunk transfers leave simultaneously when the source
    // completes and must share the trunk below their pair-model rate.
    use baechi::feedback::ReplacementPolicy;
    use baechi::topology::{Link, LinkKind, Topology};
    let spoke = CommModel::new(0.0, 1e9).unwrap();
    let trunk = CommModel::new(0.0, 1e6).unwrap();
    let links = vec![
        Link { a: 0, b: 3, kind: LinkKind::Nic, comm: spoke },
        Link { a: 3, b: 4, kind: LinkKind::Nic, comm: trunk },
        Link { a: 1, b: 4, kind: LinkKind::Nic, comm: spoke },
        Link { a: 2, b: 4, kind: LinkKind::Nic, comm: spoke },
    ];
    let topo = Topology::from_links(3, 2, links, Some(vec![0, 1, 1]), None).unwrap();
    let engine = PlacementEngine::builder()
        .cluster(
            Cluster::homogeneous(3, 1 << 30, trunk)
                .with_topology(topo)
                .unwrap()
                .with_sequential_comm(false),
        )
        .build()
        .unwrap();
    // ~5 s per cross-trunk transfer vs 10 s of compute per op:
    // spreading wins at placement time, sharing bites at sim time.
    let mut g = OpGraph::new("trunkfan");
    let src = g.add_node("src", OpKind::MatMul);
    g.node_mut(src).compute = 10.0;
    g.node_mut(src).mem.output = 5_000_000;
    g.node_mut(src).output_bytes = 5_000_000;
    for i in 0..3 {
        let h = g.add_node(&format!("h{i}"), OpKind::MatMul);
        g.node_mut(h).compute = 10.0;
        g.add_edge(src, h, 5_000_000);
    }
    let req = PlacementRequest::new(g, "m-etf");
    let policy = ReplacementPolicy::rounds(3).with_threshold(0.01);
    let it = engine.place_iterative(&req, &policy).unwrap();
    // The report is populated: flows book busy link-seconds.
    let sim = it.response.sim.as_ref().expect("iterative simulates");
    assert!(sim.ok());
    assert!(
        sim.contention.busy_seconds > 0.0,
        "parallel-comm contention report must not be empty"
    );
    assert!(it.rounds[0].max_utilization > 0.0);
    assert!(
        it.rounds[0].blocked_fraction > 0.0,
        "concurrent cross-trunk flows must register slowdown"
    );
    // The trigger fires on the flow-level signal, so the loop actually
    // iterates instead of degenerating to round 0.
    assert!(
        it.rounds.len() > 1,
        "loop must run adjustment rounds, got {:?}",
        it.rounds
    );
    assert!(it.final_makespan() <= it.baseline_makespan + 1e-9);
}

#[test]
fn iterative_rounds_hit_cache_on_repeated_topologies() {
    use baechi::feedback::ReplacementPolicy;
    let engine = contended_engine();
    let req = PlacementRequest::new(fanout_graph(8, 256 << 20), "m-etf");
    let policy = ReplacementPolicy::rounds(3).with_threshold(0.3);
    let first = engine.place_iterative(&req, &policy).unwrap();
    let misses_after_first = engine.cache_stats().misses;
    let hits_after_first = engine.cache_stats().hits;
    // The loop is deterministic: round r re-derives the same adjusted
    // topology, so repeating the call re-runs no placer at all.
    let second = engine.place_iterative(&req, &policy).unwrap();
    let stats = engine.cache_stats();
    assert_eq!(
        stats.misses,
        misses_after_first,
        "repeated iterative placement must be served from the cache"
    );
    assert_eq!(
        stats.hits,
        hits_after_first + first.rounds.len() as u64,
        "one hit per round (baseline + each adjusted topology)"
    );
    assert_eq!(first.rounds, second.rounds);
    assert_eq!(first.final_makespan().to_bits(), second.final_makespan().to_bits());
}

#[test]
fn iterative_measured_zero_rounds_is_exactly_place() {
    use baechi::calibrate::measured_report;
    use baechi::feedback::ReplacementPolicy;
    let engine = contended_engine();
    let topo = engine.cluster().effective_topology().into_owned();
    let req = PlacementRequest::new(fanout_graph(8, 256 << 20), "m-etf");
    let report = measured_report(&topo, 1.0, &[]).unwrap();
    let it = engine
        .place_iterative_measured(&req, &ReplacementPolicy::rounds(0), &report)
        .unwrap();
    let plain = engine.place(&req).unwrap();
    assert!(
        Arc::ptr_eq(&it.response, &plain),
        "0 rounds + measured report must still be bit-identical to place()"
    );
    assert!(it.rounds.is_empty());
}

#[test]
fn iterative_measured_drives_the_loop_from_the_supplied_report() {
    use baechi::calibrate::{measured_report, LinkObservation};
    use baechi::feedback::ReplacementPolicy;
    let engine = contended_engine();
    let topo = engine.cluster().effective_topology().into_owned();
    let req = PlacementRequest::new(fanout_graph(8, 256 << 20), "m-etf");
    let policy = ReplacementPolicy::rounds(3).with_threshold(0.3);

    // A quiet measured report: nothing saturated on the real cluster,
    // so the loop must not trigger even if the simulator would have.
    let quiet = measured_report(&topo, 10.0, &[]).unwrap();
    let it = engine
        .place_iterative_measured(&req, &policy, &quiet)
        .unwrap();
    assert_eq!(it.rounds.len(), 1, "quiet measurement → baseline only");
    assert!(!it.rounds[0].improved);
    assert_eq!(it.rounds[0].max_utilization, 0.0, "round 0 reflects the measurement");

    // A hot measured report: every transfer queued on the trunk links of
    // the (0,2) path. Round 0's stats must mirror the measurement and
    // the loop must run, never regressing vs single-shot.
    let step = 10.0;
    let obs: Vec<LinkObservation> = topo
        .path(0, 2)
        .iter()
        .map(|&link| LinkObservation {
            link,
            busy: 0.9 * step,
            blocked: 2.0 * step,
            transfers: 8,
            bytes: 256 << 20,
        })
        .collect();
    let hot = measured_report(&topo, step, &obs).unwrap();
    let it = engine.place_iterative_measured(&req, &policy, &hot).unwrap();
    assert!(
        it.rounds.len() > 1,
        "saturated measurement must trigger re-placement: {:?}",
        it.rounds
    );
    assert!((it.rounds[0].max_utilization - 0.9).abs() < 1e-9);
    assert!(!it.rounds[0].saturated_links.is_empty());
    assert!(it.final_makespan() <= it.baseline_makespan + 1e-9, "never regresses");
}

#[test]
fn iterative_measured_rejects_mismatched_report() {
    use baechi::calibrate::measured_report;
    use baechi::feedback::ReplacementPolicy;
    use baechi::topology::Topology;
    let engine = contended_engine();
    let req = PlacementRequest::new(fanout_graph(4, 1 << 20), "m-etf");
    // Report recorded against a different (2-device uniform) cluster.
    let other = Topology::uniform(2, CommModel::new(0.0, 1e9).unwrap());
    let report = measured_report(&other, 1.0, &[]).unwrap();
    match engine.place_iterative_measured(&req, &ReplacementPolicy::rounds(2), &report) {
        Err(BaechiError::InvalidRequest(msg)) => {
            assert!(msg.contains("links"), "{msg}")
        }
        other => panic!("expected InvalidRequest, got {other:?}"),
    }
}
