//! Integration tests over the full coordinator pipeline: every benchmark
//! × every placer × both memory regimes, checking the paper's
//! qualitative claims end to end.

use baechi::coordinator::{run, BaechiConfig, PlacerKind};
use baechi::models::Benchmark;
use baechi::optimizer::{expand_placement, optimize, OptConfig};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, Framework, SimConfig};

const ALL_PLACERS: [PlacerKind; 5] = [
    PlacerKind::Single,
    PlacerKind::Expert,
    PlacerKind::MTopo,
    PlacerKind::MEtf,
    PlacerKind::MSct,
];

fn small_benchmarks() -> Vec<Benchmark> {
    vec![
        Benchmark::Transformer { batch: 64 },
        Benchmark::InceptionV3 { batch: 32 },
        Benchmark::Mlp,
        Benchmark::LinReg,
    ]
}

#[test]
fn sufficient_memory_all_place_and_run() {
    for b in small_benchmarks() {
        for placer in ALL_PLACERS {
            let cfg = BaechiConfig::paper_default(b, placer);
            let r = run(&cfg).unwrap_or_else(|e| panic!("{placer:?} on {}: {e}", b.name()));
            assert!(
                r.sim.ok(),
                "{placer:?} on {} OOM: {:?}",
                b.name(),
                r.sim.oom
            );
            assert!(r.sim.makespan > 0.0);
            assert!(r.placement_time >= 0.0);
        }
    }
}

#[test]
fn gnmt_table4_ordering() {
    // The paper's qualitative Table-4 ordering on GNMT:
    // m-ETF and m-SCT beat single GPU; m-TOPO is the slowest algorithmic
    // placer; m-ETF within a modest factor of the expert.
    let b = Benchmark::Gnmt {
        batch: 128,
        seq_len: 40,
    };
    let step = |placer| {
        run(&BaechiConfig::paper_default(b, placer))
            .unwrap()
            .step_time()
            .expect("no OOM at full memory")
    };
    let single = step(PlacerKind::Single);
    let expert = step(PlacerKind::Expert);
    let mtopo = step(PlacerKind::MTopo);
    let metf = step(PlacerKind::MEtf);
    let msct = step(PlacerKind::MSct);
    assert!(metf < single, "m-etf {metf} !< single {single}");
    assert!(msct < single, "m-sct {msct} !< single {single}");
    assert!(mtopo > metf, "m-topo {mtopo} !> m-etf {metf}");
    assert!(
        metf < expert * 1.5,
        "m-etf {metf} not in the expert's ballpark {expert}"
    );
}

#[test]
fn inception_insufficient_memory_table5() {
    // Table 5 row: Inception bs32 at 30% — single and expert OOM; all
    // three m-* placers succeed.
    let b = Benchmark::InceptionV3 { batch: 32 };
    let fraction = 0.3;
    for placer in [PlacerKind::Single, PlacerKind::Expert] {
        let r = run(&BaechiConfig::paper_default(b, placer).with_memory_fraction(fraction))
            .unwrap();
        assert!(!r.sim.ok(), "{placer:?} should OOM at 30%");
    }
    for placer in [PlacerKind::MTopo, PlacerKind::MEtf, PlacerKind::MSct] {
        let r = run(&BaechiConfig::paper_default(b, placer).with_memory_fraction(fraction))
            .unwrap_or_else(|e| panic!("{placer:?} placement failed: {e}"));
        assert!(r.sim.ok(), "{placer:?} OOM at 30%: {:?}", r.sim.oom);
        assert!(r.devices_used >= 2, "{placer:?} must split the model");
        // Peak memory within the cap on every device.
        for (i, &p) in r.peak_memory.iter().enumerate() {
            assert!(p <= r.device_capacity, "gpu{i} over cap");
        }
    }
}

#[test]
fn optimizer_ablation_table6_direction() {
    // Optimized placement must be faster to compute and give a step time
    // at least as good (Table 6 direction).
    let b = Benchmark::Gnmt {
        batch: 128,
        seq_len: 40,
    };
    let unopt =
        run(&BaechiConfig::paper_default(b, PlacerKind::MSct).with_opt(OptConfig::none()))
            .unwrap();
    let opt = run(&BaechiConfig::paper_default(b, PlacerKind::MSct)).unwrap();
    assert!(opt.placed_ops * 5 < unopt.placed_ops);
    assert!(opt.placement_time < unopt.placement_time);
    let (su, so) = (
        unopt.step_time().unwrap_or(f64::INFINITY),
        opt.step_time().unwrap(),
    );
    assert!(so <= su * 1.05, "optimized step {so} worse than unopt {su}");
}

#[test]
fn comm_protocol_table7_direction() {
    // Overlapped comm never loses to blocking comm.
    for b in [
        Benchmark::InceptionV3 { batch: 32 },
        Benchmark::Transformer { batch: 64 },
    ] {
        let base = BaechiConfig::paper_default(b, PlacerKind::MEtf).with_memory_fraction(0.4);
        let mut blocking = base.clone();
        blocking.sim = SimConfig {
            overlap_comm: false,
            ..base.sim
        };
        let with = run(&base).unwrap();
        let without = run(&blocking).unwrap();
        if let (Some(w), Some(wo)) = (with.step_time(), without.step_time()) {
            assert!(w <= wo * 1.001, "overlap {w} worse than blocking {wo}");
        }
    }
}

#[test]
fn frameworks_memory_semantics_differ() {
    // PyTorch semantics (outputs held until backward) peak ≥ TF semantics.
    let b = Benchmark::Transformer { batch: 64 };
    let graph = b.graph();
    let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
    let opt = optimize(&graph, &OptConfig::default());
    let p = PlacerKind::MEtf
        .build(b)
        .place(&opt.graph, &cluster)
        .unwrap();
    let full = expand_placement(&graph, &opt, &p.device_of);
    let tf = simulate(&graph, &cluster, &full, SimConfig::default());
    let pt = simulate(
        &graph,
        &cluster,
        &full,
        SimConfig {
            framework: Framework::PyTorch,
            ..Default::default()
        },
    );
    assert!(tf.ok() && pt.ok());
    let tf_total: u64 = tf.peak_memory.iter().sum();
    let pt_total: u64 = pt.peak_memory.iter().sum();
    assert!(pt_total >= tf_total, "pytorch {pt_total} < tf {tf_total}");
}

#[test]
fn rl_baseline_finds_feasible_but_pays_steps() {
    let b = Benchmark::Transformer { batch: 64 };
    let cfg = BaechiConfig::paper_default(b, PlacerKind::Rl { episodes: 60 });
    let r = run(&cfg).unwrap();
    assert!(r.sim.ok());
    // The RL placer's cost is dominated by step evaluations: its
    // placement_time must exceed m-ETF's by a wide margin (Table 3's
    // orders-of-magnitude gap, shrunk to a 60-episode budget).
    let metf = run(&BaechiConfig::paper_default(b, PlacerKind::MEtf)).unwrap();
    assert!(
        r.placement_time > metf.placement_time * 3.0,
        "rl {} vs m-etf {}",
        r.placement_time,
        metf.placement_time
    );
}

#[test]
fn nvlink_ablation_helps_msct() {
    // Footnote 4: faster interconnect shrinks m-SCT's gap (ρ drops).
    let b = Benchmark::Gnmt {
        batch: 128,
        seq_len: 40,
    };
    let slow = BaechiConfig::paper_default(b, PlacerKind::MSct);
    let mut fast = slow.clone();
    fast.comm = CommModel::nvlink_like();
    let s = run(&slow).unwrap().step_time().unwrap();
    let f = run(&fast).unwrap().step_time().unwrap();
    assert!(f < s, "nvlink {f} not faster than pcie {s}");
}
