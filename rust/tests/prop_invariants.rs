//! Property-based invariants over random DAGs (DESIGN.md §7), via the
//! in-repo `util::prop` harness (proptest substitute).
//!
//! Each property draws a random layered DAG with random costs/memory and
//! asserts structural invariants of the optimizer, placers, simulator,
//! LP solver, and the Appendix A/B bound proxies.

use baechi::graph::{MemorySpec, NodeId, OpGraph, OpKind};
use baechi::optimizer::{optimize, OptConfig};
use baechi::placer::{metf::MEtf, msct::MSct, mtopo::MTopo, Placer};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::util::prop::prop_check;
use baechi::util::rng::Pcg;

/// Random layered DAG: every node has ≥1 parent in an earlier layer
/// (except sources), so the graph is connected-ish and acyclic by
/// construction.
fn random_dag(rng: &mut Pcg, max_nodes: usize) -> OpGraph {
    let n = rng.range(4, max_nodes.max(5));
    let mut g = OpGraph::new("rand");
    let mut ids: Vec<NodeId> = Vec::new();
    for i in 0..n {
        let id = g.add_node(&format!("op{i}"), OpKind::Generic(0));
        {
            let node = g.node_mut(id);
            node.compute = rng.uniform(0.5, 3.0);
            node.mem = MemorySpec {
                params: rng.below(50) + 1,
                output: rng.below(20) + 1,
                param_grad: rng.below(50),
                upstream_grad: rng.below(10),
                temp: rng.below(10),
            };
            node.output_bytes = node.mem.output;
        }
        if !ids.is_empty() {
            let parents = 1 + rng.below(3.min(ids.len() as u64)) as usize;
            for _ in 0..parents {
                let p = *rng.choose(&ids);
                if p != id {
                    let bytes = g.node(id).mem.output.max(1);
                    g.add_edge(p, id, bytes);
                }
            }
        }
        // Random co-placement groups to exercise fusion.
        if rng.chance(0.3) {
            let grp = format!("g{}", rng.below(6));
            g.node_mut(id).coplacement_group = Some(grp);
        }
        ids.push(id);
    }
    g
}

fn unit_cluster(n: usize, mem: u64) -> Cluster {
    Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
}

#[test]
fn prop_random_dags_are_acyclic_and_topo_valid() {
    prop_check("dag_topo", 200, |rng| {
        let g = random_dag(rng, 60);
        let order = g.topo_order().expect("acyclic by construction");
        let rank = g.topo_ranks();
        for e in g.edges() {
            assert!(rank[e.src.0] < rank[e.dst.0]);
        }
        assert_eq!(order.len(), g.len());
    });
}

#[test]
fn prop_fusion_never_creates_cycles_and_conserves_compute() {
    prop_check("fusion_acyclic", 200, |rng| {
        let g = random_dag(rng, 60);
        let opt = optimize(&g, &OptConfig::default());
        assert!(opt.graph.is_acyclic(), "fusion created a cycle");
        // Compute time is conserved by fusion (no forward-only here).
        let before = g.total_compute();
        let after = opt.graph.total_compute();
        assert!((before - after).abs() < 1e-9 * before.max(1.0));
        // Every live original node has a live anchor.
        for id in g.node_ids() {
            let a = opt.anchor[id.0].expect("anchor exists");
            assert!(opt.graph.is_alive(a));
        }
    });
}

#[test]
fn prop_placers_respect_memory_and_cover_all_ops() {
    prop_check("placer_memory", 120, |rng| {
        let g = random_dag(rng, 40);
        let total: u64 = g
            .iter_nodes()
            .map(|n| n.mem.params + n.mem.param_grad + n.mem.output)
            .sum();
        let n_dev = rng.range(2, 5);
        // Enough aggregate headroom that a feasible placement exists.
        let mem = (total / n_dev as u64) * 3 + 200;
        let cluster = unit_cluster(n_dev, mem);
        for placer in [&MEtf as &dyn Placer, &MTopo, &MSct::with_heuristic()] {
            match placer.place(&g, &cluster) {
                Ok(p) => {
                    assert_eq!(p.device_of.len(), g.len(), "{} coverage", placer.name());
                    for (i, &peak) in p.peak_memory.iter().enumerate() {
                        assert!(
                            peak <= mem,
                            "{}: device {i} peak {peak} > {mem}",
                            placer.name()
                        );
                    }
                }
                Err(_) => {
                    // Greedy placers may dead-end on tight instances;
                    // that is a valid outcome, not an invariant breach.
                }
            }
        }
    });
}

#[test]
fn prop_sim_makespan_lower_bounds() {
    prop_check("sim_bounds", 120, |rng| {
        let g = random_dag(rng, 40);
        let n_dev = rng.range(1, 5);
        let cluster = unit_cluster(n_dev, u64::MAX / 4);
        let placement: std::collections::BTreeMap<_, _> = g
            .node_ids()
            .map(|id| (id, baechi::graph::DeviceId(rng.range(0, n_dev))))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        let cp = g.critical_path(|_| 0.0).unwrap();
        let work = g.total_compute() / n_dev as f64;
        assert!(r.makespan >= cp - 1e-9, "makespan below critical path");
        assert!(r.makespan >= work - 1e-9, "makespan below work bound");
        // And the trivial upper bound: fully serialized + every edge paid.
        let upper = g.total_compute()
            + g.edges().iter().map(|e| e.bytes as f64).sum::<f64>();
        assert!(r.makespan <= upper + 1e-6);
    });
}

#[test]
fn prop_metf_within_appendix_a_bound_proxy() {
    // Appendix A: ω_m-etf ≤ (1 + n/R + ρ)·ω_opt. With generous memory
    // R = n, and ω_opt ≥ max(work/n, critical path), so we check
    // makespan ≤ (2 + ρ) · max(work/n, cp) — a slightly looser but
    // placement-independent proxy.
    prop_check("metf_bound", 80, |rng| {
        let g = random_dag(rng, 40);
        let n_dev = rng.range(2, 5);
        let cluster = unit_cluster(n_dev, u64::MAX / 4);
        let p = MEtf.place(&g, &cluster).expect("ample memory");
        let rho = g.rho(|b| cluster.comm.time(b));
        let opt_lb =
            (g.total_compute() / n_dev as f64).max(g.critical_path(|_| 0.0).unwrap());
        let bound = (2.0 + rho.max(1.0)) * opt_lb;
        assert!(
            p.predicted_makespan <= bound + 1e-6,
            "makespan {} > bound {bound} (rho {rho})",
            p.predicted_makespan
        );
    });
}

#[test]
fn prop_expand_placement_respects_colocation() {
    prop_check("expand_colocation", 100, |rng| {
        let mut g = random_dag(rng, 40);
        // Random colocation pairs.
        let ids: Vec<_> = g.node_ids().collect();
        for _ in 0..rng.range(1, 4) {
            let a = *rng.choose(&ids);
            let b = *rng.choose(&ids);
            let grp = format!("colo{}", rng.below(3));
            g.node_mut(a).colocation_group = Some(grp.clone());
            g.node_mut(b).colocation_group = Some(grp);
        }
        let cluster = unit_cluster(3, u64::MAX / 4);
        let opt = optimize(&g, &OptConfig::default());
        if let Ok(p) = MEtf.place(&opt.graph, &cluster) {
            let full = baechi::optimizer::expand_placement(&g, &opt, &p.device_of);
            for (_, members) in g.colocation_groups() {
                let d0 = full[&members[0]];
                for &m in &members[1..] {
                    assert_eq!(full[&m], d0, "colocation group split after expand");
                }
            }
        }
    });
}

#[test]
fn prop_perturbation_keeps_placement_feasible() {
    // Fig. 8 machinery: perturbed graphs still simulate fine under the
    // placement computed from unperturbed profiles.
    prop_check("perturb_feasible", 60, |rng| {
        let g = random_dag(rng, 30);
        let cluster = unit_cluster(3, u64::MAX / 4);
        let p = match MEtf.place(&g, &cluster) {
            Ok(p) => p,
            Err(_) => return,
        };
        let perturbed = baechi::profile::perturb::perturb_graph(&g, 0.2, rng);
        let r = simulate(&perturbed, &cluster, &p.device_of, SimConfig::default());
        assert!(r.ok());
        // ±20 % cost noise cannot change makespan by more than ~±20 %
        // plus scheduling slack; sanity: within 2×.
        let base = simulate(&g, &cluster, &p.device_of, SimConfig::default());
        let ratio = r.makespan / base.makespan;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    });
}

#[test]
fn prop_uniform_topology_bit_identical_to_single_comm_model() {
    // Backward compatibility of the topology subsystem: a cluster with
    // an explicitly-attached `Topology::uniform` (directly or through a
    // JSON round-trip) must produce bit-identical placements and
    // simulated makespans to the plain single-`CommModel` cluster.
    use baechi::topology::{json as topo_json, Topology};
    prop_check("uniform_topology_identity", 60, |rng| {
        let g = random_dag(rng, 40);
        let n_dev = rng.range(2, 5);
        let total: u64 = g
            .iter_nodes()
            .map(|n| n.mem.params + n.mem.param_grad + n.mem.output)
            .sum();
        let mem = (total / n_dev as u64) * 3 + 200;
        let comm = CommModel::new(rng.uniform(0.0, 1e-4), rng.uniform(0.5, 1e9)).unwrap();
        let base = Cluster::homogeneous(n_dev, mem, comm);
        let explicit = Cluster::homogeneous(n_dev, mem, comm)
            .with_topology(Topology::uniform(n_dev, comm))
            .unwrap();
        let json_topo =
            topo_json::from_json(&topo_json::to_json(&Topology::uniform(n_dev, comm))).unwrap();
        let from_json = Cluster::homogeneous(n_dev, mem, comm)
            .with_topology(json_topo)
            .unwrap();
        for placer in [&MEtf as &dyn Placer, &MTopo, &MSct::with_heuristic()] {
            let a = placer.place(&g, &base);
            let b = placer.place(&g, &explicit);
            let c = placer.place(&g, &from_json);
            match (a, b, c) {
                (Ok(a), Ok(b), Ok(c)) => {
                    assert_eq!(a.device_of, b.device_of, "{} placement", placer.name());
                    assert_eq!(a.device_of, c.device_of, "{} via json", placer.name());
                    assert_eq!(
                        a.predicted_makespan.to_bits(),
                        b.predicted_makespan.to_bits(),
                        "{} predicted makespan",
                        placer.name()
                    );
                    assert_eq!(
                        a.predicted_makespan.to_bits(),
                        c.predicted_makespan.to_bits()
                    );
                    let sa = simulate(&g, &base, &a.device_of, SimConfig::default());
                    let sb = simulate(&g, &explicit, &a.device_of, SimConfig::default());
                    let sc = simulate(&g, &from_json, &a.device_of, SimConfig::default());
                    assert_eq!(sa.makespan.to_bits(), sb.makespan.to_bits());
                    assert_eq!(sa.makespan.to_bits(), sc.makespan.to_bits());
                    assert_eq!(sa.transfers, sb.transfers);
                    assert_eq!(sa.peak_memory, sb.peak_memory);
                    assert_eq!(sa.events, sb.events);
                }
                (Err(_), Err(_), Err(_)) => {} // identically infeasible
                other => panic!("{}: divergent feasibility: {other:?}", placer.name()),
            }
        }
    });
}

#[test]
fn prop_lp_favorites_unique_and_consistent() {
    prop_check("lp_favorites", 40, |rng| {
        let g = random_dag(rng, 20);
        let comm = CommModel::new(0.0, 1.0).unwrap();
        let fav = baechi::lp::favorites(&g, &comm, baechi::lp::FavoriteMethod::Lp);
        let mut child_of = std::collections::BTreeMap::new();
        for i in g.node_ids() {
            if let Some(j) = fav.fav_child[i.0] {
                assert_eq!(fav.fav_parent[j.0], Some(i), "inverse mapping");
                assert!(
                    child_of.insert(j, i).is_none(),
                    "node is favorite child of two parents"
                );
                assert!(
                    g.edge_bytes(i, j).is_some(),
                    "favorite child without an edge"
                );
            }
        }
    });
}

/// Draw a ground-truth topology family for calibration round-trips:
/// uniform (sometimes with heterogeneous speeds), ragged NVLink
/// islands, or two-tier machines. The intra/inter bandwidth gap is kept
/// ≥ 4× so the island structure is unambiguous.
fn random_truth_topology(rng: &mut Pcg) -> baechi::topology::Topology {
    use baechi::topology::Topology;
    let comm = |lat: f64, bw: f64| CommModel::new(lat, bw).unwrap();
    match rng.below(3) {
        0 => {
            let n = rng.range(2, 7);
            let t = Topology::uniform(n, comm(rng.uniform(1e-6, 1e-4), rng.uniform(1e9, 2e10)));
            if rng.chance(0.5) {
                let speeds: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
                t.with_speeds(speeds).unwrap()
            } else {
                t
            }
        }
        1 => {
            let n = rng.range(3, 9);
            let island = rng.range(2, 4);
            let inter = comm(rng.uniform(2e-5, 1e-4), rng.uniform(2e9, 8e9));
            let ratio = rng.uniform(4.0, 10.0);
            let intra = comm(inter.latency / ratio, inter.bandwidth * ratio);
            Topology::nvlink_islands(n, island, intra, inter).unwrap()
        }
        _ => {
            let nodes = rng.range(2, 4);
            let per = rng.range(2, 4);
            let intra = comm(rng.uniform(1e-6, 2e-5), rng.uniform(8e9, 2e10));
            let ratio = rng.uniform(4.0, 10.0);
            let inter = comm(intra.latency * ratio, intra.bandwidth / ratio);
            Topology::two_tier(nodes, per, intra, inter).unwrap()
        }
    }
}

#[test]
fn prop_calibration_round_trip_recovers_ground_truth() {
    use baechi::calibrate::{collect, fit_cluster, pair_matrix_error, CalibrationPlan, SyntheticSource};
    prop_check("calibration_round_trip", 40, |rng| {
        let truth = random_truth_topology(rng);
        let noise = if rng.chance(0.5) {
            0.0
        } else {
            rng.uniform(0.005, 0.03)
        };
        let mut src = SyntheticSource::new(truth.clone(), noise, rng.next_u64()).unwrap();
        let m = collect(&mut src, &CalibrationPlan::default()).unwrap();
        let cal = fit_cluster(&m).unwrap();
        let rec = &cal.topology;
        assert_eq!(rec.n(), truth.n());
        let n = truth.n();

        // The recovered effective pair matrix reproduces the ground
        // truth: within 5% mean relative error at zero noise (the
        // acceptance bar), degrading gracefully with the noise level.
        let mean_err = pair_matrix_error(rec, &truth);
        let tol = 0.05 + 8.0 * noise;
        assert!(
            mean_err < tol,
            "mean pair error {mean_err} > {tol} (noise {noise}, truth {}, warnings {:?})",
            truth.describe(),
            cal.report.warnings
        );
        // The report's self-assessment agrees with the external check:
        // it scores against measurements, which sit within noise of the
        // truth the external check uses.
        assert!(cal.report.mean_rel_error < tol);

        // At zero noise the island partition is recovered exactly (both
        // sides number islands densely in device order), and so are
        // declared device speeds.
        if noise == 0.0 {
            assert_eq!(rec.islands(), truth.islands(), "island partition");
            for d in 0..n {
                assert!(
                    (rec.speed(d) - truth.speed(d)).abs() < 0.05,
                    "device {d} speed {} vs truth {}",
                    rec.speed(d),
                    truth.speed(d)
                );
            }
        }
    });
}

#[test]
fn prop_calibration_measured_report_zero_rounds_identity() {
    // A measured report through `place_iterative_measured` with a
    // 0-round budget must stay bit-identical to `place` — the measured
    // path can never perturb the single-shot contract.
    use baechi::calibrate::measured_report;
    use baechi::engine::{PlacementEngine, PlacementRequest};
    use baechi::feedback::ReplacementPolicy;
    use std::sync::Arc;
    prop_check("calibration_measured_zero_rounds", 15, |rng| {
        let g = random_dag(rng, 30);
        let truth = random_truth_topology(rng);
        let n = truth.n();
        let engine = PlacementEngine::builder()
            .cluster(
                Cluster::homogeneous(n, 1 << 30, CommModel::new(1e-5, 1e9).unwrap())
                    .with_topology(truth.clone())
                    .unwrap(),
            )
            .build()
            .unwrap();
        let req = PlacementRequest::new(g, "m-etf");
        let plain = engine.place(&req).unwrap();
        let report = measured_report(&truth, rng.uniform(0.1, 10.0), &[]).unwrap();
        let it = engine
            .place_iterative_measured(&req, &ReplacementPolicy::rounds(0), &report)
            .unwrap();
        assert!(Arc::ptr_eq(&it.response, &plain));
        assert!(it.rounds.is_empty());
    });
}

/// A chain: at most one transfer is ever in flight, so the
/// bandwidth-sharing flow simulator has nothing to share.
fn random_chain(rng: &mut Pcg, max_nodes: usize) -> OpGraph {
    let n = rng.range(3, max_nodes.max(4));
    let mut g = OpGraph::new("chain");
    let mut prev: Option<NodeId> = None;
    for i in 0..n {
        let id = g.add_node(&format!("op{i}"), OpKind::Generic(0));
        g.node_mut(id).compute = rng.uniform(0.1, 2.0);
        let bytes = rng.below(1 << 20) + 1;
        g.node_mut(id).mem.output = bytes;
        g.node_mut(id).output_bytes = bytes;
        if let Some(p) = prev {
            let b = g.node(p).mem.output;
            g.add_edge(p, id, b);
        }
        prev = Some(id);
    }
    g
}

#[test]
fn prop_flow_sim_matches_sequential_without_competing_flows() {
    // Compatibility contract of the flow simulator: with no competing
    // flows the two comm modes describe the same physics, so chain
    // makespans must agree within 1e-9 on every topology family.
    prop_check("flow_chain_compat", 60, |rng| {
        let g = random_chain(rng, 20);
        let topo = random_truth_topology(rng);
        let n = topo.n();
        let mk = |seq: bool| {
            Cluster::homogeneous(n, u64::MAX / 4, CommModel::new(1e-5, 1e9).unwrap())
                .with_topology(topo.clone())
                .unwrap()
                .with_sequential_comm(seq)
        };
        let placement: std::collections::BTreeMap<_, _> = g
            .node_ids()
            .map(|id| (id, baechi::graph::DeviceId(rng.range(0, n))))
            .collect();
        let rs = simulate(&g, &mk(true), &placement, SimConfig::default());
        let rp = simulate(&g, &mk(false), &placement, SimConfig::default());
        assert!(rs.ok() && rp.ok());
        let tol = 1e-9 * rs.makespan.max(1.0);
        assert!(
            (rs.makespan - rp.makespan).abs() <= tol,
            "chain makespans diverge: sequential {} vs flow {}",
            rs.makespan,
            rp.makespan
        );
        // One transfer at a time ⇒ nothing competes: no queue drops and
        // (up to an ulp of pair-model composition) no slowdown.
        assert!(rp.contention.blocked_seconds < 1e-9);
        assert_eq!(rp.contention.drop_warnings, 0);
        assert_eq!(rs.transfers, rp.transfers);
    });
}

#[test]
fn prop_flow_uniform_topology_bit_identical_in_parallel_comm() {
    // The flow simulator must not break the uniform-topology identity:
    // a homogeneous cluster and an explicit `Topology::uniform` resolve
    // to the same pair models and paths, so parallel-comm runs are
    // bit-identical.
    use baechi::topology::Topology;
    prop_check("flow_uniform_identity", 40, |rng| {
        let g = random_dag(rng, 40);
        let n_dev = rng.range(2, 5);
        let comm = CommModel::new(rng.uniform(0.0, 1e-4), rng.uniform(0.5, 1e9)).unwrap();
        let base =
            Cluster::homogeneous(n_dev, u64::MAX / 4, comm).with_sequential_comm(false);
        let explicit = Cluster::homogeneous(n_dev, u64::MAX / 4, comm)
            .with_topology(Topology::uniform(n_dev, comm))
            .unwrap()
            .with_sequential_comm(false);
        let placement: std::collections::BTreeMap<_, _> = g
            .node_ids()
            .map(|id| (id, baechi::graph::DeviceId(rng.range(0, n_dev))))
            .collect();
        let ra = simulate(&g, &base, &placement, SimConfig::default());
        let rb = simulate(&g, &explicit, &placement, SimConfig::default());
        assert!(ra.ok() && rb.ok());
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(ra.events, rb.events);
        assert_eq!(ra.transfers, rb.transfers);
        assert_eq!(
            ra.contention.blocked_seconds.to_bits(),
            rb.contention.blocked_seconds.to_bits()
        );
        assert_eq!(ra.contention.drop_warnings, rb.contention.drop_warnings);
    });
}

#[test]
fn prop_incremental_cone_diff_marks_exactly_descendants() {
    // A point mutation of one op's compute cost must dirty exactly the
    // mutated op and its transitive descendants — nothing else. This is
    // the soundness contract the incremental placer builds on: clean
    // nodes are provably unaffected by the change.
    use baechi::engine::fingerprint::cone_fingerprints;
    use baechi::graph::delta::diff_by_cones;
    prop_check("incremental_cone_diff", 120, |rng| {
        let old = random_dag(rng, 40);
        let ids: Vec<NodeId> = old.node_ids().collect();
        let target = *rng.choose(&ids);
        let mut new = old.clone();
        new.node_mut(target).compute += 1.0;
        let old_cones = cone_fingerprints(&old).unwrap();
        let new_cones = cone_fingerprints(&new).unwrap();
        let delta = diff_by_cones(&old, &new, &old_cones, &new_cones);
        for id in new.node_ids() {
            let expect_dirty = new.reachable(target, id);
            assert_eq!(
                delta.dirty.contains(&id),
                expect_dirty,
                "node {id:?}: dirty set must be exactly the descendants of {target:?}"
            );
        }
        // Clean pairs are identity matches (same graph layout) and the
        // partition is exhaustive.
        for &(new_id, old_id) in &delta.clean {
            assert_eq!(new_id, old_id);
        }
        assert_eq!(delta.dirty.len() + delta.clean.len(), new.len());
        let expect_fraction = delta.dirty.len() as f64 / new.len() as f64;
        assert!((delta.dirty_fraction - expect_fraction).abs() < 1e-12);
    });
}

#[test]
fn prop_incremental_results_respect_memory_and_makespan_tolerance() {
    // The ISSUE acceptance property: serving a small delta through the
    // incremental path must (a) cover every op, (b) respect per-device
    // memory capacity, and (c) never exceed the full-placement makespan
    // beyond the configured tolerance. When the service falls back to a
    // full run instead, the result must be bit-identical to a fresh
    // engine's full placement.
    use baechi::engine::{PlacementEngine, PlacementRequest};
    use baechi::graph::delta::{mutate, MutationSpec};
    use baechi::serve::{IncrementalConfig, PlacementService, ServeMode, ServiceConfig};
    use std::sync::Arc;
    prop_check("incremental_capacity_tolerance", 25, |rng| {
        let g = random_dag(rng, 30);
        let n_dev = rng.range(2, 5);
        let mem: u64 = 1 << 20; // ample for random_dag's byte scale
        let cluster = unit_cluster(n_dev, mem);
        let engine = Arc::new(
            PlacementEngine::builder()
                .cluster(cluster.clone())
                .build()
                .unwrap(),
        );
        let tol = 0.25;
        let mut scfg = ServiceConfig::default();
        scfg.workers = 1;
        scfg.incremental = IncrementalConfig {
            enabled: true,
            max_dirty_fraction: 0.6,
            makespan_tolerance: tol,
        };
        let service = PlacementService::new(engine, scfg).unwrap();

        let base = service
            .place(PlacementRequest::new(g.clone(), "m-etf"))
            .unwrap();
        let mut mutated = g.clone();
        mutate(&mut mutated, rng, &MutationSpec::small());
        let out = service
            .place(PlacementRequest::new(mutated.clone(), "m-etf"))
            .unwrap();

        // (a) coverage and (b) capacity hold in every serve mode.
        assert_eq!(out.response.placement.device_of.len(), mutated.len());
        let sim = out.response.sim.as_ref().expect("service simulates");
        assert!(sim.ok(), "served plan must not OOM: {:?}", sim.oom);
        for (d, &peak) in sim.peak_memory.iter().enumerate() {
            assert!(peak <= mem, "device {d} peak {peak} > capacity {mem}");
        }

        // Full reference for the mutated graph on a fresh engine.
        let fresh = PlacementEngine::builder()
            .cluster(cluster)
            .build()
            .unwrap();
        let full = fresh
            .place(&PlacementRequest::new(mutated, "m-etf"))
            .unwrap();
        let full_makespan = full.sim.as_ref().unwrap().makespan;
        match out.mode {
            ServeMode::Incremental { dirty_ops } => {
                assert!(dirty_ops > 0, "a real delta patches at least one op");
                // (c) tolerance: the guard compares against the cached
                // base plan, which a one-op small() mutation keeps within
                // a few percent of the fresh full makespan — 1.25× slack
                // absorbs that gap.
                assert!(
                    sim.makespan <= full_makespan * (1.0 + tol) * 1.25 + 1e-9,
                    "incremental makespan {} vs full {} beyond tolerance",
                    sim.makespan,
                    full_makespan
                );
            }
            ServeMode::Full => {
                assert_eq!(
                    out.response.placement.device_of, full.placement.device_of,
                    "full fallback must match a fresh engine bit-for-bit"
                );
            }
            ServeMode::CacheHit => {
                // A no-op mutation draw: served from cache, same plan.
                assert!(Arc::ptr_eq(&out.response, &base.response));
            }
        }
    });
}

#[test]
fn prop_iterative_zero_rounds_bit_identical_to_place() {
    use baechi::engine::{PlacementEngine, PlacementRequest};
    use baechi::feedback::ReplacementPolicy;
    use baechi::topology::Topology;
    use std::sync::Arc;
    prop_check("iterative_zero_rounds", 25, |rng| {
        let g = random_dag(rng, 40);
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let engine = PlacementEngine::builder()
            .cluster(
                Cluster::homogeneous(4, 1 << 30, inter)
                    .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
                    .unwrap(),
            )
            .build()
            .unwrap();
        let req = PlacementRequest::new(g, "m-etf");
        let plain = engine.place(&req).unwrap();
        // A zero-round budget degenerates to place(): same cached Arc,
        // hence bit-identical placement and simulation.
        let it = engine
            .place_iterative(&req, &ReplacementPolicy::rounds(0))
            .unwrap();
        assert!(Arc::ptr_eq(&it.response, &plain), "same cached response");
        assert!(it.rounds.is_empty());
        // An un-triggerable policy must break before any re-placement
        // and return the identical baseline as well.
        let lazy = ReplacementPolicy {
            trunk_utilization: f64::INFINITY,
            blocked_fraction: f64::INFINITY,
            ..ReplacementPolicy::rounds(3)
        };
        let it2 = engine.place_iterative(&req, &lazy).unwrap();
        assert!(Arc::ptr_eq(&it2.response, &plain), "loop must not trigger");
        assert_eq!(it2.rounds.len(), 1, "only the round-0 baseline");
        let plain_makespan = plain.sim.as_ref().unwrap().makespan;
        assert_eq!(it2.baseline_makespan.to_bits(), plain_makespan.to_bits());
    });
}

#[test]
fn prop_trace_collection_preserves_bit_identical_responses() {
    use baechi::engine::{PlacementEngine, PlacementRequest, RecordingObserver};

    prop_check("trace_identity", 40, |rng| {
        let g = random_dag(rng, 40);
        let traced = PlacementEngine::builder()
            .cluster(unit_cluster(3, 1 << 30))
            .tracing(true)
            .observer(RecordingObserver::new())
            .build()
            .unwrap();
        let plain = PlacementEngine::builder()
            .cluster(unit_cluster(3, 1 << 30))
            .tracing(false)
            .build()
            .unwrap();
        let req = PlacementRequest::new(g, "m-etf");
        let a = traced.place(&req).unwrap();
        let b = plain.place(&req).unwrap();
        // Telemetry must be purely observational: same placement, same
        // simulation, bit for bit.
        assert_eq!(a.placement.device_of, b.placement.device_of);
        assert_eq!(
            a.placement.predicted_makespan.to_bits(),
            b.placement.predicted_makespan.to_bits()
        );
        assert_eq!(a.devices_used, b.devices_used);
        let (sa, sb) = (a.sim.as_ref().unwrap(), b.sim.as_ref().unwrap());
        assert_eq!(sa.makespan.to_bits(), sb.makespan.to_bits());
        assert_eq!(sa.peak_memory, sb.peak_memory);
        assert!(!traced.tracer().drain().is_empty(), "spans were collected");
        assert!(plain.tracer().drain().is_empty(), "nothing booked when off");
    });
}

#[test]
fn prop_trace_sim_schedule_reconstructs_makespan() {
    prop_check("trace_schedule", 120, |rng| {
        let g = random_dag(rng, 50);
        let n_dev = rng.range(2, 5);
        let cluster = unit_cluster(n_dev, u64::MAX / 4);
        let placement: std::collections::BTreeMap<_, _> = g
            .node_ids()
            .map(|id| (id, baechi::graph::DeviceId(rng.range(0, n_dev))))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        // The recorded schedule reproduces the makespan exactly — this
        // is what makes the exported timeline trustworthy.
        assert_eq!(r.schedule.max_end().to_bits(), r.makespan.to_bits());
        assert_eq!(r.schedule.ops.len(), g.len(), "every op has a span");
        for op in &r.schedule.ops {
            assert!(op.end >= op.start - 1e-12);
            assert!(op.start >= -1e-9 && op.end <= r.makespan + 1e-9);
        }
        for tr in &r.schedule.transfers {
            assert!(tr.end >= tr.start - 1e-12);
            assert!(tr.start >= -1e-9 && tr.end <= r.makespan + 1e-9);
            assert!(!tr.links.is_empty(), "a transfer rides ≥1 link");
        }
        // Devices execute one op at a time, so per-device intervals
        // must not overlap (beyond fp rounding of reconstructed starts).
        let mut per_dev: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_dev];
        for op in &r.schedule.ops {
            per_dev[op.device].push((op.start, op.end));
        }
        for ivals in &mut per_dev {
            ivals.sort_by(|x, y| x.0.total_cmp(&y.0));
            for w in ivals.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-9, "device ops overlap: {w:?}");
            }
        }
    });
}

/// Random coarsening knobs for the hierarchical-placement properties.
fn random_coarsen_cfg(rng: &mut Pcg) -> baechi::hierarchy::CoarsenConfig {
    baechi::hierarchy::CoarsenConfig {
        enabled: true,
        max_members: rng.range(2, 12),
        rounds: rng.range(1, 6),
        fuse_chains: rng.chance(0.9),
        fuse_groups: rng.chance(0.9),
    }
}

#[test]
fn prop_hier_contraction_never_creates_cycle() {
    use baechi::hierarchy::coarsen;
    prop_check("hier_acyclic", 200, |rng| {
        let g = random_dag(rng, 60);
        let cfg = random_coarsen_cfg(rng);
        let coarse = coarsen(&g, &cfg);
        assert!(coarse.graph.is_acyclic(), "contraction created a cycle");
        for members in &coarse.members {
            assert!(
                members.len() <= cfg.max_members,
                "super-op exceeds max_members ({} > {})",
                members.len(),
                cfg.max_members
            );
        }
    });
}

#[test]
fn prop_hier_super_ops_aggregate_member_sums() {
    use baechi::hierarchy::coarsen;
    prop_check("hier_sums", 150, |rng| {
        let g = random_dag(rng, 60);
        let coarse = coarsen(&g, &random_coarsen_cfg(rng));
        for cid in coarse.graph.node_ids() {
            let members = &coarse.members[cid.0];
            let s = coarse.graph.node(cid);
            let compute: f64 = members.iter().map(|&m| g.node(m).compute).sum();
            assert!(
                (s.compute - compute).abs() <= 1e-9 * compute.max(1.0),
                "super compute is the member sum"
            );
            let sum = |f: fn(&MemorySpec) -> u64| members.iter().map(|&m| f(&g.node(m).mem)).sum::<u64>();
            assert_eq!(s.mem.params, sum(|m| m.params));
            assert_eq!(s.mem.output, sum(|m| m.output));
            assert_eq!(s.mem.param_grad, sum(|m| m.param_grad));
            assert_eq!(s.mem.upstream_grad, sum(|m| m.upstream_grad));
            assert_eq!(s.mem.temp, sum(|m| m.temp));
            let out: u64 = members.iter().map(|&m| g.node(m).output_bytes).sum();
            assert_eq!(s.output_bytes, out);
        }
    });
}

#[test]
fn prop_hier_expand_coarsen_identity_on_node_sets() {
    use baechi::hierarchy::coarsen;
    use std::collections::BTreeSet;
    prop_check("hier_node_sets", 150, |rng| {
        let g = random_dag(rng, 60);
        let coarse = coarsen(&g, &random_coarsen_cfg(rng));
        // Every original node belongs to exactly one super-op, and the
        // member lists expand back to exactly the original node set.
        let mut seen = BTreeSet::new();
        for cid in coarse.graph.node_ids() {
            for &m in &coarse.members[cid.0] {
                assert_eq!(coarse.super_of[m.0], Some(cid), "mapping is consistent");
                assert!(seen.insert(m), "node {m:?} in two super-ops");
            }
        }
        let original: BTreeSet<NodeId> = g.node_ids().collect();
        assert_eq!(seen, original, "expand∘coarsen is the identity on node sets");
    });
}

#[test]
fn prop_hier_zero_coarsening_bit_identical_to_msct() {
    use baechi::hierarchy::{CoarsenConfig, HierPlacer};
    prop_check("hier_off_identity", 80, |rng| {
        let g = random_dag(rng, 40);
        let total: u64 = g
            .iter_nodes()
            .map(|n| n.mem.params + n.mem.param_grad + n.mem.output)
            .sum();
        let n_dev = rng.range(2, 5);
        let mem = (total / n_dev as u64) * 3 + 200;
        let cluster = unit_cluster(n_dev, mem);
        let flat = MSct::default().place(&g, &cluster);
        let hier = HierPlacer::new(CoarsenConfig::off()).place(&g, &cluster);
        match (flat, hier) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.algorithm, b.algorithm, "delegation is wholesale");
                assert_eq!(a.device_of, b.device_of);
                assert_eq!(
                    a.predicted_makespan.to_bits(),
                    b.predicted_makespan.to_bits()
                );
                assert_eq!(a.peak_memory, b.peak_memory);
            }
            (Err(_), Err(_)) => {} // identically infeasible
            other => panic!("divergent feasibility: {other:?}"),
        }
    });
}

#[test]
fn prop_hier_refined_placements_respect_memory() {
    use baechi::hierarchy::{CoarsenConfig, HierPlacer};
    prop_check("hier_memory", 100, |rng| {
        let g = random_dag(rng, 50);
        let total: u64 = g
            .iter_nodes()
            .map(|n| n.mem.params + n.mem.param_grad + n.mem.output)
            .sum();
        let n_dev = rng.range(2, 5);
        let mem = (total / n_dev as u64) * 3 + 200;
        let cluster = unit_cluster(n_dev, mem);
        let cfg = random_coarsen_cfg(rng);
        match HierPlacer::new(cfg).place(&g, &cluster) {
            Ok(p) => {
                assert_eq!(p.device_of.len(), g.len(), "hier covers every op");
                for (d, &peak) in p.peak_memory.iter().enumerate() {
                    assert!(peak <= mem, "device {d} peak {peak} > capacity {mem}");
                }
            }
            Err(_) => {
                // Tight instances may be infeasible even for flat m-SCT
                // (which hier falls back to); that is a valid outcome.
            }
        }
    });
}
