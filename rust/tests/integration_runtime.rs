//! Integration tests over the PJRT runtime + AOT artifacts: load every
//! artifact, execute the kernels against host-computed references, and
//! run the distributed trainer. Skipped (with a notice) when
//! `make artifacts` hasn't produced the bundle.

use baechi::exec::plan::MlpPlan;
use baechi::exec::trainer::{
    init_params, synthetic_batch, train_distributed, train_oracle, ModelMeta, TrainConfig,
};
use baechi::exec::HostTensor;
use baechi::runtime::artifact::{literal_f32, ArtifactRegistry};
use baechi::runtime::{xla, Runtime};

fn registry() -> Option<ArtifactRegistry> {
    let dir = ArtifactRegistry::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime tests: run `make artifacts`");
        return None;
    }
    Some(ArtifactRegistry::open(Runtime::cpu().unwrap(), &dir).unwrap())
}

#[test]
fn all_artifacts_compile() {
    let Some(reg) = registry() else { return };
    let names: Vec<String> = reg.manifest().names().iter().map(|s| s.to_string()).collect();
    assert!(names.len() >= 13, "expected ≥13 artifacts, got {names:?}");
    for name in names {
        reg.load(&name)
            .unwrap_or_else(|e| panic!("compiling {name}: {e}"));
    }
}

#[test]
fn kernel_matmul_matches_host() {
    let Some(reg) = registry() else { return };
    let exec = reg.load("kernel_matmul").unwrap();
    let n = 128;
    let a: Vec<f32> = (0..n * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
    let out = exec
        .run(&[
            literal_f32(&a, &[n as i64, n as i64]).unwrap(),
            literal_f32(&b, &[n as i64, n as i64]).unwrap(),
        ])
        .unwrap();
    let got = HostTensor::from_literal(&out[0]).unwrap();
    // host reference
    for r in [0usize, 17, 63, 127] {
        for c in [0usize, 5, 80, 127] {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += a[r * n + k] as f64 * b[k * n + c] as f64;
            }
            let g = got.data[r * n + c] as f64;
            assert!(
                (g - acc).abs() < 1e-3 * (1.0 + acc.abs()),
                "({r},{c}): {g} vs {acc}"
            );
        }
    }
}

#[test]
fn kernel_attention_rows_sum_preserved() {
    let Some(reg) = registry() else { return };
    let exec = reg.load("kernel_attention").unwrap();
    let (l, d) = (64, 64);
    let q = vec![0.1f32; l * d];
    let k = vec![0.2f32; l * d];
    // constant v: attention output must equal v rows exactly
    let v: Vec<f32> = (0..l * d).map(|i| (i / d) as f32).collect();
    let out = exec
        .run(&[
            literal_f32(&q, &[l as i64, d as i64]).unwrap(),
            literal_f32(&k, &[l as i64, d as i64]).unwrap(),
            literal_f32(&v, &[l as i64, d as i64]).unwrap(),
        ])
        .unwrap();
    let got = HostTensor::from_literal(&out[0]).unwrap();
    // with uniform q·k, softmax is uniform → each output row = mean(v)
    let mean = (0..l).map(|i| i as f32).sum::<f32>() / l as f32;
    for x in &got.data {
        assert!((x - mean).abs() < 1e-3, "{x} vs {mean}");
    }
}

#[test]
fn layer_fwd_bwd_shapes_roundtrip() {
    let Some(reg) = registry() else { return };
    let meta = ModelMeta::load(&ArtifactRegistry::default_dir()).unwrap();
    let params = init_params(&meta, 5);
    let (x, _) = synthetic_batch(&meta, 0, 5);
    // layer0 forward
    let f = reg.load("layer0_fwd").unwrap();
    let y = f
        .run(&[
            x.to_literal().unwrap(),
            params[0].0.to_literal().unwrap(),
            params[0].1.to_literal().unwrap(),
        ])
        .unwrap();
    let y0 = HostTensor::from_literal(&y[0]).unwrap();
    assert_eq!(
        y0.dims,
        vec![meta.batch as i64, meta.layer_dims[0].1 as i64]
    );
    // backward arity
    let b = reg.load("layer0_bwd").unwrap();
    let g = b
        .run(&[
            x.to_literal().unwrap(),
            params[0].0.to_literal().unwrap(),
            y[0].to_literal_clone(),
            y[0].to_literal_clone(),
        ])
        .unwrap_or_else(|e| panic!("layer0_bwd: {e}"));
    assert_eq!(g.len(), 3);
}

/// Helper: clone a literal through host memory (Literal lacks Clone).
trait LiteralCloneExt {
    fn to_literal_clone(&self) -> xla::Literal;
}
impl LiteralCloneExt for xla::Literal {
    fn to_literal_clone(&self) -> xla::Literal {
        let t = HostTensor::from_literal(self).unwrap();
        t.to_literal().unwrap()
    }
}

#[test]
fn distributed_training_across_3_devices_matches_oracle() {
    let Some(_) = registry() else { return };
    let meta = ModelMeta::load(&ArtifactRegistry::default_dir()).unwrap();
    // Adversarial plan: alternate devices every layer (max communication).
    let plan = MlpPlan {
        layer_dev: (0..meta.n_layers()).map(|i| i % 3).collect(),
        loss_dev: 2,
        n_devices: 3,
    };
    let cfg = TrainConfig {
        steps: 8,
        lr: 0.05,
        ..Default::default()
    };
    let dist = train_distributed(&plan, &cfg).unwrap();
    let oracle = train_oracle(&cfg).unwrap();
    for (s, (a, b)) in dist.losses.iter().zip(&oracle).enumerate() {
        assert!(
            (a - b).abs() < 1e-4 * (1.0 + b.abs()),
            "step {s}: {a} vs {b}"
        );
    }
}

#[test]
fn modeled_comm_delay_slows_training() {
    let Some(_) = registry() else { return };
    let meta = ModelMeta::load(&ArtifactRegistry::default_dir()).unwrap();
    let plan = MlpPlan {
        layer_dev: (0..meta.n_layers()).map(|i| i % 2).collect(),
        loss_dev: 1,
        n_devices: 2,
    };
    let fast = train_distributed(
        &plan,
        &TrainConfig {
            steps: 6,
            ..Default::default()
        },
    )
    .unwrap();
    // Model a very slow 10 MB/s interconnect.
    let slow = train_distributed(
        &plan,
        &TrainConfig {
            steps: 6,
            comm: Some(baechi::profile::CommModel::new(1e-3, 10e6).unwrap()),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        slow.wall_time > fast.wall_time,
        "modeled comm delay had no effect: {} vs {}",
        slow.wall_time,
        fast.wall_time
    );
    // numerics unaffected
    for (a, b) in fast.losses.iter().zip(&slow.losses) {
        assert!((a - b).abs() < 1e-6);
    }
}
