//! Explainability invariants (ISSUE 10 acceptance criteria):
//!
//! 1. Critical-path attribution telescopes — the four category totals
//!    sum to the simulated makespan within 1e-9 on arbitrary DAG
//!    placements.
//! 2. Explain-off bit-identity — an engine with decision recording and
//!    a flight recorder active serves responses bit-identical to a
//!    plain engine, for every registered placer.
//! 3. The run-history JSONL schema round-trips under random field
//!    values.

use baechi::explain::record::{AttributionTotals, RunRecord, RUN_RECORD_SCHEMA};
use baechi::explain::{attribute, record_decisions};
use baechi::graph::{DeviceId, MemorySpec, NodeId, OpGraph, OpKind};
use baechi::profile::{Cluster, CommModel};
use baechi::sim::{simulate, SimConfig};
use baechi::util::prop::prop_check;
use baechi::util::rng::Pcg;

fn random_dag(rng: &mut Pcg, max_nodes: usize) -> OpGraph {
    let n = rng.range(4, max_nodes.max(5));
    let mut g = OpGraph::new("rand");
    let mut ids: Vec<NodeId> = Vec::new();
    for i in 0..n {
        let id = g.add_node(&format!("op{i}"), OpKind::Generic(0));
        {
            let node = g.node_mut(id);
            node.compute = rng.uniform(0.5, 3.0);
            node.mem = MemorySpec {
                params: rng.below(50) + 1,
                output: rng.below(20) + 1,
                param_grad: rng.below(50),
                upstream_grad: rng.below(10),
                temp: rng.below(10),
            };
            node.output_bytes = node.mem.output;
        }
        if !ids.is_empty() {
            let parents = 1 + rng.below(3.min(ids.len() as u64)) as usize;
            for _ in 0..parents {
                let p = *rng.choose(&ids);
                if p != id {
                    let bytes = g.node(id).mem.output.max(1);
                    g.add_edge(p, id, bytes);
                }
            }
        }
        ids.push(id);
    }
    g
}

fn unit_cluster(n: usize, mem: u64) -> Cluster {
    Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
}

#[test]
fn prop_attribution_sums_to_makespan() {
    prop_check("attribution_sum", 120, |rng| {
        let g = random_dag(rng, 50);
        let n_dev = rng.range(2, 5);
        let cluster = unit_cluster(n_dev, u64::MAX / 4);
        let placement: std::collections::BTreeMap<_, _> = g
            .node_ids()
            .map(|id| (id, DeviceId(rng.range(0, n_dev))))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        let a = attribute(&g, &r.schedule, r.makespan);
        // The headline invariant: every second of the makespan lands in
        // exactly one category.
        let eps = 1e-9 * r.makespan.abs().max(1.0);
        assert!(
            a.residual().abs() <= eps,
            "residual {:e} over makespan {}",
            a.residual(),
            r.makespan
        );
        for (name, v) in [
            ("compute", a.compute),
            ("transfer", a.transfer),
            ("queue_wait", a.queue_wait),
            ("idle", a.idle),
        ] {
            assert!(v >= -eps, "negative {name} blame: {v}");
        }
        // The path is chronological and its elements index the schedule.
        let mut prev_end = f64::NEG_INFINITY;
        for s in &a.path {
            assert!(s.start >= prev_end - eps, "path goes backward in time");
            assert!(s.gap_before >= -eps);
            prev_end = s.end;
        }
        for (&i, _) in &a.crit_ops() {
            assert!(i < r.schedule.ops.len());
        }
        for (&i, _) in &a.crit_transfers() {
            assert!(i < r.schedule.transfers.len());
        }
        // Top ops are sorted heaviest-first.
        for w in a.top_ops.windows(2) {
            assert!(w[0].seconds >= w[1].seconds - eps);
        }
        // The path's final element ends at the makespan (non-OOM run).
        if let Some(last) = a.path.last() {
            assert!((last.end - r.makespan).abs() <= eps);
        }
    });
}

#[test]
fn prop_explain_off_responses_bit_identical_for_every_registered_placer() {
    use baechi::engine::{PlacementEngine, PlacementRequest, PlacerRegistry};

    let dir = std::env::temp_dir().join(format!("baechi-explain-prop-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // `rl` with default episodes is too slow for a property loop; pin a
    // small budget (the identity must hold for any spec of it).
    let specs: Vec<String> = PlacerRegistry::with_builtins()
        .names()
        .into_iter()
        .map(|n| if n == "rl" { "rl:10".to_string() } else { n })
        .collect();

    prop_check("explain_identity", 8, |rng| {
        let g = random_dag(rng, 25);
        for spec in &specs {
            let plain = PlacementEngine::builder()
                .cluster(unit_cluster(3, 1 << 30))
                .build()
                .unwrap();
            let explained = PlacementEngine::builder()
                .cluster(unit_cluster(3, 1 << 30))
                .run_history(dir.join(format!("{spec}.jsonl")).display().to_string(), 1 << 20)
                .build()
                .unwrap();
            let req = PlacementRequest::new(g.clone(), spec);
            let a = plain.place(&req);
            let scope = record_decisions();
            let b = explained.place(&req);
            let _log = scope.finish();
            match (a, b) {
                (Ok(a), Ok(b)) => {
                    // Explain must be purely observational: same
                    // placement, same simulation, bit for bit.
                    assert_eq!(a.placement.device_of, b.placement.device_of, "{spec}");
                    assert_eq!(
                        a.placement.predicted_makespan.to_bits(),
                        b.placement.predicted_makespan.to_bits(),
                        "{spec}"
                    );
                    assert_eq!(a.devices_used, b.devices_used, "{spec}");
                    let (sa, sb) = (a.sim.as_ref().unwrap(), b.sim.as_ref().unwrap());
                    assert_eq!(sa.makespan.to_bits(), sb.makespan.to_bits(), "{spec}");
                    assert_eq!(sa.peak_memory, sb.peak_memory, "{spec}");
                }
                // The expert refuses graphs with no benchmark identity —
                // identically on both sides.
                (Err(ea), Err(eb)) => assert_eq!(ea.to_string(), eb.to_string(), "{spec}"),
                (a, b) => panic!("{spec}: divergent outcomes: {a:?} vs {b:?}"),
            }
        }
    });
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prop_run_record_jsonl_round_trips() {
    prop_check("run_record_roundtrip", 200, |rng| {
        let modes = ["full", "cache_hit", "incremental"];
        let placers = ["m-sct", "m-etf", "hier:32", "rl:200"];
        let makespan = rng.chance(0.7).then(|| rng.uniform(1e-6, 1e3));
        let rec = RunRecord {
            schema: RUN_RECORD_SCHEMA,
            graph: format!("g{}", rng.below(1000)),
            placer: rng.choose(&placers).to_string(),
            coarsening: rng.chance(0.5).then(|| format!("members:{}", rng.below(64))),
            serve_mode: rng.choose(&modes).to_string(),
            ops: rng.below(1 << 20),
            edges: rng.below(1 << 21),
            devices: rng.range(1, 64) as u64,
            total_compute: rng.uniform(0.0, 1e6),
            total_permanent_memory: rng.below(1 << 40),
            total_edge_bytes: rng.below(1 << 40),
            makespan,
            attribution: makespan.map(|m| AttributionTotals {
                compute: rng.uniform(0.0, m),
                transfer: rng.uniform(0.0, m),
                queue_wait: rng.uniform(0.0, m),
                idle: rng.uniform(0.0, m),
            }),
        };
        // Rust's f64 Display prints shortest-round-trip digits, so the
        // JSONL line reconstructs every field exactly.
        let back = RunRecord::parse_line(&rec.to_line()).unwrap();
        assert_eq!(back, rec);
    });
}

#[test]
fn run_explained_reports_decisions_and_attribution_end_to_end() {
    use baechi::coordinator::{run_explained, BaechiConfig, PlacerKind};
    use baechi::models::Benchmark;

    let cfg = BaechiConfig::paper_default(Benchmark::Mlp, PlacerKind::MSct);
    let er = run_explained(&cfg).unwrap();
    assert!(er.report.sim.ok());
    // The attribution explains exactly the simulated makespan.
    assert_eq!(
        er.attribution.makespan.to_bits(),
        er.report.sim.makespan.to_bits()
    );
    let eps = 1e-9 * er.attribution.makespan.abs().max(1.0);
    assert!(er.attribution.residual().abs() <= eps);
    assert!(!er.attribution.path.is_empty());
    // m-SCT records one decision per placed op.
    assert!(!er.decisions.decisions.is_empty());
    let placed: usize = er.decisions.counts_by_reason().iter().map(|(_, n)| n).sum();
    assert_eq!(placed, er.decisions.decisions.len());
    for d in &er.decisions.decisions {
        assert!(!d.candidates.is_empty(), "decision without candidates");
        assert!(
            d.candidates.iter().any(|c| c.device == d.chosen),
            "chosen device is not among the candidates"
        );
    }
    // The combined JSON report carries both pillars.
    let j = er.to_json(5);
    assert!(j.get("attribution").is_some());
    let decisions = j
        .get("decisions")
        .and_then(|d| d.get("decisions"))
        .unwrap();
    assert_eq!(
        decisions.as_arr().unwrap().len(),
        er.decisions.decisions.len()
    );
}
