//! `baechi serve-bench`: drive the [`PlacementService`] with a sustained
//! closed-loop stream of mutated benchmark graphs and report serving
//! metrics (placements/sec, latency percentiles, cache hit rate,
//! incremental-vs-full split).

use super::config::BaechiConfig;
use crate::engine::{PlacementEngine, PlacementRequest, DEFAULT_CACHE_CAPACITY};
use crate::error::BaechiError;
use crate::graph::delta::{mutate, MutationSpec};
use crate::graph::OpGraph;
use crate::serve::{PlacementService, ServiceConfig, ServiceMetrics};
use crate::telemetry::{chrome_trace, MetricsServer};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use std::sync::Arc;
use std::time::Instant;

/// Knobs of one serving-bench run.
#[derive(Debug, Clone)]
pub struct ServeBenchOpts {
    /// Total requests in the stream.
    pub requests: usize,
    /// Closed-loop client threads (each submits its slice and waits).
    pub clients: usize,
    /// Probability a request's graph mutates away from the previous one
    /// (0 = the same graph repeated, 1 = every request is a new version).
    pub mutation_rate: f64,
    /// Engine placement-cache shard count.
    pub cache_shards: usize,
    /// Engine placement-cache capacity (cost units).
    pub cache_capacity: u64,
    /// Service worker threads.
    pub workers: usize,
    /// Enable the incremental (delta) placement path.
    pub incremental: bool,
    /// Stream RNG seed (the stream is deterministic given the seed).
    pub seed: u64,
    /// Collect telemetry spans and return the Chrome trace-event JSON
    /// of the whole run in [`ServeBenchReport::trace`].
    pub trace: bool,
    /// Serve Prometheus metrics over HTTP at this address for the
    /// duration of the bench (e.g. `"127.0.0.1:9184"`).
    pub metrics_addr: Option<String>,
}

impl Default for ServeBenchOpts {
    fn default() -> ServeBenchOpts {
        ServeBenchOpts {
            requests: 200,
            clients: 4,
            mutation_rate: 0.3,
            cache_shards: 8,
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            workers: 2,
            incremental: true,
            seed: 0xbaec1,
            trace: false,
            metrics_addr: None,
        }
    }
}

/// Result of [`run_serve_bench`].
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    pub benchmark: String,
    pub placer: String,
    pub requests: usize,
    /// Wall-clock of the whole stream, seconds.
    pub wall_s: f64,
    /// Completed placements per wall-clock second.
    pub placements_per_sec: f64,
    pub metrics: ServiceMetrics,
    /// Chrome trace-event JSON of the run's telemetry spans
    /// (`opts.trace`; deliberately not folded into [`Self::to_json`] —
    /// the CLI writes it to its own file).
    pub trace: Option<Json>,
}

impl ServeBenchReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("benchmark", self.benchmark.as_str())
            .set("placer", self.placer.as_str())
            .set("requests", self.requests)
            .set("wall_s", self.wall_s)
            .set("placements_per_sec", self.placements_per_sec)
            .set("metrics", self.metrics.to_json());
        j
    }
}

/// Deterministic request stream: a graph version chain where each request
/// either repeats the current version or mutates it by one small delta.
/// This is the serving workload the ROADMAP names — users iterating on
/// models, most requests near-duplicates.
pub fn request_stream(base: &OpGraph, n: usize, mutation_rate: f64, seed: u64) -> Vec<OpGraph> {
    let mut rng = Pcg::seed(seed);
    let spec = MutationSpec::small();
    let mut current = base.clone();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.chance(mutation_rate) {
            mutate(&mut current, &mut rng, &spec);
        }
        out.push(current.clone());
    }
    out
}

/// Build the engine + service described by `cfg`/`opts`, run the stream
/// through closed-loop clients, and report.
pub fn run_serve_bench(
    cfg: &BaechiConfig,
    opts: &ServeBenchOpts,
) -> crate::Result<ServeBenchReport> {
    let mut builder = PlacementEngine::builder()
        .cluster(cfg.cluster()?)
        .optimizer(cfg.opt)
        .sim(cfg.sim)
        .cache_shards(opts.cache_shards)
        .cache_capacity(opts.cache_capacity);
    if opts.trace {
        builder = builder.tracing(true);
    }
    let engine = Arc::new(builder.build()?);
    let mut scfg = ServiceConfig::default();
    scfg.workers = opts.workers.max(1);
    scfg.incremental.enabled = opts.incremental;
    let service = Arc::new(PlacementService::new(Arc::clone(&engine), scfg)?);
    // Live Prometheus endpoint for the duration of the bench; dropped
    // (and joined) when this function returns.
    let _metrics_server = match &opts.metrics_addr {
        Some(addr) => {
            let svc = Arc::clone(&service);
            let server = MetricsServer::bind(addr, move || svc.metrics_text())?;
            crate::util::log::log(
                crate::util::log::Level::Info,
                format_args!("serving metrics at http://{}/metrics", server.addr()),
            );
            Some(server)
        }
        None => None,
    };

    let stream = request_stream(&cfg.benchmark.graph(), opts.requests, opts.mutation_rate, opts.seed);
    let placer = cfg.placer.spec();
    let clients = opts.clients.max(1);
    let chunk = (stream.len() + clients - 1) / clients.max(1);

    let t0 = Instant::now();
    std::thread::scope(|s| -> crate::Result<()> {
        let service = &service;
        let placer = placer.as_str();
        let handles: Vec<_> = stream
            .chunks(chunk.max(1))
            .map(|slice| {
                s.spawn(move || -> crate::Result<()> {
                    for g in slice {
                        service.place(PlacementRequest::new(g.clone(), placer))?;
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            h.join()
                .map_err(|_| BaechiError::runtime("serve-bench client panicked"))??;
        }
        Ok(())
    })?;
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = service.metrics();
    let trace = opts
        .trace
        .then(|| chrome_trace(&engine.tracer().drain(), None));
    Ok(ServeBenchReport {
        benchmark: cfg.benchmark.name(),
        placer: cfg.placer.spec(),
        requests: opts.requests,
        wall_s,
        placements_per_sec: metrics.completed as f64 / wall_s.max(1e-9),
        metrics,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlacerKind;
    use crate::models::Benchmark;

    #[test]
    fn serve_bench_small_stream_reports() {
        let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        let opts = ServeBenchOpts {
            requests: 24,
            clients: 2,
            mutation_rate: 0.3,
            workers: 2,
            ..ServeBenchOpts::default()
        };
        let r = run_serve_bench(&cfg, &opts).unwrap();
        assert_eq!(r.metrics.completed, 24);
        assert_eq!(r.metrics.errors, 0);
        assert!(r.metrics.cache_hit_rate() > 0.0, "repeats must hit: {:?}", r.metrics);
        assert!(r.placements_per_sec > 0.0);
        let j = r.to_json();
        assert!(j.get("metrics").and_then(|m| m.get("qps")).is_some());
    }

    #[test]
    fn request_stream_is_deterministic_and_mutates() {
        let base = Benchmark::LinReg.graph();
        let a = request_stream(&base, 16, 0.5, 7);
        let b = request_stream(&base, 16, 0.5, 7);
        assert_eq!(a.len(), 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                crate::engine::fingerprint::graph_fingerprint(x),
                crate::engine::fingerprint::graph_fingerprint(y)
            );
        }
        let first = crate::engine::fingerprint::graph_fingerprint(&a[0]);
        assert!(
            a.iter()
                .any(|g| crate::engine::fingerprint::graph_fingerprint(g) != first),
            "rate 0.5 over 16 requests must mutate at least once"
        );
    }
}
