//! The Baechi coordinator: the full profile → optimize → place →
//! evaluate pipeline behind the CLI, examples, and benches (paper Fig. 6
//! system architecture). A thin wrapper over
//! [`crate::engine::PlacementEngine`] since the service-API redesign.

pub mod config;
pub mod pipeline;
pub mod serve;

pub use config::{BaechiConfig, CalibrationSpec, PlacerKind, TopologySpec};
pub use pipeline::{
    engine_for, run, run_explained, run_traced, ExplainReport, ReplacementSummary, RunReport,
};
pub use serve::{run_serve_bench, ServeBenchOpts, ServeBenchReport};
