//! Coordinator configuration: which benchmark, cluster, optimizer and
//! placement algorithm to run.
//!
//! [`PlacerKind`] is kept as a thin compatibility shim over the
//! [`PlacerRegistry`](crate::engine::PlacerRegistry): it enumerates the
//! built-in placers for CLI parsing and table iteration, and `build`
//! delegates to the registry. New placement strategies should register
//! with the engine directly instead of growing this enum.

use crate::engine::PlacerRegistry;
use crate::error::BaechiError;
use crate::models::Benchmark;
use crate::optimizer::OptConfig;
use crate::placer::Placer;
use crate::profile::{Cluster, CommModel};
use crate::sim::{Framework, SimConfig};

/// Selection of a built-in placement algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacerKind {
    Single,
    Expert,
    MTopo,
    MEtf,
    MSct,
    /// m-SCT with the greedy favorite-child heuristic (ablation).
    MSctHeuristic,
    /// m-SCT forced onto the LP favorite-child path (ablation).
    MSctLp,
    /// REINFORCE baseline with this many episodes.
    Rl { episodes: usize },
}

impl PlacerKind {
    pub fn parse(s: &str) -> crate::Result<PlacerKind> {
        Ok(match s {
            "single" => PlacerKind::Single,
            "expert" => PlacerKind::Expert,
            "m-topo" | "mtopo" => PlacerKind::MTopo,
            "m-etf" | "metf" => PlacerKind::MEtf,
            "m-sct" | "msct" => PlacerKind::MSct,
            "m-sct-heur" => PlacerKind::MSctHeuristic,
            "m-sct-lp" => PlacerKind::MSctLp,
            s if s.starts_with("rl") => {
                let episodes = s
                    .strip_prefix("rl:")
                    .and_then(|e| e.parse().ok())
                    .unwrap_or(200);
                PlacerKind::Rl { episodes }
            }
            other => {
                return Err(BaechiError::UnknownPlacer {
                    name: other.to_string(),
                    known: PlacerRegistry::with_builtins().names(),
                })
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacerKind::Single => "single-gpu",
            PlacerKind::Expert => "expert",
            PlacerKind::MTopo => "m-topo",
            PlacerKind::MEtf => "m-etf",
            PlacerKind::MSct => "m-sct",
            PlacerKind::MSctHeuristic => "m-sct-heur",
            PlacerKind::MSctLp => "m-sct-lp",
            PlacerKind::Rl { .. } => "rl",
        }
    }

    /// The registry spec this kind resolves through (e.g. `"rl:200"`).
    pub fn spec(&self) -> String {
        match self {
            PlacerKind::Single => "single".to_string(),
            PlacerKind::Expert => "expert".to_string(),
            PlacerKind::MTopo => "m-topo".to_string(),
            PlacerKind::MEtf => "m-etf".to_string(),
            PlacerKind::MSct => "m-sct".to_string(),
            PlacerKind::MSctHeuristic => "m-sct-heur".to_string(),
            PlacerKind::MSctLp => "m-sct-lp".to_string(),
            PlacerKind::Rl { episodes } => format!("rl:{episodes}"),
        }
    }

    /// Instantiate the placer through the built-in registry (the expert
    /// needs the benchmark identity).
    pub fn build(&self, benchmark: Benchmark) -> Box<dyn Placer> {
        PlacerRegistry::with_builtins()
            .resolve(&self.spec(), Some(benchmark))
            .expect("built-in placers always resolve")
            .placer
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct BaechiConfig {
    pub benchmark: Benchmark,
    pub placer: PlacerKind,
    pub devices: usize,
    /// Bytes per device before the fraction cap.
    pub device_memory: u64,
    /// Table 5's memory fraction (1.0 = sufficient memory).
    pub memory_fraction: f64,
    pub opt: OptConfig,
    pub comm: CommModel,
    pub sequential_comm: bool,
    pub sim: SimConfig,
}

impl BaechiConfig {
    /// The paper's testbed defaults: 4 × 8 GB GPUs over host-mediated
    /// PCIe, TF memory semantics.
    pub fn paper_default(benchmark: Benchmark, placer: PlacerKind) -> BaechiConfig {
        let framework = match benchmark {
            Benchmark::InceptionV3 { .. } | Benchmark::Gnmt { .. } | Benchmark::LinReg => {
                Framework::TensorFlow
            }
            Benchmark::Transformer { .. } | Benchmark::Mlp => Framework::PyTorch,
        };
        let comm = CommModel::pcie_via_host();
        BaechiConfig {
            benchmark,
            placer,
            devices: 4,
            device_memory: 8 << 30,
            memory_fraction: 1.0,
            opt: OptConfig {
                // price multi-tensor fused edges consistently with the ES
                latency_equiv_bytes: (comm.latency * comm.bandwidth) as u64,
                ..OptConfig::default()
            },
            comm,
            sequential_comm: true,
            sim: SimConfig {
                framework,
                overlap_comm: true,
            },
        }
    }

    pub fn with_memory_fraction(mut self, f: f64) -> BaechiConfig {
        self.memory_fraction = f;
        self
    }

    pub fn with_opt(mut self, opt: OptConfig) -> BaechiConfig {
        self.opt = opt;
        self
    }

    pub fn cluster(&self) -> Cluster {
        Cluster::homogeneous(self.devices, self.device_memory, self.comm)
            .with_memory_fraction(self.memory_fraction)
            .with_sequential_comm(self.sequential_comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placer_parse_roundtrip() {
        assert_eq!(PlacerKind::parse("single").unwrap(), PlacerKind::Single);
        assert_eq!(PlacerKind::parse("expert").unwrap(), PlacerKind::Expert);
        assert_eq!(PlacerKind::parse("m-topo").unwrap(), PlacerKind::MTopo);
        assert_eq!(PlacerKind::parse("m-etf").unwrap(), PlacerKind::MEtf);
        assert_eq!(PlacerKind::parse("m-sct").unwrap(), PlacerKind::MSct);
        assert_eq!(
            PlacerKind::parse("m-sct-heur").unwrap(),
            PlacerKind::MSctHeuristic
        );
        assert_eq!(
            PlacerKind::parse("rl:50").unwrap(),
            PlacerKind::Rl { episodes: 50 }
        );
        assert!(PlacerKind::parse("nope").is_err());
    }

    #[test]
    fn parse_rejects_unknown_with_typed_error() {
        match PlacerKind::parse("nope") {
            Err(BaechiError::UnknownPlacer { name, known }) => {
                assert_eq!(name, "nope");
                assert!(known.contains(&"m-etf".to_string()));
            }
            other => panic!("expected UnknownPlacer, got {other:?}"),
        }
    }

    #[test]
    fn spec_round_trips_through_registry() {
        let registry = PlacerRegistry::with_builtins();
        for kind in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
            PlacerKind::MSctHeuristic,
            PlacerKind::MSctLp,
            PlacerKind::Rl { episodes: 5 },
        ] {
            let resolved = registry
                .resolve(&kind.spec(), Some(Benchmark::Mlp))
                .unwrap_or_else(|e| panic!("{}: {e}", kind.spec()));
            // The shim and the registry agree on the algorithm.
            let built = kind.build(Benchmark::Mlp);
            assert_eq!(resolved.placer.name(), built.name(), "{}", kind.spec());
        }
    }

    #[test]
    fn paper_default_cluster() {
        let c = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf)
            .with_memory_fraction(0.3)
            .cluster();
        assert_eq!(c.n(), 4);
        assert_eq!(c.devices[0].memory, (8u64 << 30) * 3 / 10);
        assert!(c.sequential_comm);
    }
}
