//! Coordinator configuration: which benchmark, cluster, optimizer and
//! placement algorithm to run.
//!
//! [`PlacerKind`] is kept as a thin compatibility shim over the
//! [`PlacerRegistry`](crate::engine::PlacerRegistry): it enumerates the
//! built-in placers for CLI parsing and table iteration, and `build`
//! delegates to the registry. New placement strategies should register
//! with the engine directly instead of growing this enum.

use crate::calibrate::{
    calibrate, CalibratedCluster, CalibrationPlan, RuntimeSource, SyntheticSource,
};
use crate::engine::PlacerRegistry;
use crate::error::BaechiError;
use crate::feedback::ReplacementPolicy;
use crate::models::Benchmark;
use crate::optimizer::OptConfig;
use crate::placer::Placer;
use crate::profile::{Cluster, CommModel};
use crate::sim::{Framework, SimConfig};
use crate::topology::{json as topo_json, Topology};

/// Selection of a built-in placement algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacerKind {
    Single,
    Expert,
    MTopo,
    MEtf,
    MSct,
    /// m-SCT with the greedy favorite-child heuristic (ablation).
    MSctHeuristic,
    /// m-SCT forced onto the LP favorite-child path (ablation).
    MSctLp,
    /// REINFORCE baseline with this many episodes.
    Rl { episodes: usize },
    /// Hierarchical coarsen→place→refine for very large graphs.
    /// `max_members == 0` keeps the default super-op size cap.
    Hier { enabled: bool, max_members: usize },
}

impl PlacerKind {
    pub fn parse(s: &str) -> crate::Result<PlacerKind> {
        Ok(match s {
            "single" => PlacerKind::Single,
            "expert" => PlacerKind::Expert,
            "m-topo" | "mtopo" => PlacerKind::MTopo,
            "m-etf" | "metf" => PlacerKind::MEtf,
            "m-sct" | "msct" => PlacerKind::MSct,
            "m-sct-heur" => PlacerKind::MSctHeuristic,
            "m-sct-lp" => PlacerKind::MSctLp,
            s if s.starts_with("rl") => {
                let episodes = s
                    .strip_prefix("rl:")
                    .and_then(|e| e.parse().ok())
                    .unwrap_or(200);
                PlacerKind::Rl { episodes }
            }
            "hier:off" => PlacerKind::Hier {
                enabled: false,
                max_members: 0,
            },
            s if s.starts_with("hier") => {
                let max_members = s
                    .strip_prefix("hier:")
                    .and_then(|e| e.parse().ok())
                    .unwrap_or(0);
                PlacerKind::Hier {
                    enabled: true,
                    max_members,
                }
            }
            other => {
                return Err(BaechiError::UnknownPlacer {
                    name: other.to_string(),
                    known: PlacerRegistry::with_builtins().names(),
                })
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacerKind::Single => "single-gpu",
            PlacerKind::Expert => "expert",
            PlacerKind::MTopo => "m-topo",
            PlacerKind::MEtf => "m-etf",
            PlacerKind::MSct => "m-sct",
            PlacerKind::MSctHeuristic => "m-sct-heur",
            PlacerKind::MSctLp => "m-sct-lp",
            PlacerKind::Rl { .. } => "rl",
            PlacerKind::Hier { .. } => "hier",
        }
    }

    /// The registry spec this kind resolves through (e.g. `"rl:200"`).
    pub fn spec(&self) -> String {
        match self {
            PlacerKind::Single => "single".to_string(),
            PlacerKind::Expert => "expert".to_string(),
            PlacerKind::MTopo => "m-topo".to_string(),
            PlacerKind::MEtf => "m-etf".to_string(),
            PlacerKind::MSct => "m-sct".to_string(),
            PlacerKind::MSctHeuristic => "m-sct-heur".to_string(),
            PlacerKind::MSctLp => "m-sct-lp".to_string(),
            PlacerKind::Rl { episodes } => format!("rl:{episodes}"),
            PlacerKind::Hier {
                enabled: false, ..
            } => "hier:off".to_string(),
            PlacerKind::Hier { max_members: 0, .. } => "hier".to_string(),
            PlacerKind::Hier { max_members, .. } => format!("hier:{max_members}"),
        }
    }

    /// Instantiate the placer through the built-in registry (the expert
    /// needs the benchmark identity).
    pub fn build(&self, benchmark: Benchmark) -> Box<dyn Placer> {
        PlacerRegistry::with_builtins()
            .resolve(&self.spec(), Some(benchmark))
            .expect("built-in placers always resolve")
            .placer
    }
}

/// How the run's interconnect topology is obtained (`--topology`).
///
/// * `uniform` — the paper's single-model cluster (default);
/// * `nvlink-islands:<island>[:<ratio>]` — NVLink islands of `<island>`
///   devices over the configured PCIe model, intra-island bandwidth
///   `<ratio>`× the inter model (default 8×);
/// * `two-tier:<nodes>[:<ratio>]` — `<nodes>` machines whose NIC trunks
///   run at `1/<ratio>` of the intra model (default 4×);
/// * `<path>.json` — arbitrary link graph, schema in
///   [`crate::topology::json`].
///
/// Malformed specs are [`BaechiError::InvalidRequest`], never panics.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologySpec {
    Uniform,
    NvlinkIslands { island: usize, ratio: f64 },
    TwoTier { nodes: usize, ratio: f64 },
    File(String),
}

impl TopologySpec {
    pub fn parse(s: &str) -> crate::Result<TopologySpec> {
        fn tail(s: &str, what: &str) -> crate::Result<(usize, f64)> {
            let mut parts = s.split(':');
            let count: usize = parts
                .next()
                .and_then(|p| p.parse().ok())
                .filter(|&c| c > 0)
                .ok_or_else(|| {
                    BaechiError::invalid(format!("topology: '{s}' needs a positive {what}"))
                })?;
            let ratio: f64 = match parts.next() {
                None => return Ok((count, 0.0)), // caller's default
                Some(r) => r.parse().ok().filter(|r| *r >= 1.0).ok_or_else(|| {
                    BaechiError::invalid(format!("topology: ratio in '{s}' must be ≥ 1"))
                })?,
            };
            if parts.next().is_some() {
                return Err(BaechiError::invalid(format!(
                    "topology: too many ':' fields in '{s}'"
                )));
            }
            Ok((count, ratio))
        }
        match s {
            "uniform" => Ok(TopologySpec::Uniform),
            _ if s.ends_with(".json") => Ok(TopologySpec::File(s.to_string())),
            _ => {
                if let Some(rest) = s.strip_prefix("nvlink-islands:") {
                    let (island, ratio) = tail(rest, "island size")?;
                    Ok(TopologySpec::NvlinkIslands {
                        island,
                        ratio: if ratio == 0.0 { 8.0 } else { ratio },
                    })
                } else if let Some(rest) = s.strip_prefix("two-tier:") {
                    let (nodes, ratio) = tail(rest, "machine count")?;
                    Ok(TopologySpec::TwoTier {
                        nodes,
                        ratio: if ratio == 0.0 { 4.0 } else { ratio },
                    })
                } else {
                    Err(BaechiError::invalid(format!(
                        "unknown topology '{s}' \
                         (uniform | nvlink-islands:<island>[:<ratio>] | \
                         two-tier:<nodes>[:<ratio>] | <path>.json)"
                    )))
                }
            }
        }
    }

    /// Build the topology for an `n`-device cluster whose baseline
    /// interconnect is `comm`. `Ok(None)` keeps the cluster's default
    /// uniform topology.
    pub fn build(&self, n: usize, comm: CommModel) -> crate::Result<Option<Topology>> {
        match self {
            TopologySpec::Uniform => Ok(None),
            TopologySpec::NvlinkIslands { island, ratio } => {
                let intra = CommModel::new(comm.latency / ratio, comm.bandwidth * ratio)?;
                Topology::nvlink_islands(n, *island, intra, comm).map(Some)
            }
            TopologySpec::TwoTier { nodes, ratio } => {
                if n % nodes != 0 {
                    return Err(BaechiError::invalid(format!(
                        "two-tier topology: {n} devices do not split into {nodes} machines"
                    )));
                }
                let inter = CommModel::new(comm.latency * ratio, comm.bandwidth / ratio)?;
                Topology::two_tier(*nodes, n / nodes, comm, inter).map(Some)
            }
            TopologySpec::File(path) => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    BaechiError::invalid(format!("topology file {path}: {e}"))
                })?;
                let t = topo_json::from_json_str(&text)?;
                if t.n() != n {
                    return Err(BaechiError::invalid(format!(
                        "topology file {path} describes {} devices, the run uses {n}",
                        t.n()
                    )));
                }
                Ok(Some(t))
            }
        }
    }
}

/// How the run obtains its cluster model (`--calibrate`): hand-specified
/// (`off`, the default — the [`TopologySpec`] is used as-is), measured
/// from a deterministic synthetic replay of that topology
/// (`synthetic[:noise]`, seeded — what CI runs), measured from the real
/// host (`runtime`), or loaded from a saved
/// [`CalibratedCluster`] artifact (`<path>.json`).
#[derive(Debug, Clone, PartialEq)]
pub enum CalibrationSpec {
    Off,
    Synthetic { noise: f64 },
    Runtime,
    File(String),
}

/// Seed for `--calibrate synthetic` runs: fixed so CLI runs are
/// reproducible (the property tests sweep seeds instead).
const SYNTHETIC_CALIBRATION_SEED: u64 = 0xbaec1;

impl CalibrationSpec {
    pub fn parse(s: &str) -> crate::Result<CalibrationSpec> {
        match s {
            "off" => Ok(CalibrationSpec::Off),
            "runtime" => Ok(CalibrationSpec::Runtime),
            "synthetic" => Ok(CalibrationSpec::Synthetic { noise: 0.0 }),
            _ if s.ends_with(".json") => Ok(CalibrationSpec::File(s.to_string())),
            _ => {
                if let Some(rest) = s.strip_prefix("synthetic:") {
                    let noise: f64 = rest
                        .parse()
                        .ok()
                        .filter(|n: &f64| n.is_finite() && *n >= 0.0)
                        .ok_or_else(|| {
                            BaechiError::invalid(format!(
                                "calibrate: noise in '{s}' must be a non-negative number"
                            ))
                        })?;
                    Ok(CalibrationSpec::Synthetic { noise })
                } else {
                    Err(BaechiError::invalid(format!(
                        "unknown calibration source '{s}' \
                         (off | synthetic[:<noise>] | runtime | <artifact>.json)"
                    )))
                }
            }
        }
    }

    /// Run this calibration for an `n`-device cluster. `truth` lazily
    /// builds the hand-specified topology the synthetic source replays —
    /// it is only invoked (and its errors only surface) for
    /// [`CalibrationSpec::Synthetic`]; runtime probes and saved
    /// artifacts never need (or validate) a hand-specified ground
    /// truth. `Ok(None)` when calibration is off.
    pub fn run(
        &self,
        n: usize,
        truth: impl FnOnce() -> crate::Result<Topology>,
    ) -> crate::Result<Option<CalibratedCluster>> {
        let plan = CalibrationPlan::default();
        match self {
            CalibrationSpec::Off => Ok(None),
            CalibrationSpec::Synthetic { noise } => {
                let mut src =
                    SyntheticSource::new(truth()?, *noise, SYNTHETIC_CALIBRATION_SEED)?;
                calibrate(&mut src, &plan).map(Some)
            }
            CalibrationSpec::Runtime => {
                let mut src = RuntimeSource::new(n)?;
                calibrate(&mut src, &plan).map(Some)
            }
            CalibrationSpec::File(path) => CalibratedCluster::load(path).map(Some),
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct BaechiConfig {
    pub benchmark: Benchmark,
    pub placer: PlacerKind,
    pub devices: usize,
    /// Bytes per device before the fraction cap.
    pub device_memory: u64,
    /// Table 5's memory fraction (1.0 = sufficient memory).
    pub memory_fraction: f64,
    pub opt: OptConfig,
    pub comm: CommModel,
    pub sequential_comm: bool,
    pub sim: SimConfig,
    /// Interconnect topology (`TopologySpec::Uniform` = the paper's
    /// single-model cluster).
    pub topology: TopologySpec,
    /// Cluster-model calibration (`--calibrate`): when not `Off`, the
    /// hand-specified topology is replaced by a measured one (for the
    /// synthetic source it doubles as the ground truth being measured).
    pub calibrate: CalibrationSpec,
    /// Contention-driven re-placement rounds (`--replace-rounds`;
    /// 0 = single-shot placement, the paper's behavior).
    pub replace_rounds: usize,
    /// Link-utilization trigger for re-placement
    /// (`--replace-threshold`).
    pub replace_threshold: f64,
}

impl BaechiConfig {
    /// The paper's testbed defaults: 4 × 8 GB GPUs over host-mediated
    /// PCIe, TF memory semantics.
    pub fn paper_default(benchmark: Benchmark, placer: PlacerKind) -> BaechiConfig {
        let framework = match benchmark {
            Benchmark::InceptionV3 { .. }
            | Benchmark::Gnmt { .. }
            | Benchmark::LinReg
            | Benchmark::Synthetic { .. } => Framework::TensorFlow,
            Benchmark::Transformer { .. } | Benchmark::Mlp => Framework::PyTorch,
        };
        let comm = CommModel::pcie_via_host();
        BaechiConfig {
            benchmark,
            placer,
            devices: 4,
            device_memory: 8 << 30,
            memory_fraction: 1.0,
            opt: OptConfig {
                // price multi-tensor fused edges consistently with the ES
                latency_equiv_bytes: (comm.latency * comm.bandwidth) as u64,
                ..OptConfig::default()
            },
            comm,
            sequential_comm: true,
            sim: SimConfig {
                framework,
                overlap_comm: true,
                ..SimConfig::default()
            },
            topology: TopologySpec::Uniform,
            calibrate: CalibrationSpec::Off,
            replace_rounds: 0,
            replace_threshold: 0.5,
        }
    }

    pub fn with_memory_fraction(mut self, f: f64) -> BaechiConfig {
        self.memory_fraction = f;
        self
    }

    pub fn with_opt(mut self, opt: OptConfig) -> BaechiConfig {
        self.opt = opt;
        self
    }

    /// The re-placement policy this config asks for; `None` keeps the
    /// single-shot pipeline. The CLI exposes one sensitivity knob, so
    /// the secondary blocked-seconds trigger scales with the threshold
    /// (0.5 maps to the policy's 0.05 default) — a high
    /// `--replace-threshold` genuinely suppresses re-placement instead
    /// of being overruled by the blocked-fraction default.
    pub fn replacement_policy(&self) -> Option<ReplacementPolicy> {
        (self.replace_rounds > 0).then(|| {
            let mut p = ReplacementPolicy::rounds(self.replace_rounds)
                .with_threshold(self.replace_threshold);
            p.blocked_fraction = self.replace_threshold * 0.1;
            p
        })
    }

    /// The hand-specified topology this config describes (the uniform
    /// star when the spec is `uniform`) — what a synthetic calibration
    /// run measures as its ground truth.
    pub fn truth_topology(&self) -> crate::Result<Topology> {
        Ok(self
            .topology
            .build(self.devices, self.comm)?
            .unwrap_or_else(|| Topology::uniform(self.devices, self.comm)))
    }

    /// Run this config's calibration against its hand-specified
    /// topology as the ground truth. `Ok(None)` when `calibrate` is
    /// [`CalibrationSpec::Off`].
    pub fn calibrated(&self) -> crate::Result<Option<CalibratedCluster>> {
        self.calibrate.run(self.devices, || self.truth_topology())
    }

    /// Build the cluster this config describes, including calibration
    /// when requested (the measured topology replaces the hand-specified
    /// one). Fails with a typed [`BaechiError::InvalidRequest`] when the
    /// topology spec is malformed or does not match the device count.
    pub fn cluster(&self) -> crate::Result<Cluster> {
        self.cluster_with(self.calibrated()?.as_ref())
    }

    /// [`BaechiConfig::cluster`] with an already-run calibration (so one
    /// calibration serves both the engine and the run report).
    pub fn cluster_with(&self, cal: Option<&CalibratedCluster>) -> crate::Result<Cluster> {
        let base = Cluster::homogeneous(self.devices, self.device_memory, self.comm)
            .with_memory_fraction(self.memory_fraction)
            .with_sequential_comm(self.sequential_comm);
        if let Some(cal) = cal {
            return cal.apply_to(base);
        }
        match self.topology.build(self.devices, self.comm)? {
            Some(t) => base.with_topology(t),
            None => Ok(base),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placer_parse_roundtrip() {
        assert_eq!(PlacerKind::parse("single").unwrap(), PlacerKind::Single);
        assert_eq!(PlacerKind::parse("expert").unwrap(), PlacerKind::Expert);
        assert_eq!(PlacerKind::parse("m-topo").unwrap(), PlacerKind::MTopo);
        assert_eq!(PlacerKind::parse("m-etf").unwrap(), PlacerKind::MEtf);
        assert_eq!(PlacerKind::parse("m-sct").unwrap(), PlacerKind::MSct);
        assert_eq!(
            PlacerKind::parse("m-sct-heur").unwrap(),
            PlacerKind::MSctHeuristic
        );
        assert_eq!(
            PlacerKind::parse("rl:50").unwrap(),
            PlacerKind::Rl { episodes: 50 }
        );
        assert_eq!(
            PlacerKind::parse("hier").unwrap(),
            PlacerKind::Hier {
                enabled: true,
                max_members: 0
            }
        );
        assert_eq!(
            PlacerKind::parse("hier:128").unwrap(),
            PlacerKind::Hier {
                enabled: true,
                max_members: 128
            }
        );
        assert_eq!(
            PlacerKind::parse("hier:off").unwrap(),
            PlacerKind::Hier {
                enabled: false,
                max_members: 0
            }
        );
        assert_eq!(PlacerKind::parse("hier:128").unwrap().spec(), "hier:128");
        assert_eq!(PlacerKind::parse("hier:off").unwrap().spec(), "hier:off");
        assert!(PlacerKind::parse("nope").is_err());
    }

    #[test]
    fn parse_rejects_unknown_with_typed_error() {
        match PlacerKind::parse("nope") {
            Err(BaechiError::UnknownPlacer { name, known }) => {
                assert_eq!(name, "nope");
                assert!(known.contains(&"m-etf".to_string()));
            }
            other => panic!("expected UnknownPlacer, got {other:?}"),
        }
    }

    #[test]
    fn spec_round_trips_through_registry() {
        let registry = PlacerRegistry::with_builtins();
        for kind in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
            PlacerKind::MSctHeuristic,
            PlacerKind::MSctLp,
            PlacerKind::Rl { episodes: 5 },
            PlacerKind::Hier {
                enabled: true,
                max_members: 0,
            },
            PlacerKind::Hier {
                enabled: true,
                max_members: 16,
            },
            PlacerKind::Hier {
                enabled: false,
                max_members: 0,
            },
        ] {
            let resolved = registry
                .resolve(&kind.spec(), Some(Benchmark::Mlp))
                .unwrap_or_else(|e| panic!("{}: {e}", kind.spec()));
            // The shim and the registry agree on the algorithm.
            let built = kind.build(Benchmark::Mlp);
            assert_eq!(resolved.placer.name(), built.name(), "{}", kind.spec());
        }
    }

    #[test]
    fn paper_default_cluster() {
        let c = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf)
            .with_memory_fraction(0.3)
            .cluster()
            .unwrap();
        assert_eq!(c.n(), 4);
        assert_eq!(c.devices[0].memory, (8u64 << 30) * 3 / 10);
        assert!(c.sequential_comm);
        assert!(c.topology().is_uniform());
    }

    #[test]
    fn topology_spec_parse_and_build() {
        assert_eq!(TopologySpec::parse("uniform").unwrap(), TopologySpec::Uniform);
        assert_eq!(
            TopologySpec::parse("nvlink-islands:2").unwrap(),
            TopologySpec::NvlinkIslands { island: 2, ratio: 8.0 }
        );
        assert_eq!(
            TopologySpec::parse("nvlink-islands:2:16").unwrap(),
            TopologySpec::NvlinkIslands { island: 2, ratio: 16.0 }
        );
        assert_eq!(
            TopologySpec::parse("two-tier:2").unwrap(),
            TopologySpec::TwoTier { nodes: 2, ratio: 4.0 }
        );
        assert_eq!(
            TopologySpec::parse("cluster.json").unwrap(),
            TopologySpec::File("cluster.json".into())
        );
        for bad in ["mesh", "nvlink-islands:0", "nvlink-islands:2:0.5", "two-tier:2:1:9"] {
            assert!(
                matches!(TopologySpec::parse(bad), Err(BaechiError::InvalidRequest(_))),
                "{bad}"
            );
        }

        let comm = CommModel::pcie_via_host();
        let t = TopologySpec::parse("nvlink-islands:2")
            .unwrap()
            .build(4, comm)
            .unwrap()
            .unwrap();
        assert_eq!(t.n_islands(), 2);
        // Intra-island is 8× the inter bandwidth.
        assert!((t.pair(0, 1).bandwidth - comm.bandwidth * 8.0).abs() < 1.0);
        assert!(TopologySpec::Uniform.build(4, comm).unwrap().is_none());
        // Two-tier device count must divide.
        assert!(matches!(
            TopologySpec::TwoTier { nodes: 3, ratio: 4.0 }.build(4, comm),
            Err(BaechiError::InvalidRequest(_))
        ));
        // Missing file is typed, not a panic.
        assert!(matches!(
            TopologySpec::File("/nonexistent/topo.json".into()).build(4, comm),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn replacement_policy_follows_config() {
        let mut cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        assert!(cfg.replacement_policy().is_none(), "single-shot by default");
        cfg.replace_rounds = 2;
        cfg.replace_threshold = 0.7;
        let p = cfg.replacement_policy().unwrap();
        assert_eq!(p.max_rounds, 2);
        assert_eq!(p.trunk_utilization, 0.7);
        // Both triggers follow the CLI knob (0.5 → the 0.05 default).
        assert!((p.blocked_fraction - 0.07).abs() < 1e-12);
    }

    #[test]
    fn calibration_spec_parse() {
        assert_eq!(CalibrationSpec::parse("off").unwrap(), CalibrationSpec::Off);
        assert_eq!(
            CalibrationSpec::parse("synthetic").unwrap(),
            CalibrationSpec::Synthetic { noise: 0.0 }
        );
        assert_eq!(
            CalibrationSpec::parse("synthetic:0.05").unwrap(),
            CalibrationSpec::Synthetic { noise: 0.05 }
        );
        assert_eq!(
            CalibrationSpec::parse("runtime").unwrap(),
            CalibrationSpec::Runtime
        );
        assert_eq!(
            CalibrationSpec::parse("calib.json").unwrap(),
            CalibrationSpec::File("calib.json".into())
        );
        for bad in ["synthetic:-1", "synthetic:nan", "mesh", ""] {
            assert!(
                matches!(
                    CalibrationSpec::parse(bad),
                    Err(BaechiError::InvalidRequest(_))
                ),
                "{bad}"
            );
        }
        // Missing artifact file is typed, not a panic.
        assert!(matches!(
            CalibrationSpec::File("/nonexistent/calib.json".into())
                .run(4, || Ok(Topology::uniform(4, CommModel::pcie_via_host()))),
            Err(BaechiError::Io(_))
        ));
        // Non-synthetic sources never build (or fail on) the ground
        // truth — loading an artifact must not validate a topology that
        // is about to be replaced anyway.
        let err = CalibrationSpec::File("/nonexistent/calib.json".into())
            .run(4, || Err(BaechiError::invalid("truth must not be built")));
        assert!(matches!(err, Err(BaechiError::Io(_))), "{err:?}");
    }

    #[test]
    fn calibrated_cluster_replaces_hand_specified_topology() {
        let mut cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        cfg.topology = TopologySpec::TwoTier { nodes: 2, ratio: 8.0 };
        cfg.calibrate = CalibrationSpec::Synthetic { noise: 0.0 };
        let cal = cfg.calibrated().unwrap().expect("calibration ran");
        assert_eq!(cal.report.devices, 4);
        assert_eq!(cal.report.n_islands, 2, "{:?}", cal.report.warnings);
        assert!(cal.report.mean_rel_error < 0.05);
        let c = cfg.cluster().unwrap();
        // The cluster carries the *measured* topology (star through a
        // fitted core switch), not the hand-specified trunk graph.
        assert_eq!(c.topology(), &cal.topology);
        assert_eq!(c.topology().n_islands(), 2);
        // Off keeps the hand-specified one.
        cfg.calibrate = CalibrationSpec::Off;
        assert!(cfg.calibrated().unwrap().is_none());
        assert_ne!(cfg.cluster().unwrap().topology(), &cal.topology);
    }

    #[test]
    fn config_cluster_applies_topology() {
        let mut cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        cfg.topology = TopologySpec::NvlinkIslands { island: 2, ratio: 8.0 };
        let c = cfg.cluster().unwrap();
        assert!(!c.topology().is_uniform());
        assert_eq!(c.topology().n_islands(), 2);
        // 6 devices split into 3 machines; 4 do not.
        cfg.devices = 6;
        cfg.topology = TopologySpec::TwoTier { nodes: 3, ratio: 4.0 };
        assert!(cfg.cluster().is_ok());
        cfg.devices = 4;
        assert!(matches!(cfg.cluster(), Err(BaechiError::InvalidRequest(_))));
    }
}
