//! The end-to-end placement pipeline (paper Fig. 6):
//! graph generation → graph optimizer → placement algorithm → placement
//! expansion → execution-simulator evaluation.
//!
//! Since the `PlacementEngine` redesign this module is a thin wrapper:
//! [`run`] builds an engine for the config's cluster, issues one
//! [`PlacementRequest`](crate::engine::PlacementRequest), and reshapes
//! the response into the table-oriented [`RunReport`]. Anything that
//! needs more control (batching, caching, custom placers, observers)
//! should talk to [`crate::engine`] directly.

use super::config::BaechiConfig;
use crate::engine::{PlacementEngine, PlacementRequest};
use crate::graph::{DeviceId, NodeId};
use crate::sim::SimResult;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Everything a run produces (one row of the paper's tables).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub benchmark: String,
    pub placer: String,
    /// Ops in the original and optimized (placed) graphs (Table 6).
    pub original_ops: usize,
    pub placed_ops: usize,
    /// Placement wall-clock seconds (Table 3).
    pub placement_time: f64,
    /// Makespan predicted by the placer's internal schedule.
    pub predicted_makespan: f64,
    /// Step time from the execution simulator (Tables 4, 5, 7).
    pub sim: SimResult,
    /// Devices actually used.
    pub devices_used: usize,
    /// Peak memory per device from the simulator (Fig. 7).
    pub peak_memory: Vec<u64>,
    pub devices: usize,
    pub device_capacity: u64,
    /// The expanded placement itself (for DOT export and auditing).
    pub device_of: BTreeMap<NodeId, DeviceId>,
    /// Human summary of the cluster topology the run placed against.
    pub topology: String,
}

impl RunReport {
    pub fn step_time(&self) -> Option<f64> {
        self.sim.ok().then_some(self.sim.makespan)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("benchmark", self.benchmark.as_str())
            .set("placer", self.placer.as_str())
            .set("topology", self.topology.as_str())
            .set("original_ops", self.original_ops)
            .set("placed_ops", self.placed_ops)
            .set("placement_time_s", self.placement_time)
            .set("predicted_makespan_s", self.predicted_makespan)
            .set(
                "step_time_s",
                self.step_time().map(Json::from).unwrap_or(Json::Null),
            )
            .set("oom", self.sim.oom.is_some())
            .set("devices_used", self.devices_used)
            .set(
                "peak_memory",
                Json::Arr(self.peak_memory.iter().map(|&b| Json::from(b)).collect()),
            );
        j
    }
}

/// Build the [`PlacementEngine`] a config describes (without serving any
/// request). The CLI shares this so every entrypoint routes through one
/// engine construction path.
pub fn engine_for(cfg: &BaechiConfig) -> crate::Result<PlacementEngine> {
    PlacementEngine::builder()
        .cluster(cfg.cluster()?)
        .optimizer(cfg.opt)
        .sim(cfg.sim)
        .build()
}

/// Run the full pipeline through the engine. `Err` only for
/// infrastructure failures; placement OOM surfaces as
/// `Err(BaechiError::Oom { .. })` (the paper's m-* OOM rows), while
/// *runtime* OOM of a successful placement is reported in `sim.oom`.
pub fn run(cfg: &BaechiConfig) -> crate::Result<RunReport> {
    let engine = engine_for(cfg)?;
    let resp = engine.place(&PlacementRequest::for_benchmark(
        cfg.benchmark,
        &cfg.placer.spec(),
    ))?;
    let sim = resp
        .sim
        .clone()
        .expect("pipeline requests always simulate");
    Ok(RunReport {
        benchmark: cfg.benchmark.name(),
        placer: resp.placer.clone(),
        original_ops: resp.stats.original_ops,
        placed_ops: resp.stats.placed_ops,
        placement_time: resp.placement.placement_time,
        predicted_makespan: resp.placement.predicted_makespan,
        peak_memory: sim.peak_memory.clone(),
        devices_used: resp.devices_used,
        sim,
        devices: cfg.devices,
        device_capacity: engine.cluster().devices[0].memory,
        device_of: resp.placement.device_of.clone(),
        topology: engine.cluster().effective_topology().describe(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlacerKind;
    use crate::models::Benchmark;

    #[test]
    fn transformer_all_placers_sufficient_memory() {
        let b = Benchmark::Transformer { batch: 64 };
        let mut steps = std::collections::BTreeMap::new();
        for placer in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
        ] {
            let cfg = BaechiConfig::paper_default(b, placer);
            let r = run(&cfg).unwrap();
            assert!(r.sim.ok(), "{placer:?} OOM: {:?}", r.sim.oom);
            assert!(r.sim.makespan > 0.0);
            steps.insert(placer.name(), r.sim.makespan);
        }
        // paper Table 4 shape: m-ETF/m-SCT within ~±35 % of single.
        let single = steps["single-gpu"];
        for k in ["m-etf", "m-sct"] {
            let ratio = steps[k] / single;
            assert!(
                (0.4..=1.4).contains(&ratio),
                "{k} ratio {ratio} ({} vs {single})",
                steps[k]
            );
        }
    }

    #[test]
    fn mlp_insufficient_memory_single_ooms_msct_survives() {
        let b = Benchmark::Mlp;
        // Shrink devices until single can't hold the MLP (peak ≈ 1.05× the
        // permanent total) but each fused layer module plus its pinned
        // colocation group still fits one device.
        let total = b.graph().total_permanent_memory();
        let cfg = BaechiConfig {
            devices: 4,
            device_memory: total * 4 / 5,
            ..BaechiConfig::paper_default(b, PlacerKind::Single)
        };
        let single = run(&cfg).unwrap();
        assert!(!single.sim.ok(), "single must OOM at half memory");
        let cfg_sct = BaechiConfig {
            placer: PlacerKind::MSct,
            ..cfg
        };
        let sct = run(&cfg_sct).unwrap();
        assert!(sct.sim.ok(), "m-sct should place: {:?}", sct.sim.oom);
        assert!(sct.devices_used >= 2);
    }

    #[test]
    fn report_json_serializes() {
        let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        let r = run(&cfg).unwrap();
        let j = r.to_json();
        assert_eq!(j.get("placer").unwrap().as_str(), Some("m-etf"));
        assert!(Json::parse(&j.pretty()).is_ok());
    }
}
