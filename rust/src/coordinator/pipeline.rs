//! The end-to-end placement pipeline (paper Fig. 6):
//! graph generation → graph optimizer → placement algorithm → placement
//! expansion → execution-simulator evaluation.

use super::config::{BaechiConfig, PlacerKind};
use crate::optimizer;
use crate::sim::{self, SimResult};
use crate::util::json::Json;

/// Everything a run produces (one row of the paper's tables).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub benchmark: String,
    pub placer: String,
    /// Ops in the original and optimized (placed) graphs (Table 6).
    pub original_ops: usize,
    pub placed_ops: usize,
    /// Placement wall-clock seconds (Table 3).
    pub placement_time: f64,
    /// Makespan predicted by the placer's internal schedule.
    pub predicted_makespan: f64,
    /// Step time from the execution simulator (Tables 4, 5, 7).
    pub sim: SimResult,
    /// Devices actually used.
    pub devices_used: usize,
    /// Peak memory per device from the simulator (Fig. 7).
    pub peak_memory: Vec<u64>,
    pub devices: usize,
    pub device_capacity: u64,
}

impl RunReport {
    pub fn step_time(&self) -> Option<f64> {
        self.sim.ok().then_some(self.sim.makespan)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("benchmark", self.benchmark.as_str())
            .set("placer", self.placer.as_str())
            .set("original_ops", self.original_ops)
            .set("placed_ops", self.placed_ops)
            .set("placement_time_s", self.placement_time)
            .set("predicted_makespan_s", self.predicted_makespan)
            .set(
                "step_time_s",
                self.step_time().map(Json::from).unwrap_or(Json::Null),
            )
            .set("oom", self.sim.oom.is_some())
            .set("devices_used", self.devices_used)
            .set(
                "peak_memory",
                Json::Arr(self.peak_memory.iter().map(|&b| Json::from(b)).collect()),
            );
        j
    }
}

/// Run the full pipeline. `Err` only for infrastructure failures;
/// placement OOM surfaces as `Err` too (the paper's m-* OOM rows), while
/// *runtime* OOM of a successful placement is reported in `sim.oom`.
pub fn run(cfg: &BaechiConfig) -> anyhow::Result<RunReport> {
    let graph = cfg.benchmark.graph();
    let cluster = cfg.cluster();

    // Graph optimizer (§3.1). Baselines place the raw graph the way the
    // paper's baselines do (single/expert don't need reduction), but the
    // RL baseline uses the optimized graph to keep its action space sane.
    let use_optimizer = !matches!(cfg.placer, PlacerKind::Single | PlacerKind::Expert);
    let opt = if use_optimizer {
        let mut ocfg = cfg.opt;
        if ocfg.fusion && ocfg.latency_equiv_bytes == 0 {
            // Price multi-tensor fused edges consistently with the ES.
            ocfg.latency_equiv_bytes = (cfg.comm.latency * cfg.comm.bandwidth) as u64;
        }
        optimizer::optimize(&graph, &ocfg)
    } else {
        optimizer::optimize(&graph, &optimizer::OptConfig::none())
    };

    let placer = cfg.placer.build(cfg.benchmark);
    let placement = placer.place(&opt.graph, &cluster)?;
    let full = optimizer::expand_placement(&graph, &opt, &placement.device_of);

    // Evaluate the *full* graph placement in the ES.
    let sim = sim::simulate(&graph, &cluster, &full, cfg.sim);

    let devices_used = {
        let set: std::collections::BTreeSet<_> = full.values().collect();
        set.len()
    };
    Ok(RunReport {
        benchmark: cfg.benchmark.name(),
        placer: placement.algorithm.clone(),
        original_ops: opt.stats.original_ops,
        placed_ops: opt.stats.placed_ops,
        placement_time: placement.placement_time,
        predicted_makespan: placement.predicted_makespan,
        peak_memory: sim.peak_memory.clone(),
        devices_used,
        sim,
        devices: cfg.devices,
        device_capacity: cluster.devices[0].memory,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Benchmark;

    #[test]
    fn transformer_all_placers_sufficient_memory() {
        let b = Benchmark::Transformer { batch: 64 };
        let mut steps = std::collections::BTreeMap::new();
        for placer in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
        ] {
            let cfg = BaechiConfig::paper_default(b, placer);
            let r = run(&cfg).unwrap();
            assert!(r.sim.ok(), "{placer:?} OOM: {:?}", r.sim.oom);
            assert!(r.sim.makespan > 0.0);
            steps.insert(placer.name(), r.sim.makespan);
        }
        // paper Table 4 shape: m-ETF/m-SCT within ~±35 % of single.
        let single = steps["single-gpu"];
        for k in ["m-etf", "m-sct"] {
            let ratio = steps[k] / single;
            assert!(
                (0.4..=1.4).contains(&ratio),
                "{k} ratio {ratio} ({} vs {single})",
                steps[k]
            );
        }
    }

    #[test]
    fn mlp_insufficient_memory_single_ooms_msct_survives() {
        let b = Benchmark::Mlp;
        // Shrink devices until single can't hold the MLP (peak ≈ 1.05× the
        // permanent total) but each fused layer module plus its pinned
        // colocation group still fits one device.
        let total = b.graph().total_permanent_memory();
        let cfg = BaechiConfig {
            devices: 4,
            device_memory: total * 4 / 5,
            ..BaechiConfig::paper_default(b, PlacerKind::Single)
        };
        let single = run(&cfg).unwrap();
        assert!(!single.sim.ok(), "single must OOM at half memory");
        let cfg_sct = BaechiConfig {
            placer: PlacerKind::MSct,
            ..cfg
        };
        let sct = run(&cfg_sct).unwrap();
        assert!(sct.sim.ok(), "m-sct should place: {:?}", sct.sim.oom);
        assert!(sct.devices_used >= 2);
    }

    #[test]
    fn report_json_serializes() {
        let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        let r = run(&cfg).unwrap();
        let j = r.to_json();
        assert_eq!(j.get("placer").unwrap().as_str(), Some("m-etf"));
        assert!(Json::parse(&j.pretty()).is_ok());
    }
}
