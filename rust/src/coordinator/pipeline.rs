//! The end-to-end placement pipeline (paper Fig. 6):
//! graph generation → graph optimizer → placement algorithm → placement
//! expansion → execution-simulator evaluation.
//!
//! Since the `PlacementEngine` redesign this module is a thin wrapper:
//! [`run`] builds an engine for the config's cluster, issues one
//! [`PlacementRequest`](crate::engine::PlacementRequest), and reshapes
//! the response into the table-oriented [`RunReport`]. Anything that
//! needs more control (batching, caching, custom placers, observers)
//! should talk to [`crate::engine`] directly.

use super::config::BaechiConfig;
use crate::calibrate::{CalibratedCluster, CalibrationReport};
use crate::engine::{PlacementEngine, PlacementRequest};
use crate::feedback::ReplacementRound;
use crate::graph::{DeviceId, NodeId};
use crate::sim::SimResult;
use crate::telemetry::{chrome_trace, SimTrack};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Trajectory of an iterative run (`--replace-rounds > 0`): the
/// single-shot baseline plus every feedback round. A report-friendly
/// projection of [`crate::engine::IterativePlacement`] — same
/// `baseline_makespan`/`rounds`, minus the `Arc`'d response that
/// `RunReport` already carries as its own fields. Gains are computed
/// via [`crate::feedback::relative_gain`].
#[derive(Debug, Clone)]
pub struct ReplacementSummary {
    /// Simulated step time of the single-shot (round 0) placement.
    pub baseline_makespan: f64,
    pub rounds: Vec<ReplacementRound>,
}

impl ReplacementSummary {
    fn to_json(&self) -> Json {
        let mut rounds = Vec::new();
        for r in &self.rounds {
            let links: Vec<Json> = r.saturated_links.iter().map(|&l| Json::from(l)).collect();
            let mut o = Json::obj();
            o.set("round", r.round)
                .set("makespan_s", r.makespan)
                .set("oom", r.oom)
                .set("saturated_links", Json::Arr(links))
                .set("blocked_fraction", r.blocked_fraction)
                .set("max_utilization", r.max_utilization)
                .set("improved", r.improved);
            rounds.push(o);
        }
        let mut j = Json::obj();
        j.set("baseline_makespan_s", self.baseline_makespan)
            .set("rounds", Json::Arr(rounds));
        j
    }
}

/// Everything a run produces (one row of the paper's tables).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub benchmark: String,
    pub placer: String,
    /// Ops in the original and optimized (placed) graphs (Table 6).
    pub original_ops: usize,
    pub placed_ops: usize,
    /// Placement wall-clock seconds (Table 3).
    pub placement_time: f64,
    /// Makespan predicted by the placer's internal schedule.
    pub predicted_makespan: f64,
    /// Step time from the execution simulator (Tables 4, 5, 7).
    pub sim: SimResult,
    /// Devices actually used.
    pub devices_used: usize,
    /// Peak memory per device from the simulator (Fig. 7).
    pub peak_memory: Vec<u64>,
    pub devices: usize,
    pub device_capacity: u64,
    /// The expanded placement itself (for DOT export and auditing).
    pub device_of: BTreeMap<NodeId, DeviceId>,
    /// Human summary of the cluster topology the run placed against.
    pub topology: String,
    /// Re-placement trajectory (`None` for single-shot runs, and for
    /// runs whose simulation OOMed — a partial makespan is not a gain).
    pub replacement: Option<ReplacementSummary>,
    /// Calibration quality report (`--calibrate`; `None` when the run
    /// used the hand-specified cluster model).
    pub calibration: Option<CalibrationReport>,
}

impl RunReport {
    pub fn step_time(&self) -> Option<f64> {
        self.sim.ok().then_some(self.sim.makespan)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("benchmark", self.benchmark.as_str())
            .set("placer", self.placer.as_str())
            .set("topology", self.topology.as_str())
            .set("original_ops", self.original_ops)
            .set("placed_ops", self.placed_ops)
            .set("placement_time_s", self.placement_time)
            .set("predicted_makespan_s", self.predicted_makespan)
            .set(
                "step_time_s",
                self.step_time().map(Json::from).unwrap_or(Json::Null),
            )
            .set("oom", self.sim.oom.is_some())
            .set("devices_used", self.devices_used)
            .set(
                "peak_memory",
                Json::Arr(self.peak_memory.iter().map(|&b| Json::from(b)).collect()),
            );
        if let Some(rep) = &self.replacement {
            j.set("replacement", rep.to_json());
        }
        if let Some(cal) = &self.calibration {
            j.set("calibration", cal.to_json());
        }
        j
    }
}

/// Build the [`PlacementEngine`] a config describes (without serving any
/// request), running calibration when the config asks for it. The CLI
/// shares this so every entrypoint routes through one engine
/// construction path.
pub fn engine_for(cfg: &BaechiConfig) -> crate::Result<PlacementEngine> {
    engine_with(cfg, cfg.calibrated()?.as_ref(), None)
}

/// `tracing = None` defers to the builder's default (`BAECHI_TRACE`);
/// `Some(on)` forces span collection on or off.
fn engine_with(
    cfg: &BaechiConfig,
    cal: Option<&CalibratedCluster>,
    tracing: Option<bool>,
) -> crate::Result<PlacementEngine> {
    let mut builder = PlacementEngine::builder()
        .cluster(cfg.cluster_with(cal)?)
        .optimizer(cfg.opt)
        .sim(cfg.sim);
    if let Some(on) = tracing {
        builder = builder.tracing(on);
    }
    builder.build()
}

/// Run the full pipeline through the engine. `Err` only for
/// infrastructure failures; placement OOM surfaces as
/// `Err(BaechiError::Oom { .. })` (the paper's m-* OOM rows), while
/// *runtime* OOM of a successful placement is reported in `sim.oom`.
pub fn run(cfg: &BaechiConfig) -> crate::Result<RunReport> {
    // Calibrate once; the engine's cluster and the report share the run.
    let calibrated = cfg.calibrated()?;
    let engine = engine_with(cfg, calibrated.as_ref(), None)?;
    run_with_engine(cfg, &engine, calibrated)
}

/// [`run`] with span collection forced on: returns the report plus the
/// Chrome trace-event JSON covering both the pipeline spans and the
/// simulated execution timeline (`baechi trace` / `baechi place
/// --trace`). Load the file in `chrome://tracing` or Perfetto.
pub fn run_traced(cfg: &BaechiConfig) -> crate::Result<(RunReport, Json)> {
    let calibrated = cfg.calibrated()?;
    let engine = engine_with(cfg, calibrated.as_ref(), Some(true))?;
    let report = run_with_engine(cfg, &engine, calibrated)?;
    let spans = engine.tracer().drain();
    let graph = cfg.benchmark.graph();
    let topo = engine.cluster().effective_topology().into_owned();
    // Critical-path annotation: events on the makespan-defining chain
    // get `crit`/`crit_category` args so Perfetto can highlight them.
    let attribution = report
        .sim
        .ok()
        .then(|| crate::explain::attribute(&graph, &report.sim.schedule, report.sim.makespan));
    let trace = chrome_trace(
        &spans,
        Some(SimTrack {
            graph: &graph,
            topo: &topo,
            schedule: &report.sim.schedule,
            attribution: attribution.as_ref(),
        }),
    );
    Ok((report, trace))
}

/// Everything `baechi explain` reports: the run itself, the per-op
/// decision log captured while the placer ran, and the critical-path
/// attribution of the simulated schedule.
#[derive(Debug, Clone)]
pub struct ExplainReport {
    pub report: RunReport,
    /// Decisions recorded by the placer under this run's explain scope.
    pub decisions: crate::explain::DecisionLog,
    /// Makespan attribution over the simulated schedule. When the run
    /// OOMed at runtime the walk covers the truncated schedule (its own
    /// `max_end`), so the breakdown still describes what executed.
    pub attribution: crate::explain::Attribution,
}

impl ExplainReport {
    /// The run report plus `attribution` and `decisions` sections
    /// (`baechi explain --json`).
    pub fn to_json(&self, top_k: usize) -> Json {
        let mut j = self.report.to_json();
        j.set(
            "attribution",
            self.attribution.to_json(&self.report.sim.schedule, top_k),
        )
        .set("decisions", self.decisions.to_json());
        j
    }
}

/// [`run`] with decision recording on: the placer runs under a
/// [`crate::explain::DecisionScope`], and the simulated schedule is
/// attributed back to compute / transfer / queue-wait / idle. The
/// response itself is bit-identical to a plain [`run`] — recording
/// only observes.
pub fn run_explained(cfg: &BaechiConfig) -> crate::Result<ExplainReport> {
    let calibrated = cfg.calibrated()?;
    let engine = engine_with(cfg, calibrated.as_ref(), None)?;
    let scope = crate::explain::record_decisions();
    let report = run_with_engine(cfg, &engine, calibrated);
    let decisions = scope.finish();
    let report = report?;
    let graph = cfg.benchmark.graph();
    let makespan = if report.sim.ok() {
        report.sim.makespan
    } else {
        report.sim.schedule.max_end()
    };
    let attribution = crate::explain::attribute(&graph, &report.sim.schedule, makespan);
    Ok(ExplainReport {
        report,
        decisions,
        attribution,
    })
}

fn run_with_engine(
    cfg: &BaechiConfig,
    engine: &PlacementEngine,
    calibrated: Option<CalibratedCluster>,
) -> crate::Result<RunReport> {
    let req = PlacementRequest::for_benchmark(cfg.benchmark, &cfg.placer.spec());
    let (resp, replacement) = match cfg.replacement_policy() {
        Some(policy) => {
            let it = engine.place_iterative(&req, &policy)?;
            // A run whose simulation OOMed has no meaningful makespan
            // trajectory — report the OOM alone, not a bogus gain.
            let ok = it.response.sim.as_ref().map_or(false, |s| s.ok());
            let summary = ok.then(|| ReplacementSummary {
                baseline_makespan: it.baseline_makespan,
                rounds: it.rounds,
            });
            (it.response, summary)
        }
        None => (engine.place(&req)?, None),
    };
    let sim = resp
        .sim
        .clone()
        .expect("pipeline requests always simulate");
    Ok(RunReport {
        benchmark: cfg.benchmark.name(),
        placer: resp.placer.clone(),
        original_ops: resp.stats.original_ops,
        placed_ops: resp.stats.placed_ops,
        placement_time: resp.placement.placement_time,
        predicted_makespan: resp.placement.predicted_makespan,
        peak_memory: sim.peak_memory.clone(),
        devices_used: resp.devices_used,
        sim,
        devices: cfg.devices,
        device_capacity: engine.cluster().devices[0].memory,
        device_of: resp.placement.device_of.clone(),
        topology: engine.cluster().effective_topology().describe(),
        replacement,
        calibration: calibrated.map(|c| c.report),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlacerKind;
    use crate::models::Benchmark;

    #[test]
    fn transformer_all_placers_sufficient_memory() {
        let b = Benchmark::Transformer { batch: 64 };
        let mut steps = std::collections::BTreeMap::new();
        for placer in [
            PlacerKind::Single,
            PlacerKind::Expert,
            PlacerKind::MTopo,
            PlacerKind::MEtf,
            PlacerKind::MSct,
        ] {
            let cfg = BaechiConfig::paper_default(b, placer);
            let r = run(&cfg).unwrap();
            assert!(r.sim.ok(), "{placer:?} OOM: {:?}", r.sim.oom);
            assert!(r.sim.makespan > 0.0);
            steps.insert(placer.name(), r.sim.makespan);
        }
        // paper Table 4 shape: m-ETF/m-SCT within ~±35 % of single.
        let single = steps["single-gpu"];
        for k in ["m-etf", "m-sct"] {
            let ratio = steps[k] / single;
            assert!(
                (0.4..=1.4).contains(&ratio),
                "{k} ratio {ratio} ({} vs {single})",
                steps[k]
            );
        }
    }

    #[test]
    fn mlp_insufficient_memory_single_ooms_msct_survives() {
        let b = Benchmark::Mlp;
        // Shrink devices until single can't hold the MLP (peak ≈ 1.05× the
        // permanent total) but each fused layer module plus its pinned
        // colocation group still fits one device.
        let total = b.graph().total_permanent_memory();
        let cfg = BaechiConfig {
            devices: 4,
            device_memory: total * 4 / 5,
            ..BaechiConfig::paper_default(b, PlacerKind::Single)
        };
        let single = run(&cfg).unwrap();
        assert!(!single.sim.ok(), "single must OOM at half memory");
        let cfg_sct = BaechiConfig {
            placer: PlacerKind::MSct,
            ..cfg
        };
        let sct = run(&cfg_sct).unwrap();
        assert!(sct.sim.ok(), "m-sct should place: {:?}", sct.sim.oom);
        assert!(sct.devices_used >= 2);
    }

    #[test]
    fn report_json_serializes() {
        let cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        let r = run(&cfg).unwrap();
        let j = r.to_json();
        assert_eq!(j.get("placer").unwrap().as_str(), Some("m-etf"));
        assert!(j.get("replacement").is_none(), "single-shot run");
        assert!(Json::parse(&j.pretty()).is_ok());
    }

    #[test]
    fn calibrated_run_reports_quality_and_serializes() {
        use crate::coordinator::{CalibrationSpec, TopologySpec};
        let mut cfg = BaechiConfig::paper_default(Benchmark::LinReg, PlacerKind::MEtf);
        cfg.topology = TopologySpec::TwoTier { nodes: 2, ratio: 8.0 };
        cfg.calibrate = CalibrationSpec::Synthetic { noise: 0.0 };
        let r = run(&cfg).unwrap();
        let cal = r.calibration.as_ref().expect("calibrated run carries a report");
        assert!(cal.mean_rel_error < 0.05, "mean rel error {}", cal.mean_rel_error);
        assert_eq!(cal.n_islands, 2);
        let j = r.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        let cj = parsed.get("calibration").expect("calibration in JSON");
        assert_eq!(cj.get("islands").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn replace_rounds_records_trajectory_and_never_hurts() {
        use crate::coordinator::TopologySpec;
        let mut cfg = BaechiConfig::paper_default(
            Benchmark::Gnmt {
                batch: 32,
                seq_len: 10,
            },
            PlacerKind::MEtf,
        );
        cfg.topology = TopologySpec::TwoTier {
            nodes: 2,
            ratio: 8.0,
        };
        let single = run(&cfg).unwrap();
        assert!(single.replacement.is_none());
        cfg.replace_rounds = 2;
        cfg.replace_threshold = 0.4;
        let it = run(&cfg).unwrap();
        let rep = it.replacement.as_ref().expect("records rounds");
        assert!(!rep.rounds.is_empty());
        assert_eq!(rep.rounds[0].round, 0);
        assert_eq!(rep.baseline_makespan, single.sim.makespan);
        // Best-of-rounds can never be worse than the single shot.
        assert!(it.sim.makespan <= single.sim.makespan + 1e-9);
        let j = it.to_json();
        assert!(j.get("replacement").is_some());
        assert!(Json::parse(&j.pretty()).is_ok());
    }
}
