//! Linear programming substrate for m-SCT (paper §2.4, §4.2).
//!
//! * [`matrix`] — dense matrix, Cholesky, and the sparse constraint
//!   matrix with `A·D·Aᵀ` normal-matrix assembly.
//! * [`interior`] — Mehrotra predictor–corrector primal–dual interior
//!   point solver for standard-form LPs (replaces Mosek).
//! * [`sct`] — the relaxed SCT favorite-child LP, 0.1-threshold rounding,
//!   and the greedy max-communication fallback.

pub mod interior;
pub mod matrix;
pub mod sct;

pub use interior::{solve, IpmOptions, LpSolution, StandardLp};
pub use sct::{favorites, FavoriteMethod, Favorites};
