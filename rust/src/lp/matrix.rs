//! Dense row-major matrix with the factorizations the interior-point
//! solver needs. Kept deliberately small: matvec, AᵀB-style products,
//! and an in-place Cholesky with diagonal regularization.

/// 4-lane dot product: independent partial sums let LLVM vectorize
/// despite float non-associativity (§Perf iteration 2).
#[inline]
pub(crate) fn dot4(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] += v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            if xi != 0.0 {
                for j in 0..self.cols {
                    y[j] += row[j] * xi;
                }
            }
        }
        y
    }
}

/// Cholesky factor (lower-triangular, in place) of a symmetric
/// positive-definite matrix, with diagonal regularization `reg` added
/// when a pivot dips below it. Returns `Err` if the matrix is too
/// indefinite to repair.
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    pub fn factor(mut a: Mat, reg: f64) -> crate::Result<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        for k in 0..n {
            // pivot: akk -= Σ L[k,p]²  (iterator form → no bounds checks,
            // auto-vectorized; §Perf iteration 1)
            let mut akk = a.at(k, k);
            let lk_row = &a.data[k * n..k * n + k];
            akk -= dot4(lk_row, lk_row);
            if akk < reg {
                akk += reg.max(1e-12) * (1.0 + a.at(k, k).abs());
                if akk <= 0.0 {
                    return Err(crate::BaechiError::lp(format!(
                        "cholesky: non-PD pivot at {k}: {akk}"
                    )));
                }
            }
            let lkk = akk.sqrt();
            a.set(k, k, lkk);
            let inv = 1.0 / lkk;
            // column below pivot: split rows to appease the borrow checker
            for i in k + 1..n {
                let (head, tail) = a.data.split_at_mut(i * n);
                let lk = &head[k * n..k * n + k];
                let li = &tail[..k];
                tail[k] = (tail[k] - dot4(li, lk)) * inv;
            }
        }
        // zero the strict upper triangle for cleanliness
        for i in 0..n {
            for j in i + 1..n {
                a.set(i, j, 0.0);
            }
        }
        Ok(Cholesky { l: a })
    }

    /// Solve A x = b given A = L Lᵀ.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            y[i] = (y[i] - dot4(&row[..i], &y[..i])) / row[i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= self.l.at(j, i) * y[j];
            }
            y[i] = acc / self.l.at(i, i);
        }
        y
    }
}

/// Sparse matrix in column-major triplet groups — the constraint matrix
/// of our LPs is extremely sparse (≤ 4 nonzeros per column), and the
/// interior-point solver only needs `A·x`, `Aᵀ·y`, and the normal-matrix
/// assembly `Σ_j d_j a_j a_jᵀ`.
#[derive(Debug, Clone, Default)]
pub struct SparseCols {
    pub rows: usize,
    pub cols: usize,
    /// For each column: list of (row, value).
    pub col: Vec<Vec<(usize, f64)>>,
}

impl SparseCols {
    pub fn new(rows: usize, cols: usize) -> SparseCols {
        SparseCols {
            rows,
            cols,
            col: vec![Vec::new(); cols],
        }
    }

    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        assert!(row < self.rows && col < self.cols);
        if val != 0.0 {
            self.col[col].push((row, val));
        }
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        for (j, entries) in self.col.iter().enumerate() {
            let xj = x[j];
            if xj != 0.0 {
                for &(i, v) in entries {
                    y[i] += v * xj;
                }
            }
        }
        y
    }

    /// y = Aᵀ x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        for (j, entries) in self.col.iter().enumerate() {
            let mut acc = 0.0;
            for &(i, v) in entries {
                acc += v * x[i];
            }
            y[j] = acc;
        }
        y
    }

    /// Assemble the (dense, symmetric) normal matrix `A D Aᵀ` where
    /// `D = diag(d)`. Exploits column sparsity: cost O(Σ nnz(col)²).
    pub fn normal_matrix(&self, d: &[f64]) -> Mat {
        assert_eq!(d.len(), self.cols);
        let mut m = Mat::zeros(self.rows, self.rows);
        for (j, entries) in self.col.iter().enumerate() {
            let dj = d[j];
            if dj == 0.0 {
                continue;
            }
            for &(i1, v1) in entries {
                let w = dj * v1;
                for &(i2, v2) in entries {
                    // fill full matrix (simplifies Cholesky)
                    m.add_at(i1, i2, w * v2);
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]], b = [8, 7] → x = [1.4..? solve: 4x+2y=8, 2x+3y=7 → x=(24-14)/(12-4)=1.25, y=(8-4*1.25)/2=1.5
        let a = Mat::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::factor(a, 0.0).unwrap();
        let x = ch.solve(&[8.0, 7.0]);
        assert!((x[0] - 1.25).abs() < 1e-10);
        assert!((x[1] - 1.5).abs() < 1e-10);
    }

    #[test]
    fn cholesky_larger_random_spd() {
        // Build SPD as BᵀB + I.
        let n = 20;
        let mut rng = crate::util::rng::Pcg::seed(12);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b.set(i, j, rng.uniform(-1.0, 1.0));
            }
        }
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    acc += b.at(k, i) * b.at(k, j);
                }
                a.set(i, j, acc);
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64) - 10.0).collect();
        let rhs = a.matvec(&x_true);
        let ch = Cholesky::factor(a, 0.0).unwrap();
        let x = ch.solve(&rhs);
        for i in 0..n {
            assert!((x[i] - x_true[i]).abs() < 1e-8, "i={i}");
        }
    }

    #[test]
    fn sparse_ops_match_dense() {
        let mut s = SparseCols::new(3, 2);
        s.push(0, 0, 1.0);
        s.push(1, 0, 3.0);
        s.push(2, 0, 5.0);
        s.push(0, 1, 2.0);
        s.push(1, 1, 4.0);
        s.push(2, 1, 6.0);
        assert_eq!(s.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(s.matvec_t(&[1.0, 1.0, 1.0]), vec![9.0, 12.0]);
        // normal matrix with D = I equals A Aᵀ
        let m = s.normal_matrix(&[1.0, 1.0]);
        assert!((m.at(0, 0) - 5.0).abs() < 1e-12);
        assert!((m.at(0, 1) - 11.0).abs() < 1e-12);
        assert!((m.at(1, 2) - 39.0).abs() < 1e-12);
        assert!((m.at(2, 2) - 61.0).abs() < 1e-12);
    }
}
