//! The SCT favorite-child LP (paper §2.4).
//!
//! Relaxation of the ILP from Hanen & Munier [26]: `x_ij ∈ [0,1]`,
//! `x_ij = 0` ⇔ `j` is `i`'s favorite child. Solved with the
//! interior-point method and rounded at threshold 0.1 (paper §4.4: the
//! default 0.5 rounding produced favorite-child violations; 0.1 removes
//! them). A greedy max-communication heuristic is provided both as the
//! large-graph fallback and as an ablation (DESIGN.md §6).

use super::interior::{solve, IpmOptions, StandardLp};
use super::matrix::SparseCols;
use crate::graph::{NodeId, OpGraph};
use crate::profile::CommModel;

/// Favorite child/parent assignment (at most one each, paper §2.4).
#[derive(Debug, Clone, Default)]
pub struct Favorites {
    /// fav_child[i] = Some(j): prefer scheduling j on i's device.
    pub fav_child: Vec<Option<NodeId>>,
    /// fav_parent[j] = Some(i).
    pub fav_parent: Vec<Option<NodeId>>,
    /// Whether the LP path was used (vs the heuristic fallback).
    pub used_lp: bool,
    /// LP iterations (0 for heuristic).
    pub lp_iterations: usize,
}

impl Favorites {
    pub fn empty(cap: usize) -> Favorites {
        Favorites {
            fav_child: vec![None; cap],
            fav_parent: vec![None; cap],
            used_lp: false,
            lp_iterations: 0,
        }
    }

    pub fn is_favorite_edge(&self, i: NodeId, j: NodeId) -> bool {
        self.fav_child[i.0] == Some(j)
    }
}

/// Favorite-child selection method.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FavoriteMethod {
    /// Solve the relaxed LP (paper default).
    Lp,
    /// Greedy max-communication matching (fallback/ablation).
    Heuristic,
    /// LP when the graph has at most this many edges, else heuristic.
    Auto { edge_limit: usize },
}

/// Compute favorite children for a graph.
pub fn favorites(graph: &OpGraph, comm: &CommModel, method: FavoriteMethod) -> Favorites {
    let edges = graph.edge_count();
    match method {
        FavoriteMethod::Heuristic => heuristic_favorites(graph, comm),
        FavoriteMethod::Lp => lp_favorites(graph, comm)
            .unwrap_or_else(|_| heuristic_favorites(graph, comm)),
        FavoriteMethod::Auto { edge_limit } => {
            if edges <= edge_limit {
                lp_favorites(graph, comm).unwrap_or_else(|_| heuristic_favorites(graph, comm))
            } else {
                heuristic_favorites(graph, comm)
            }
        }
    }
}

/// Greedy matching on edges by descending communication time: each node
/// gets at most one favorite child and is the favorite child of at most
/// one parent.
pub fn heuristic_favorites(graph: &OpGraph, comm: &CommModel) -> Favorites {
    let mut fav = Favorites::empty(graph.capacity());
    let mut edges = graph.edges();
    edges.sort_by(|a, b| {
        comm.time(b.bytes)
            .partial_cmp(&comm.time(a.bytes))
            .unwrap()
            .then(a.src.cmp(&b.src))
            .then(a.dst.cmp(&b.dst))
    });
    for e in edges {
        if fav.fav_child[e.src.0].is_none() && fav.fav_parent[e.dst.0].is_none() {
            fav.fav_child[e.src.0] = Some(e.dst);
            fav.fav_parent[e.dst.0] = Some(e.src);
        }
    }
    fav
}

/// Build and solve the relaxed SCT LP; round x_ij at `0.1`.
///
/// Standard-form layout (columns):
/// `[ s_0..s_{V-1} | w | x_e (per edge) | slacks... ]`
///
/// Rows:
/// 1. makespan:    s_i + k_i ≤ w                       (V rows)
/// 2. precedence:  s_i + k_i + c_ij·x_ij ≤ s_j         (E rows)
/// 3. fav child:   Σ_j x_ij ≥ out(i) − 1               (rows where out ≥ 2)
/// 4. fav parent:  Σ_i x_ij ≥ in(j) − 1                (rows where in ≥ 2)
/// 5. bound:       x_ij ≤ 1                            (E rows)
pub fn lp_favorites(graph: &OpGraph, comm: &CommModel) -> crate::Result<Favorites> {
    let ids: Vec<NodeId> = graph.node_ids().collect();
    if ids.is_empty() {
        return Err(crate::BaechiError::lp("empty graph"));
    }
    let node_col: std::collections::BTreeMap<NodeId, usize> =
        ids.iter().enumerate().map(|(k, &id)| (id, k)).collect();
    let nv = ids.len();
    let edges = graph.edges();
    let ne = edges.len();
    if ne == 0 {
        return Err(crate::BaechiError::lp("no edges"));
    }

    let w_col = nv;
    let x_col = |e: usize| nv + 1 + e;

    // Count rows.
    let fav_child_rows: Vec<NodeId> = ids
        .iter()
        .copied()
        .filter(|&i| graph.out_degree(i) >= 2)
        .collect();
    let fav_parent_rows: Vec<NodeId> = ids
        .iter()
        .copied()
        .filter(|&j| graph.in_degree(j) >= 2)
        .collect();
    let m = nv + ne + fav_child_rows.len() + fav_parent_rows.len() + ne;
    let n_structural = nv + 1 + ne;
    let n = n_structural + m; // one slack per row

    let mut a = SparseCols::new(m, n);
    let mut b = vec![0.0; m];
    let mut c = vec![0.0; n];
    c[w_col] = 1.0; // min w

    // Scale times so coefficients are O(1) for numerical stability.
    let tmax = ids
        .iter()
        .map(|&i| graph.node(i).compute)
        .fold(0.0f64, f64::max)
        .max(edges.iter().map(|e| comm.time(e.bytes)).fold(0.0, f64::max))
        .max(1e-9);

    let mut row = 0;
    // 1. makespan rows: s_i - w ≤ -k_i  →  s_i - w + slack = -k_i
    // (negate to keep b ≥ 0: -s_i + w - k_i ≥ 0 → w - s_i - slack = k_i)
    for &i in &ids {
        a.push(row, w_col, 1.0);
        a.push(row, node_col[&i], -1.0);
        a.push(row, n_structural + row, -1.0);
        b[row] = graph.node(i).compute / tmax;
        row += 1;
    }
    // 2. precedence: s_j - s_i - c_ij x_ij - slack = k_i
    for (e_idx, e) in edges.iter().enumerate() {
        a.push(row, node_col[&e.dst], 1.0);
        a.push(row, node_col[&e.src], -1.0);
        a.push(row, x_col(e_idx), -comm.time(e.bytes) / tmax);
        a.push(row, n_structural + row, -1.0);
        b[row] = graph.node(e.src).compute / tmax;
        row += 1;
    }
    // 3. favorite child: Σ x_ij - slack = out(i) - 1
    for &i in &fav_child_rows {
        for (e_idx, e) in edges.iter().enumerate() {
            if e.src == i {
                a.push(row, x_col(e_idx), 1.0);
            }
        }
        a.push(row, n_structural + row, -1.0);
        b[row] = graph.out_degree(i) as f64 - 1.0;
        row += 1;
    }
    // 4. favorite parent: Σ x_ji - slack = in(j) - 1
    for &j in &fav_parent_rows {
        for (e_idx, e) in edges.iter().enumerate() {
            if e.dst == j {
                a.push(row, x_col(e_idx), 1.0);
            }
        }
        a.push(row, n_structural + row, -1.0);
        b[row] = graph.in_degree(j) as f64 - 1.0;
        row += 1;
    }
    // 5. x_ij + slack = 1
    for e_idx in 0..ne {
        a.push(row, x_col(e_idx), 1.0);
        a.push(row, n_structural + row, 1.0);
        b[row] = 1.0;
        row += 1;
    }
    debug_assert_eq!(row, m);

    let sol = solve(&StandardLp { a, b, c }, IpmOptions::default())?;

    // Round: favorite edge iff x < 0.1; enforce uniqueness by picking the
    // smallest x per source and per destination.
    let mut fav = Favorites::empty(graph.capacity());
    fav.used_lp = true;
    fav.lp_iterations = sol.iterations;
    let mut candidates: Vec<(f64, NodeId, NodeId)> = edges
        .iter()
        .enumerate()
        .filter_map(|(e_idx, e)| {
            let xv = sol.x[x_col(e_idx)];
            if xv < 0.1 {
                Some((xv, e.src, e.dst))
            } else {
                None
            }
        })
        .collect();
    candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (_, src, dst) in candidates {
        if fav.fav_child[src.0].is_none() && fav.fav_parent[dst.0].is_none() {
            fav.fav_child[src.0] = Some(dst);
            fav.fav_parent[dst.0] = Some(src);
        }
    }
    Ok(fav)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpGraph, OpKind};

    /// Chain a→b→c: every edge should be a favorite edge (no contention).
    #[test]
    fn chain_all_favorites() {
        let mut g = OpGraph::new("chain");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        for id in [a, b, c] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, b, 1000);
        g.add_edge(b, c, 1000);
        let comm = CommModel::new(0.0, 1e3).unwrap(); // 1 s per edge (SCT-ish ρ=1)
        let fav = lp_favorites(&g, &comm).unwrap();
        assert!(fav.used_lp);
        assert_eq!(fav.fav_child[a.0], Some(b));
        assert_eq!(fav.fav_child[b.0], Some(c));
        assert_eq!(fav.fav_parent[c.0], Some(b));
    }

    /// Fork a→{b,c}: exactly one of b,c is a's favorite child, and the LP
    /// should pick the one on the critical path (heavier subtree).
    #[test]
    fn fork_picks_single_favorite() {
        let mut g = OpGraph::new("fork");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 5.0; // heavy child
        g.node_mut(c).compute = 1.0;
        g.add_edge(a, b, 1000);
        g.add_edge(a, c, 1000);
        let comm = CommModel::new(0.0, 1e3).unwrap();
        let fav = lp_favorites(&g, &comm).unwrap();
        let chosen = fav.fav_child[a.0].expect("one favorite");
        assert_eq!(chosen, b, "LP should favor the critical-path child");
        // uniqueness
        let n_favs = [b, c]
            .iter()
            .filter(|&&x| fav.fav_child[a.0] == Some(x))
            .count();
        assert_eq!(n_favs, 1);
    }

    #[test]
    fn heuristic_respects_uniqueness() {
        let mut g = OpGraph::new("x");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        g.add_edge(a, c, 100);
        g.add_edge(b, c, 200);
        g.add_edge(a, d, 50);
        let comm = CommModel::new(0.0, 1e3).unwrap();
        let fav = heuristic_favorites(&g, &comm);
        // b→c is heaviest: b's favorite child = c; then a can't take c,
        // falls back to d.
        assert_eq!(fav.fav_child[b.0], Some(c));
        assert_eq!(fav.fav_child[a.0], Some(d));
        assert_eq!(fav.fav_parent[c.0], Some(b));
    }

    #[test]
    fn auto_switches_on_size() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 1.0;
        g.add_edge(a, b, 100);
        let comm = CommModel::new(0.0, 1e3).unwrap();
        let lp = favorites(&g, &comm, FavoriteMethod::Auto { edge_limit: 10 });
        assert!(lp.used_lp);
        let heur = favorites(&g, &comm, FavoriteMethod::Auto { edge_limit: 0 });
        assert!(!heur.used_lp);
        assert_eq!(lp.fav_child[a.0], heur.fav_child[a.0]);
    }

    /// LP on a model-scale (fused transformer) graph terminates and
    /// produces a consistent assignment.
    #[test]
    fn lp_on_fused_transformer() {
        let g = crate::models::transformer::transformer(
            crate::models::transformer::TransformerConfig::paper(64),
        );
        let opt = crate::optimizer::optimize(&g, &crate::optimizer::OptConfig::full());
        let comm = CommModel::pcie_via_host();
        let fav = lp_favorites(&opt.graph, &comm).unwrap();
        // consistency: fav_child/fav_parent are inverse partial maps
        for i in opt.graph.node_ids() {
            if let Some(j) = fav.fav_child[i.0] {
                assert_eq!(fav.fav_parent[j.0], Some(i));
            }
        }
    }
}
