//! Primal–dual interior-point LP solver (Mehrotra predictor–corrector).
//!
//! Solves standard-form problems
//!
//! ```text
//! min cᵀx   s.t.  A x = b,  x ≥ 0
//! ```
//!
//! replacing the paper's Mosek homogeneous interior-point solver (§4.2).
//! The constraint matrix is sparse (≤ 4 nonzeros per column for the SCT
//! LP); the normal matrix `A D Aᵀ` is assembled sparsely and factored
//! with a dense Cholesky — the same structure commercial IPMs use, minus
//! sparse elimination ordering.

use super::matrix::{Cholesky, SparseCols};

/// Standard-form LP.
#[derive(Debug, Clone)]
pub struct StandardLp {
    pub a: SparseCols,
    pub b: Vec<f64>,
    pub c: Vec<f64>,
}

/// Solver result.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
    /// Final complementarity gap μ.
    pub gap: f64,
}

/// Solver options.
#[derive(Debug, Clone, Copy)]
pub struct IpmOptions {
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for IpmOptions {
    fn default() -> IpmOptions {
        IpmOptions {
            max_iters: 60,
            // the SCT rounding threshold is 0.1 — 1e-6 is ample (§Perf)
            tol: 1e-6,
        }
    }
}

/// Solve a standard-form LP. Assumes the problem is feasible and bounded
/// (the SCT LP always is: x = rounding of any valid schedule).
pub fn solve(lp: &StandardLp, opts: IpmOptions) -> crate::Result<LpSolution> {
    let m = lp.a.rows;
    let n = lp.a.cols;
    if lp.b.len() != m || lp.c.len() != n {
        return Err(crate::BaechiError::lp("lp shape mismatch"));
    }
    if n == 0 || m == 0 {
        return Err(crate::BaechiError::lp("empty lp"));
    }

    // --- Initial point (Mehrotra's heuristic) ---------------------------
    // x0 = Aᵀ(AAᵀ)⁻¹ b (min-norm primal), y0 = (AAᵀ)⁻¹ A c, s0 = c - Aᵀy0,
    // then shift into the positive orthant.
    let ones = vec![1.0; n];
    let aat = lp.a.normal_matrix(&ones);
    let reg = 1e-8;
    let ch = Cholesky::factor(aat, reg)?;
    let x_tilde = lp.a.matvec_t(&ch.solve(&lp.b));
    let y0 = ch.solve(&lp.a.matvec(&lp.c));
    let s_tilde: Vec<f64> = lp
        .c
        .iter()
        .zip(lp.a.matvec_t(&y0))
        .map(|(c, aty)| c - aty)
        .collect();
    let dx = (-x_tilde.iter().cloned().fold(f64::INFINITY, f64::min)).max(0.0) + 0.1;
    let ds = (-s_tilde.iter().cloned().fold(f64::INFINITY, f64::min)).max(0.0) + 0.1;
    let mut x: Vec<f64> = x_tilde.iter().map(|v| v + dx).collect();
    let mut s: Vec<f64> = s_tilde.iter().map(|v| v + ds).collect();
    let mut y = y0;
    // second shift for balance
    let xs: f64 = x.iter().zip(&s).map(|(a, b)| a * b).sum();
    let sx: f64 = s.iter().sum();
    let sxx: f64 = x.iter().sum();
    let dx2 = 0.5 * xs / sx.max(1e-12);
    let ds2 = 0.5 * xs / sxx.max(1e-12);
    for v in x.iter_mut() {
        *v += dx2;
    }
    for v in s.iter_mut() {
        *v += ds2;
    }

    let bnorm = 1.0 + norm_inf(&lp.b);
    let cnorm = 1.0 + norm_inf(&lp.c);

    let mut iterations = 0;
    let mut mu = dot(&x, &s) / n as f64;
    for iter in 0..opts.max_iters {
        iterations = iter + 1;
        // Residuals.
        let ax = lp.a.matvec(&x);
        let rp: Vec<f64> = lp.b.iter().zip(&ax).map(|(b, a)| b - a).collect();
        let aty = lp.a.matvec_t(&y);
        let rd: Vec<f64> = lp
            .c
            .iter()
            .zip(aty.iter().zip(&s))
            .map(|(c, (aty, s))| c - aty - s)
            .collect();
        mu = dot(&x, &s) / n as f64;

        if norm_inf(&rp) / bnorm < opts.tol
            && norm_inf(&rd) / cnorm < opts.tol
            && mu < opts.tol
        {
            break;
        }

        // Normal matrix with D = X S⁻¹.
        let d: Vec<f64> = x.iter().zip(&s).map(|(x, s)| x / s).collect();
        let mm = lp.a.normal_matrix(&d);
        let ch = match Cholesky::factor(mm, 1e-10 * (1.0 + mu)) {
            Ok(c) => c,
            Err(_) => break, // numerically done
        };

        // --- Affine (predictor) step: v = -XSe → S⁻¹v = -x -------------
        let solve_dir = |sinv_v: &[f64]| -> (Vec<f64>, Vec<f64>, Vec<f64>) {
            // rhs = rp + A D rd - A (S⁻¹ v)
            let mut tmp: Vec<f64> = (0..n).map(|j| d[j] * rd[j] - sinv_v[j]).collect();
            let atmp = lp.a.matvec(&tmp);
            let rhs: Vec<f64> = rp.iter().zip(&atmp).map(|(r, a)| r + a).collect();
            let dy = ch.solve(&rhs);
            let atdy = lp.a.matvec_t(&dy);
            let dsv: Vec<f64> = (0..n).map(|j| rd[j] - atdy[j]).collect();
            for j in 0..n {
                tmp[j] = sinv_v[j] - d[j] * dsv[j];
            }
            (tmp, dy, dsv) // (dx, dy, ds)
        };

        let sinv_v_aff: Vec<f64> = x.iter().map(|xv| -xv).collect();
        let (dx_aff, _dy_aff, ds_aff) = solve_dir(&sinv_v_aff);
        let alpha_p_aff = max_step(&x, &dx_aff);
        let alpha_d_aff = max_step(&s, &ds_aff);
        let mu_aff = {
            let mut acc = 0.0;
            for j in 0..n {
                acc += (x[j] + alpha_p_aff * dx_aff[j]) * (s[j] + alpha_d_aff * ds_aff[j]);
            }
            acc / n as f64
        };
        let sigma = (mu_aff / mu).powi(3).clamp(0.0, 1.0);

        // --- Corrector step: v = σμe - XSe - ΔXaff ΔSaff e --------------
        let sinv_v: Vec<f64> = (0..n)
            .map(|j| (sigma * mu - dx_aff[j] * ds_aff[j]) / s[j] - x[j])
            .collect();
        let (dxv, dyv, dsv) = solve_dir(&sinv_v);

        let eta = 0.995_f64.max(1.0 - mu);
        let alpha_p = (eta * max_step(&x, &dxv)).min(1.0);
        let alpha_d = (eta * max_step(&s, &dsv)).min(1.0);
        for j in 0..n {
            x[j] += alpha_p * dxv[j];
            s[j] += alpha_d * dsv[j];
        }
        for i in 0..m {
            y[i] += alpha_d * dyv[i];
        }
    }

    Ok(LpSolution {
        objective: dot(&lp.c, &x),
        x,
        iterations,
        gap: mu,
    })
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm_inf(v: &[f64]) -> f64 {
    v.iter().fold(0.0, |m, x| m.max(x.abs()))
}

/// Largest α ∈ (0, 1] with v + α d ≥ 0.
fn max_step(v: &[f64], d: &[f64]) -> f64 {
    let mut alpha = 1.0f64;
    for j in 0..v.len() {
        if d[j] < 0.0 {
            alpha = alpha.min(-v[j] / d[j]);
        }
    }
    alpha.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Helper: build sparse A from dense rows.
    fn sparse(rows: &[&[f64]]) -> SparseCols {
        let m = rows.len();
        let n = rows[0].len();
        let mut a = SparseCols::new(m, n);
        for (i, r) in rows.iter().enumerate() {
            for (j, &v) in r.iter().enumerate() {
                a.push(i, j, v);
            }
        }
        a
    }

    #[test]
    fn solves_textbook_lp() {
        // max x1 + 2x2 s.t. x1 + x2 ≤ 4, x1 ≤ 2, x2 ≤ 3, x ≥ 0
        // → min -x1 - 2x2 with slacks. Optimum at x1=1, x2=3 → obj -7.
        let a = sparse(&[
            &[1.0, 1.0, 1.0, 0.0, 0.0],
            &[1.0, 0.0, 0.0, 1.0, 0.0],
            &[0.0, 1.0, 0.0, 0.0, 1.0],
        ]);
        let lp = StandardLp {
            a,
            b: vec![4.0, 2.0, 3.0],
            c: vec![-1.0, -2.0, 0.0, 0.0, 0.0],
        };
        let sol = solve(&lp, IpmOptions::default()).unwrap();
        assert!((sol.objective + 7.0).abs() < 1e-5, "obj {}", sol.objective);
        assert!((sol.x[0] - 1.0).abs() < 1e-4);
        assert!((sol.x[1] - 3.0).abs() < 1e-4);
    }

    #[test]
    fn solves_degenerate_lp() {
        // min x1 s.t. x1 + x2 = 1, x ≥ 0 → x1 = 0.
        let a = sparse(&[&[1.0, 1.0]]);
        let lp = StandardLp {
            a,
            b: vec![1.0],
            c: vec![1.0, 0.0],
        };
        let sol = solve(&lp, IpmOptions::default()).unwrap();
        assert!(sol.objective.abs() < 1e-6);
        assert!(sol.x[0].abs() < 1e-5);
        assert!((sol.x[1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn random_lps_match_vertex_enumeration() {
        // Small random LPs: min cᵀx s.t. x1 + ... + xn = 1, x ≥ 0 —
        // optimum is min(c).
        let mut rng = crate::util::rng::Pcg::seed(99);
        for _ in 0..20 {
            let n = rng.range(2, 8);
            let mut a = SparseCols::new(1, n);
            for j in 0..n {
                a.push(0, j, 1.0);
            }
            let c: Vec<f64> = (0..n).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let lp = StandardLp {
                a,
                b: vec![1.0],
                c: c.clone(),
            };
            let sol = solve(&lp, IpmOptions::default()).unwrap();
            let best = c.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                (sol.objective - best).abs() < 1e-5,
                "obj {} vs best {}",
                sol.objective,
                best
            );
        }
    }

    #[test]
    fn transportation_like_lp() {
        // min Σ cost·flow, 2 supplies × 2 demands with equality rows.
        // supplies 3, 2; demands 4, 1; costs [[1, 3], [2, 1]].
        // Optimal: x11=3, x21=1, x22=1 → 3 + 2 + 1 = 6.
        let a = sparse(&[
            &[1.0, 1.0, 0.0, 0.0], // supply 1
            &[0.0, 0.0, 1.0, 1.0], // supply 2
            &[1.0, 0.0, 1.0, 0.0], // demand 1
            &[0.0, 1.0, 0.0, 1.0], // demand 2
        ]);
        let lp = StandardLp {
            a,
            b: vec![3.0, 2.0, 4.0, 1.0],
            c: vec![1.0, 3.0, 2.0, 1.0],
        };
        let sol = solve(&lp, IpmOptions::default()).unwrap();
        assert!((sol.objective - 6.0).abs() < 1e-4, "obj {}", sol.objective);
    }
}
