//! Minimal blocking HTTP/1.1 listener for Prometheus scrapes.
//!
//! One accept thread, one request per connection (`Connection: close`),
//! two routes: `GET /metrics` (and `/`) returns the rendered exposition
//! text, anything else 404. This is deliberately not a web server — a
//! Prometheus scraper sends one short GET and reads one response, which
//! is exactly what `std::net` handles comfortably without pulling in an
//! async stack.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::BaechiError;

/// Background metrics endpoint; shuts down when dropped.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks a free port)
    /// and serve `render()`'s output on every scrape.
    pub fn bind(
        addr: &str,
        render: impl Fn() -> String + Send + Sync + 'static,
    ) -> crate::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| BaechiError::io(format!("metrics listener on {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| BaechiError::io(format!("metrics listener addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("baechi-metrics".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // A hung scraper must not wedge the endpoint.
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                        let _ = serve_one(stream, &render);
                    }
                }
            })
            .map_err(|e| BaechiError::runtime(format!("metrics thread: {e}")))?;
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread (idempotent).
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // The accept loop is blocked in `incoming()`; a throwaway
        // connection wakes it so it can observe the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_one(mut stream: TcpStream, render: &dyn Fn() -> String) -> std::io::Result<()> {
    // Read until the end of the request head (or the buffer fills —
    // scrape requests are tiny).
    let mut buf = [0u8; 4096];
    let mut len = 0;
    while len < buf.len() {
        let n = stream.read(&mut buf[len..])?;
        if n == 0 {
            break;
        }
        len += n;
        if buf[..len].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..len]);
    let mut parts = head.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let response = if method == "GET" && (path == "/metrics" || path == "/") {
        let body = render();
        format!(
            "HTTP/1.1 200 OK\r\n\
             Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{}",
            body.len(),
            body
        )
    } else {
        let body = "not found\n";
        format!(
            "HTTP/1.1 404 Not Found\r\n\
             Content-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n{}",
            body.len(),
            body
        )
    };
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_everything_else() {
        let mut server =
            MetricsServer::bind("127.0.0.1:0", || "# TYPE up gauge\nup 1\n".to_string()).unwrap();
        let addr = server.addr();
        let ok = get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK\r\n"), "{ok}");
        assert!(ok.contains("version=0.0.4"), "{ok}");
        assert!(ok.ends_with("# TYPE up gauge\nup 1\n"), "{ok}");
        let root = get(addr, "/");
        assert!(root.starts_with("HTTP/1.1 200 OK\r\n"));
        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        server.shutdown();
        // Idempotent; Drop after shutdown is fine too.
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_the_listener_thread() {
        let server = MetricsServer::bind("127.0.0.1:0", String::new).unwrap();
        let addr = server.addr();
        drop(server); // Drop path exercises shutdown.
        // The port is released: connecting either fails or the
        // throwaway wake connection already consumed the listener.
        // Binding again must succeed.
        let again = MetricsServer::bind(&addr.to_string(), String::new);
        assert!(again.is_ok(), "port must be released after shutdown");
    }
}
