//! Prometheus text exposition format 0.0.4.
//!
//! [`render_metrics`] turns a [`ServiceMetrics`] snapshot (plus the
//! tracer's counters) into the `# HELP` / `# TYPE` / sample-line text a
//! Prometheus scraper expects; [`parse_text`] is the strict line-level
//! validator the tests (and any future self-scrape) use. Both sides
//! are hand-rolled — the format is line-oriented and small enough that
//! a dependency would cost more than it saves.

use crate::serve::ServiceMetrics;
use crate::telemetry::tracer::TraceStats;

/// Format one sample value the way Prometheus expects (`NaN`, `+Inf`,
/// `-Inf` for the non-finite cases).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

struct Renderer {
    out: String,
}

impl Renderer {
    fn family(&mut self, name: &str, kind: &str, help: &str) -> &mut Self {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
        self
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], v: f64) -> &mut Self {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{val}\""));
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(v));
        self.out.push('\n');
        self
    }
}

/// Render a metrics snapshot + tracer counters as Prometheus text.
pub fn render_metrics(m: &ServiceMetrics, t: &TraceStats) -> String {
    let mut r = Renderer { out: String::new() };
    r.family(
        "baechi_requests_submitted_total",
        "counter",
        "Placement requests accepted by the service.",
    )
    .sample("baechi_requests_submitted_total", &[], m.submitted as f64);
    r.family(
        "baechi_requests_completed_total",
        "counter",
        "Placement requests answered (success or error).",
    )
    .sample("baechi_requests_completed_total", &[], m.completed as f64);
    r.family(
        "baechi_request_errors_total",
        "counter",
        "Requests that completed with an error.",
    )
    .sample("baechi_request_errors_total", &[], m.errors as f64);
    r.family(
        "baechi_deadline_misses_total",
        "counter",
        "Requests answered after their deadline expired.",
    )
    .sample("baechi_deadline_misses_total", &[], m.deadline_misses as f64);
    r.family(
        "baechi_served_total",
        "counter",
        "Requests served, by placement mode.",
    )
    .sample("baechi_served_total", &[("mode", "cache_hit")], m.cache_hits as f64)
    .sample("baechi_served_total", &[("mode", "incremental")], m.incremental as f64)
    .sample("baechi_served_total", &[("mode", "full")], m.full as f64);
    r.family(
        "baechi_batches_total",
        "counter",
        "Worker batches executed.",
    )
    .sample("baechi_batches_total", &[], m.batches as f64);
    r.family(
        "baechi_batched_requests_total",
        "counter",
        "Requests that rode in a multi-request batch.",
    )
    .sample("baechi_batched_requests_total", &[], m.batched_requests as f64);
    r.family("baechi_uptime_seconds", "gauge", "Service uptime.")
        .sample("baechi_uptime_seconds", &[], m.uptime_s);
    r.family(
        "baechi_qps",
        "gauge",
        "Lifetime completions per second of uptime.",
    )
    .sample("baechi_qps", &[], m.qps);
    r.family(
        "baechi_recent_qps",
        "gauge",
        "Completions per second over the recent latency window.",
    )
    .sample("baechi_recent_qps", &[], m.recent_qps);
    r.family(
        "baechi_request_latency_seconds",
        "gauge",
        "Request latency statistics over the sliding reservoir.",
    )
    .sample("baechi_request_latency_seconds", &[("stat", "mean")], m.mean_latency_s)
    .sample("baechi_request_latency_seconds", &[("stat", "p50")], m.p50_latency_s)
    .sample("baechi_request_latency_seconds", &[("stat", "p99")], m.p99_latency_s)
    .sample(
        "baechi_request_latency_seconds",
        &[("stat", "incremental_mean")],
        m.incremental_mean_latency_s,
    )
    .sample(
        "baechi_request_latency_seconds",
        &[("stat", "full_mean")],
        m.full_mean_latency_s,
    );
    r.family(
        "baechi_engine_cache_hits_total",
        "counter",
        "Placement-cache hits across all shards.",
    )
    .sample("baechi_engine_cache_hits_total", &[], m.engine_cache.hits as f64);
    r.family(
        "baechi_engine_cache_misses_total",
        "counter",
        "Placement-cache misses across all shards.",
    )
    .sample("baechi_engine_cache_misses_total", &[], m.engine_cache.misses as f64);
    r.family(
        "baechi_engine_cache_evictions_total",
        "counter",
        "Placement-cache LRU evictions across all shards.",
    )
    .sample(
        "baechi_engine_cache_evictions_total",
        &[],
        m.engine_cache.evictions as f64,
    );
    r.family(
        "baechi_run_records_total",
        "counter",
        "Placement runs appended to the run-history flight recorder.",
    )
    .sample("baechi_run_records_total", &[], m.explain.run_records as f64);
    r.family(
        "baechi_run_record_bytes_total",
        "counter",
        "Cumulative run-history bytes written (across rotations).",
    )
    .sample(
        "baechi_run_record_bytes_total",
        &[],
        m.explain.run_record_bytes as f64,
    );
    r.family(
        "baechi_run_record_rotations_total",
        "counter",
        "Times the run-history file was rotated.",
    )
    .sample(
        "baechi_run_record_rotations_total",
        &[],
        m.explain.run_record_rotations as f64,
    );
    r.family(
        "baechi_explain_decisions_total",
        "counter",
        "Placement decisions captured by explain scopes.",
    )
    .sample(
        "baechi_explain_decisions_total",
        &[],
        m.explain.decisions as f64,
    );
    r.family(
        "baechi_critical_path_fraction",
        "gauge",
        "Fraction of the last recorded run's makespan, by blame category.",
    );
    if let Some(a) = m.explain.critical_path {
        let total = (a.compute + a.transfer + a.queue_wait + a.idle).max(1e-12);
        for (cat, v) in [
            ("compute", a.compute),
            ("transfer", a.transfer),
            ("queue_wait", a.queue_wait),
            ("idle", a.idle),
        ] {
            r.sample(
                "baechi_critical_path_fraction",
                &[("category", cat)],
                v / total,
            );
        }
    }
    r.family(
        "baechi_trace_spans_recorded_total",
        "counter",
        "Telemetry spans stored in the collector.",
    )
    .sample("baechi_trace_spans_recorded_total", &[], t.recorded as f64);
    r.family(
        "baechi_trace_spans_dropped_total",
        "counter",
        "Telemetry spans lost to a full collector shard.",
    )
    .sample("baechi_trace_spans_dropped_total", &[], t.dropped as f64);
    r.family(
        "baechi_trace_collecting",
        "gauge",
        "1 when span collection is enabled.",
    )
    .sample(
        "baechi_trace_collecting",
        &[],
        if t.collecting { 1.0 } else { 0.0 },
    );
    r.out
}

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> Result<f64, String> {
    match s {
        "NaN" => Ok(f64::NAN),
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        _ => s.parse().map_err(|_| format!("bad sample value {s:?}")),
    }
}

/// Parse `{k="v",...}` starting after the `{`. Returns the labels and
/// the rest of the line after the closing `}`.
fn parse_labels(s: &str) -> Result<(Vec<(String, String)>, &str), String> {
    let mut labels = Vec::new();
    let mut rest = s;
    loop {
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix('}') {
            return Ok((labels, r));
        }
        let eq = rest.find('=').ok_or("label without '='")?;
        let name = rest[..eq].trim();
        if !valid_label_name(name) {
            return Err(format!("bad label name {name:?}"));
        }
        rest = rest[eq + 1..]
            .trim_start()
            .strip_prefix('"')
            .ok_or("label value must be quoted")?;
        // Scan the quoted value honoring \" escapes.
        let mut value = String::new();
        let mut chars = rest.char_indices();
        let end = loop {
            let (i, c) = chars.next().ok_or("unterminated label value")?;
            match c {
                '"' => break i,
                '\\' => {
                    let (_, esc) = chars.next().ok_or("dangling escape")?;
                    value.push(match esc {
                        'n' => '\n',
                        '\\' => '\\',
                        '"' => '"',
                        other => return Err(format!("bad escape \\{other}")),
                    });
                }
                c => value.push(c),
            }
        };
        labels.push((name.to_string(), value));
        rest = &rest[end + 1..];
        rest = rest.trim_start();
        if let Some(r) = rest.strip_prefix(',') {
            rest = r;
        }
    }
}

/// Strictly parse a text-format 0.0.4 exposition: every line must be a
/// well-formed `# HELP`/`# TYPE` comment or a sample, every sample's
/// family must have a preceding `# TYPE`, and values must parse.
/// Returns the samples in order.
pub fn parse_text(text: &str) -> Result<Vec<PromSample>, String> {
    const TYPES: [&str; 5] = ["counter", "gauge", "histogram", "summary", "untyped"];
    let mut typed: Vec<String> = Vec::new();
    let mut samples = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(body) = rest.strip_prefix("HELP ") {
                let name = body.split_whitespace().next().unwrap_or("");
                if !valid_metric_name(name) {
                    return Err(format!("line {lineno}: bad HELP metric name {name:?}"));
                }
            } else if let Some(body) = rest.strip_prefix("TYPE ") {
                let mut parts = body.split_whitespace();
                let name = parts.next().unwrap_or("");
                let kind = parts.next().unwrap_or("");
                if !valid_metric_name(name) {
                    return Err(format!("line {lineno}: bad TYPE metric name {name:?}"));
                }
                if !TYPES.contains(&kind) {
                    return Err(format!("line {lineno}: bad metric type {kind:?}"));
                }
                typed.push(name.to_string());
            } else {
                return Err(format!("line {lineno}: comment is neither HELP nor TYPE"));
            }
            continue;
        }
        // Sample line: name[{labels}] value [timestamp]
        let name_end = line
            .find(|c: char| c == '{' || c.is_whitespace())
            .ok_or_else(|| format!("line {lineno}: sample without value"))?;
        let name = &line[..name_end];
        if !valid_metric_name(name) {
            return Err(format!("line {lineno}: bad metric name {name:?}"));
        }
        let (labels, rest) = if line[name_end..].starts_with('{') {
            parse_labels(&line[name_end + 1..]).map_err(|e| format!("line {lineno}: {e}"))?
        } else {
            (Vec::new(), &line[name_end..])
        };
        let mut parts = rest.split_whitespace();
        let value = parse_value(parts.next().ok_or(format!("line {lineno}: missing value"))?)
            .map_err(|e| format!("line {lineno}: {e}"))?;
        if let Some(ts) = parts.next() {
            // Optional millisecond timestamp.
            ts.parse::<i64>()
                .map_err(|_| format!("line {lineno}: bad timestamp {ts:?}"))?;
        }
        if parts.next().is_some() {
            return Err(format!("line {lineno}: trailing garbage"));
        }
        // The family of `name_bucket`/`name_sum`/`name_count` is `name`.
        let family_ok = typed.iter().any(|t| {
            name == t
                || (name.strip_prefix(t.as_str()).is_some_and(|s| {
                    matches!(s, "_bucket" | "_sum" | "_count")
                }))
        });
        if !family_ok {
            return Err(format!("line {lineno}: sample {name:?} has no preceding # TYPE"));
        }
        samples.push(PromSample {
            name: name.to_string(),
            labels,
            value,
        });
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CacheStats;

    fn sample_metrics() -> ServiceMetrics {
        ServiceMetrics {
            submitted: 10,
            completed: 9,
            errors: 1,
            deadline_misses: 0,
            cache_hits: 4,
            incremental: 2,
            full: 3,
            batches: 5,
            batched_requests: 2,
            uptime_s: 12.5,
            qps: 0.72,
            recent_qps: 1.5,
            mean_latency_s: 0.01,
            p50_latency_s: 0.008,
            p99_latency_s: 0.05,
            incremental_mean_latency_s: 0.004,
            full_mean_latency_s: 0.02,
            engine_cache: CacheStats::default(),
            explain: crate::serve::ExplainStats {
                run_records: 7,
                run_record_bytes: 2048,
                run_record_rotations: 1,
                decisions: 42,
                critical_path: Some(crate::explain::record::AttributionTotals {
                    compute: 0.5,
                    transfer: 0.25,
                    queue_wait: 0.15,
                    idle: 0.1,
                }),
            },
        }
    }

    #[test]
    fn rendered_text_parses_and_round_trips_counters() {
        let text = render_metrics(&sample_metrics(), &TraceStats::default());
        let samples = parse_text(&text).expect("must parse");
        let find = |name: &str, labels: &[(&str, &str)]| -> f64 {
            samples
                .iter()
                .find(|s| {
                    s.name == name
                        && s.labels.len() == labels.len()
                        && labels
                            .iter()
                            .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
                })
                .unwrap_or_else(|| panic!("missing {name} {labels:?}"))
                .value
        };
        assert_eq!(find("baechi_requests_submitted_total", &[]), 10.0);
        assert_eq!(find("baechi_served_total", &[("mode", "cache_hit")]), 4.0);
        assert_eq!(find("baechi_served_total", &[("mode", "full")]), 3.0);
        assert_eq!(find("baechi_qps", &[]), 0.72);
        assert_eq!(find("baechi_recent_qps", &[]), 1.5);
        assert_eq!(find("baechi_request_latency_seconds", &[("stat", "p99")]), 0.05);
        assert_eq!(find("baechi_trace_collecting", &[]), 0.0);
        assert_eq!(find("baechi_run_records_total", &[]), 7.0);
        assert_eq!(find("baechi_run_record_bytes_total", &[]), 2048.0);
        assert_eq!(find("baechi_run_record_rotations_total", &[]), 1.0);
        assert_eq!(find("baechi_explain_decisions_total", &[]), 42.0);
        // Fractions normalize the four totals (which sum to 1.0 here).
        assert!((find("baechi_critical_path_fraction", &[("category", "compute")]) - 0.5).abs() < 1e-9);
        assert!((find("baechi_critical_path_fraction", &[("category", "idle")]) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn critical_path_gauge_absent_without_attribution() {
        let mut m = sample_metrics();
        m.explain.critical_path = None;
        let text = render_metrics(&m, &TraceStats::default());
        let samples = parse_text(&text).expect("must parse");
        assert!(
            !samples.iter().any(|s| s.name == "baechi_critical_path_fraction"),
            "no samples until a run is recorded"
        );
        // The family declaration still renders, so scrapers see a
        // stable exposition either way.
        assert!(text.contains("# TYPE baechi_critical_path_fraction gauge"));
    }

    #[test]
    fn non_finite_values_render_in_prometheus_spelling() {
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        let parsed = parse_text("# TYPE x gauge\nx +Inf\n").unwrap();
        assert_eq!(parsed[0].value, f64::INFINITY);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_text("no_type_sample 1\n").is_err(), "sample without TYPE");
        assert!(parse_text("# TYPE x widget\nx 1\n").is_err(), "bad type");
        assert!(parse_text("# TYPE x gauge\nx notanumber\n").is_err());
        assert!(parse_text("# TYPE 9x gauge\n").is_err(), "bad name");
        assert!(parse_text("# TYPE x gauge\nx{9bad=\"v\"} 1\n").is_err());
        assert!(parse_text("# TYPE x gauge\nx{l=\"unterminated} 1\n").is_err());
        assert!(parse_text("# random comment\n").is_err());
        assert!(parse_text("# TYPE x gauge\nx 1 123 extra\n").is_err());
    }

    #[test]
    fn parser_handles_labels_and_escapes() {
        let s = parse_text("# TYPE m counter\nm{a=\"x\",b=\"q\\\"uo\\\\te\"} 2 1700000000000\n")
            .unwrap();
        assert_eq!(s[0].labels[0], ("a".into(), "x".into()));
        assert_eq!(s[0].labels[1], ("b".into(), "q\"uo\\te".into()));
        assert_eq!(s[0].value, 2.0);
    }
}
