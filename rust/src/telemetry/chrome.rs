//! Chrome/Perfetto trace-event JSON export.
//!
//! Serializes collected pipeline spans and (optionally) a simulated
//! execution schedule into the trace-event format understood by
//! `chrome://tracing` and <https://ui.perfetto.dev>: a `traceEvents`
//! array of complete (`ph:"X"`) events with microsecond `ts`/`dur`,
//! plus metadata (`ph:"M"`) events naming processes and threads.
//!
//! Two synthetic "processes" keep the tracks apart:
//!
//! * **pid 1 — "baechi pipeline"**: one track per worker thread, one
//!   event per span (request, optimize, place, expand, simulate,
//!   cache_hit, queued). Span ids and parent ids ride in `args`, so
//!   nesting is recoverable even though trace-event rendering already
//!   nests by time containment per track.
//! * **pid 2 — "simulated plan"**: one track per device (op intervals)
//!   and one per interconnect link (transfer intervals; a transfer
//!   crossing k links appears on all k of its path tracks). Timestamps
//!   are simulated seconds into the step, scaled to µs.

use crate::graph::OpGraph;
use crate::sim::SimSchedule;
use crate::telemetry::tracer::SpanRecord;
use crate::topology::Topology;
use crate::util::json::Json;

const PIPELINE_PID: u64 = 1;
const SIM_PID: u64 = 2;

/// The simulated-plan side of an export: which graph and topology the
/// schedule's indices refer to. When `attribution` is supplied, events
/// on the critical path carry `crit: true` and `crit_category` args so
/// Perfetto queries (`SELECT ... WHERE EXTRACT_ARG(arg_set_id,
/// 'args.crit')`) can highlight the path.
pub struct SimTrack<'a> {
    pub graph: &'a OpGraph,
    pub topo: &'a Topology,
    pub schedule: &'a SimSchedule,
    pub attribution: Option<&'a crate::explain::Attribution>,
}

fn meta(pid: u64, tid: Option<u64>, kind: &str, name: &str) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", "M").set("pid", pid).set("name", kind);
    if let Some(tid) = tid {
        ev.set("tid", tid);
    }
    let mut args = Json::obj();
    args.set("name", name);
    ev.set("args", args);
    ev
}

fn complete(pid: u64, tid: u64, name: &str, start_s: f64, end_s: f64, args: Json) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", "X")
        .set("pid", pid)
        .set("tid", tid)
        .set("name", name)
        .set("ts", start_s * 1e6)
        .set("dur", (end_s - start_s).max(0.0) * 1e6)
        .set("args", args);
    ev
}

/// Label for a topology endpoint: devices first, then switches.
fn endpoint_label(topo: &Topology, e: usize) -> String {
    if e < topo.n() {
        format!("gpu{e}")
    } else {
        format!("sw{}", e - topo.n())
    }
}

/// Serialize spans (and optionally a simulated schedule) to a
/// trace-event JSON document.
pub fn chrome_trace(spans: &[SpanRecord], sim: Option<SimTrack<'_>>) -> Json {
    let mut events: Vec<Json> = Vec::new();

    // Pipeline tracks: one per worker thread that emitted a span.
    if !spans.is_empty() {
        events.push(meta(PIPELINE_PID, None, "process_name", "baechi pipeline"));
        let mut threads: Vec<u64> = spans.iter().map(|s| s.thread).collect();
        threads.sort_unstable();
        threads.dedup();
        for &t in &threads {
            events.push(meta(
                PIPELINE_PID,
                Some(t),
                "thread_name",
                &format!("worker {t}"),
            ));
        }
        for s in spans {
            let mut args = Json::obj();
            args.set("trace", s.trace.0).set("span", s.span.0);
            if let Some(p) = s.parent {
                args.set("parent", p.0);
            }
            if !s.detail.is_empty() {
                args.set("placer", s.detail.as_str());
            }
            if s.ops_in != 0 || s.ops_out != 0 {
                args.set("ops_in", s.ops_in).set("ops_out", s.ops_out);
            }
            events.push(complete(
                PIPELINE_PID,
                s.thread,
                s.name,
                s.start_s,
                s.end_s,
                args,
            ));
        }
    }

    // Simulated-plan tracks: devices 0..n, then one per link.
    if let Some(sim) = sim {
        let n = sim.topo.n();
        events.push(meta(SIM_PID, None, "process_name", "simulated plan"));
        for d in 0..n {
            events.push(meta(SIM_PID, Some(d as u64), "thread_name", &format!("gpu{d}")));
        }
        for (i, link) in sim.topo.links().iter().enumerate() {
            let name = format!(
                "link {}-{} ({})",
                endpoint_label(sim.topo, link.a),
                endpoint_label(sim.topo, link.b),
                link.kind.name()
            );
            events.push(meta(SIM_PID, Some((n + i) as u64), "thread_name", &name));
        }
        let (crit_ops, crit_xfers) = match sim.attribution {
            Some(a) => (a.crit_ops(), a.crit_transfers()),
            None => Default::default(),
        };
        for (i, op) in sim.schedule.ops.iter().enumerate() {
            let mut args = Json::obj();
            args.set("node", op.node.0).set("device", op.device);
            if let Some(cat) = crit_ops.get(&i) {
                args.set("crit", true).set("crit_category", cat.as_str());
            }
            events.push(complete(
                SIM_PID,
                op.device as u64,
                &sim.graph.node(op.node).name,
                op.start,
                op.end,
                args,
            ));
        }
        for (i, tr) in sim.schedule.transfers.iter().enumerate() {
            for &l in &tr.links {
                let mut args = Json::obj();
                args.set("node", tr.node.0)
                    .set("src", tr.src)
                    .set("dst", tr.dst)
                    .set("bytes", tr.bytes)
                    .set("link", l);
                if let Some(cat) = crit_xfers.get(&i) {
                    args.set("crit", true).set("crit_category", cat.as_str());
                }
                events.push(complete(
                    SIM_PID,
                    (n + l) as u64,
                    &format!("xfer {}", sim.graph.node(tr.node).name),
                    tr.start,
                    tr.end,
                    args,
                ));
            }
        }
    }

    let mut doc = Json::obj();
    doc.set("traceEvents", events).set("displayTimeUnit", "ms");
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::tracer::{SpanId, TraceId};

    fn span(trace: u64, id: u64, parent: Option<u64>, name: &'static str, s: f64, e: f64) -> SpanRecord {
        SpanRecord {
            trace: TraceId(trace),
            span: SpanId(id),
            parent: parent.map(SpanId),
            name,
            detail: "m-etf".to_string(),
            start_s: s,
            end_s: e,
            thread: 7,
            ops_in: 3,
            ops_out: 4,
        }
    }

    #[test]
    fn pipeline_spans_become_complete_events() {
        let spans = vec![
            span(1, 10, None, "request", 0.0, 1.0),
            span(1, 11, Some(10), "place", 0.25, 0.75),
        ];
        let doc = chrome_trace(&spans, None);
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        let req = xs.iter().find(|e| e.get("name").unwrap().as_str() == Some("request")).unwrap();
        assert_eq!(req.get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(req.get("dur").unwrap().as_f64(), Some(1e6));
        assert_eq!(req.get("pid").unwrap().as_u64(), Some(1));
        assert_eq!(req.get("tid").unwrap().as_u64(), Some(7));
        let place = xs.iter().find(|e| e.get("name").unwrap().as_str() == Some("place")).unwrap();
        let args = place.get("args").unwrap();
        assert_eq!(args.get("parent").unwrap().as_u64(), Some(10));
        assert_eq!(args.get("trace").unwrap().as_u64(), Some(1));
        assert_eq!(args.get("placer").unwrap().as_str(), Some("m-etf"));
        // Metadata names the process and the worker thread.
        assert!(events.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("M")
                && e.get("name").unwrap().as_str() == Some("process_name")
        }));
        assert!(events.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("M")
                && e.get("name").unwrap().as_str() == Some("thread_name")
                && e.get("tid").map(|t| t.as_u64()) == Some(Some(7))
        }));
    }

    #[test]
    fn sim_track_maps_ops_to_device_tids_and_transfers_to_link_tids() {
        use crate::graph::{OpGraph, OpKind};
        use crate::profile::CommModel;
        use crate::sim::{OpSpan, SimSchedule, TransferSpan};

        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 10);
        let topo = Topology::uniform(2, CommModel::new(0.0, 1.0).unwrap());
        let sched = SimSchedule {
            ops: vec![
                OpSpan { node: a, device: 0, start: 0.0, end: 1.0 },
                OpSpan { node: b, device: 1, start: 11.0, end: 12.0 },
            ],
            transfers: vec![TransferSpan {
                node: a,
                src: 0,
                dst: 1,
                bytes: 10,
                links: vec![0, 1],
                start: 1.0,
                end: 11.0,
            }],
        };
        let attribution = crate::explain::attribute(&g, &sched, sched.max_end());
        let doc = chrome_trace(
            &[],
            Some(SimTrack {
                graph: &g,
                topo: &topo,
                schedule: &sched,
                attribution: Some(&attribution),
            }),
        );
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        // 2 op events + 1 transfer × 2 path links.
        assert_eq!(xs.len(), 4);
        let op_b = xs.iter().find(|e| e.get("name").unwrap().as_str() == Some("b")).unwrap();
        assert_eq!(op_b.get("pid").unwrap().as_u64(), Some(2));
        assert_eq!(op_b.get("tid").unwrap().as_u64(), Some(1));
        let xfers: Vec<&&Json> = xs
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some("xfer a"))
            .collect();
        assert_eq!(xfers.len(), 2);
        // Link tracks start after the device tracks (tid = n + link).
        for x in &xfers {
            let tid = x.get("tid").unwrap().as_u64().unwrap();
            assert!(tid >= 2 && tid < 4);
            assert_eq!(x.get("dur").unwrap().as_f64(), Some(10.0 * 1e6));
        }
        // The whole a → xfer → b chain defines the makespan, so every
        // event carries the critical-path annotation.
        for e in &xs {
            let args = e.get("args").unwrap();
            assert_eq!(args.get("crit").unwrap().as_bool(), Some(true));
            assert!(args.get("crit_category").unwrap().as_str().is_some());
        }
        // The max interval end across X events reconstructs max_end.
        let max_end_us = xs
            .iter()
            .map(|e| {
                e.get("ts").unwrap().as_f64().unwrap() + e.get("dur").unwrap().as_f64().unwrap()
            })
            .fold(0.0, f64::max);
        assert!((max_end_us - sched.max_end() * 1e6).abs() < 1e-6);
    }

    #[test]
    fn empty_export_is_still_valid_json() {
        let doc = chrome_trace(&[], None);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }
}
