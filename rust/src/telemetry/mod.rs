//! End-to-end telemetry: request tracing, Chrome-trace export, and
//! Prometheus metrics exposition.
//!
//! The paper's headline claim is placement *speed* (654×–206,000× faster
//! than learning-based planners), which makes the placement pipeline
//! itself a latency-sensitive serving system — and a serving system
//! needs to show where a request spends its time. This layer provides
//! the three standard observability surfaces over the engine and the
//! service, with zero external dependencies:
//!
//! * **Spans & trace IDs** ([`tracer`]) — a [`Tracer`] mints one trace
//!   id per placement request (at [`crate::serve::PlacementService`]
//!   intake, or per [`crate::engine::PlacementEngine::place`] call) and
//!   times each pipeline stage as a span nested under the request span.
//!   Spans land in a bounded, lock-sharded collector; when tracing is
//!   off and no listeners are attached, opening a span is a single
//!   relaxed atomic load and nothing else. The engine's legacy
//!   [`crate::engine::PlacementObserver`] hooks are fed by a
//!   span-close listener ([`SpanListener`]), so observers keep working
//!   unchanged whether or not spans are being collected.
//! * **Chrome trace-event export** ([`chrome`]) — serializes collected
//!   spans (one track per worker thread) and the execution simulator's
//!   schedule (one track per device and per interconnect link, from
//!   [`crate::sim::SimSchedule`]) to Chrome/Perfetto trace-event JSON.
//!   `baechi trace --model … --out trace.json` writes a file that opens
//!   directly in `chrome://tracing` or <https://ui.perfetto.dev>.
//! * **Prometheus exposition** ([`prometheus`]) — renders
//!   [`crate::serve::ServiceMetrics`] + engine cache counters + tracer
//!   counters in text format 0.0.4, surfaced as
//!   `PlacementService::metrics_text()` and served by the minimal
//!   HTTP/1.1 listener in [`http`] (`baechi serve-bench
//!   --metrics-addr 127.0.0.1:9184`).
//!
//! Collection is controlled by the `BAECHI_TRACE` environment variable
//! (any value except `0|false|off|no` enables it) or explicitly via
//! [`crate::engine::PlacementEngineBuilder::tracing`]. Log lines gain a
//! `t=<trace id>` context while a span is open on the logging thread
//! (see [`crate::util::log`]).

pub mod chrome;
pub mod http;
pub mod prometheus;
pub mod tracer;

pub use chrome::{chrome_trace, SimTrack};
pub use http::MetricsServer;
pub use tracer::{Span, SpanId, SpanListener, SpanRecord, TraceId, TraceStats, Tracer};

/// Whether the `BAECHI_TRACE` environment variable asks for span
/// collection. Unset, empty, `0`, `false`, `off`, and `no` mean off;
/// anything else means on.
pub fn env_tracing_enabled() -> bool {
    match std::env::var("BAECHI_TRACE") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "" | "0" | "false" | "off" | "no"
        ),
        Err(_) => false,
    }
}
