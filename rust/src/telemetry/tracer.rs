//! Request tracing: trace IDs, span guards, and a bounded lock-sharded
//! span collector.
//!
//! A [`Tracer`] is owned by the placement engine. Each top-level
//! `place` call opens a *request span* (minting a fresh [`TraceId`]
//! unless the caller stamped one on the request), and each pipeline
//! stage opens a child span under it. Spans are RAII guards: they
//! capture a start timestamp on open and emit a [`SpanRecord`] on drop.
//!
//! The hot path is engineered around one question — "is anyone
//! watching?" — answered by a single relaxed atomic load
//! ([`Tracer::is_live`]). The tracer is live when span *collection* is
//! enabled or at least one [`SpanListener`] is attached (the engine
//! bridges legacy `PlacementObserver`s through a listener). When not
//! live, every span constructor returns an inert guard whose drop does
//! nothing: no clock reads, no allocation, no locks.
//!
//! Collected records land in a fixed number of mutex-sharded buffers,
//! each individually bounded; a full shard counts a drop instead of
//! growing, so a runaway trace can never exhaust memory. While a span
//! is open, the logging layer's thread-local trace context is set to
//! its trace id, so `info!`/`debug!` lines emitted from inside the
//! pipeline carry `t=<id>` (see [`crate::util::log`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::log;

/// Identifies one placement request end to end. Minted by
/// [`Tracer::mint_trace`]; never zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

/// Identifies one span within the tracer's lifetime. Never zero.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpanId(pub u64);

/// One closed span: a named interval on a thread, attributed to a
/// trace, optionally nested under a parent span.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub trace: TraceId,
    pub span: SpanId,
    pub parent: Option<SpanId>,
    /// Stage name ("request", "optimize", "place", "expand",
    /// "simulate", "cache_hit", "queued", ...).
    pub name: &'static str,
    /// Free-form annotation; for pipeline stages this is the placer
    /// name, which the observer bridge forwards as `StageStats.placer`.
    pub detail: String,
    /// Seconds since the tracer's epoch.
    pub start_s: f64,
    pub end_s: f64,
    /// Stable per-thread id (small integers in spawn order), used by
    /// the Chrome exporter as the track id.
    pub thread: u64,
    pub ops_in: usize,
    pub ops_out: usize,
}

/// Receives every closed span, live or collected. Listeners are
/// attached before the tracer is shared (no lock on the emit path) and
/// must be cheap: they run inline on the traced thread.
pub trait SpanListener: Send + Sync {
    fn on_close(&self, record: &SpanRecord);
}

/// Counters for the Prometheus surface.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Spans stored in the collector since construction (drained spans
    /// still count).
    pub recorded: u64,
    /// Spans lost to a full shard.
    pub dropped: u64,
    /// Whether span collection is currently enabled.
    pub collecting: bool,
}

const SHARDS: usize = 8;

/// Default per-tracer bound on collected spans (across all shards).
pub const DEFAULT_SPAN_CAPACITY: usize = 65_536;

/// Span factory and bounded collector. See the module docs for the
/// liveness model.
pub struct Tracer {
    /// `collecting || !listeners.is_empty()` — the one flag the hot
    /// path reads.
    live: AtomicBool,
    collecting: AtomicBool,
    epoch: Instant,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    shards: Vec<Mutex<Vec<SpanRecord>>>,
    shard_capacity: usize,
    recorded: AtomicU64,
    dropped: AtomicU64,
    listeners: Vec<Arc<dyn SpanListener>>,
}

impl Tracer {
    /// A tracer that can hold up to `capacity` spans before dropping.
    /// Collection starts disabled; call [`set_collecting`] or attach a
    /// listener to make the tracer live.
    ///
    /// [`set_collecting`]: Tracer::set_collecting
    pub fn new(capacity: usize) -> Self {
        let shard_capacity = capacity.div_ceil(SHARDS).max(1);
        Tracer {
            live: AtomicBool::new(false),
            collecting: AtomicBool::new(false),
            epoch: Instant::now(),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            shards: (0..SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
            shard_capacity,
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            listeners: Vec::new(),
        }
    }

    /// Attach a close listener. Requires exclusive access — the engine
    /// builder calls this before wrapping the tracer in an `Arc` — so
    /// the emit path can iterate listeners without a lock.
    pub fn add_listener(&mut self, listener: Arc<dyn SpanListener>) {
        self.listeners.push(listener);
        self.live.store(true, Ordering::Release);
    }

    /// Enable or disable span collection. Listeners keep firing either
    /// way.
    pub fn set_collecting(&self, on: bool) {
        self.collecting.store(on, Ordering::Release);
        self.live
            .store(on || !self.listeners.is_empty(), Ordering::Release);
    }

    pub fn collecting(&self) -> bool {
        self.collecting.load(Ordering::Acquire)
    }

    /// The no-op fast path: false means spans are inert guards.
    #[inline]
    pub fn is_live(&self) -> bool {
        self.live.load(Ordering::Relaxed)
    }

    /// Seconds since this tracer was constructed.
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// A fresh, unique, non-zero trace id.
    pub fn mint_trace(&self) -> TraceId {
        TraceId(self.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    /// `Some(fresh id)` when live, `None` otherwise. Used by the
    /// service to stamp requests only when someone is watching.
    pub fn active_trace_id(&self) -> Option<TraceId> {
        if self.is_live() {
            Some(self.mint_trace())
        } else {
            None
        }
    }

    fn mint_span(&self) -> SpanId {
        SpanId(self.next_span.fetch_add(1, Ordering::Relaxed))
    }

    /// Open the top-level span for one placement request. `trace` is
    /// the id stamped on the request by the service (propagation), or
    /// `None` to mint one here. Inert when the tracer is not live.
    pub fn request_span(&self, trace: Option<u64>, placer: &str) -> Span<'_> {
        if !self.is_live() {
            return Span::inert();
        }
        let trace = match trace {
            Some(t) if t != 0 => TraceId(t),
            _ => self.mint_trace(),
        };
        Span::open(self, trace, None, "request", placer.to_string())
    }

    /// Open a stage span nested under `parent`. An inert parent yields
    /// an inert child, so stage code never checks liveness itself.
    pub fn child(&self, parent: &Span<'_>, name: &'static str, detail: &str) -> Span<'_> {
        match parent.ids {
            Some((trace, span)) => Span::open(self, trace, Some(span), name, detail.to_string()),
            None => Span::inert(),
        }
    }

    /// Book a span whose interval was measured externally (cache hits
    /// timed around a lock-free lookup, queue-wait intervals measured
    /// by the service). Timestamps are seconds since this tracer's
    /// epoch. No-op when not live.
    #[allow(clippy::too_many_arguments)]
    pub fn record_at(
        &self,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &'static str,
        detail: &str,
        start_s: f64,
        end_s: f64,
        ops_in: usize,
        ops_out: usize,
    ) -> SpanId {
        let span = self.mint_span();
        if self.is_live() {
            self.emit(SpanRecord {
                trace,
                span,
                parent,
                name,
                detail: detail.to_string(),
                start_s,
                end_s,
                thread: thread_track_id(),
                ops_in,
                ops_out,
            });
        }
        span
    }

    fn emit(&self, record: SpanRecord) {
        for l in &self.listeners {
            l.on_close(&record);
        }
        if !self.collecting() {
            return;
        }
        let shard = (record.span.0 as usize) % SHARDS;
        let mut buf = self.shards[shard].lock().unwrap();
        if buf.len() >= self.shard_capacity {
            drop(buf);
            self.dropped.fetch_add(1, Ordering::Relaxed);
        } else {
            buf.push(record);
            drop(buf);
            self.recorded.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Remove and return every collected span, ordered by start time.
    pub fn drain(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.append(&mut shard.lock().unwrap());
        }
        out.sort_by(|a, b| a.start_s.total_cmp(&b.start_s));
        out
    }

    pub fn stats(&self) -> TraceStats {
        TraceStats {
            recorded: self.recorded.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            collecting: self.collecting(),
        }
    }
}

/// RAII span guard. Created by [`Tracer::request_span`] /
/// [`Tracer::child`]; records its interval when dropped. The inert
/// form (tracer not live) carries no tracer reference and drops for
/// free.
pub struct Span<'t> {
    tracer: Option<&'t Tracer>,
    /// `(trace, span)` — present even for inert spans' children check.
    ids: Option<(TraceId, SpanId)>,
    parent: Option<SpanId>,
    name: &'static str,
    detail: String,
    start_s: f64,
    ops_in: usize,
    ops_out: usize,
    /// Previous log trace context, restored on drop.
    prev_log_ctx: u64,
}

impl<'t> Span<'t> {
    fn inert() -> Span<'static> {
        Span {
            tracer: None,
            ids: None,
            parent: None,
            name: "",
            detail: String::new(),
            start_s: 0.0,
            ops_in: 0,
            ops_out: 0,
            prev_log_ctx: 0,
        }
    }

    fn open(
        tracer: &'t Tracer,
        trace: TraceId,
        parent: Option<SpanId>,
        name: &'static str,
        detail: String,
    ) -> Span<'t> {
        let span = tracer.mint_span();
        let prev_log_ctx = log::set_trace_context(trace.0);
        Span {
            tracer: Some(tracer),
            ids: Some((trace, span)),
            parent,
            name,
            detail,
            start_s: tracer.now_s(),
            ops_in: 0,
            ops_out: 0,
            prev_log_ctx,
        }
    }

    /// The trace id this span belongs to, if it is live.
    pub fn trace_id(&self) -> Option<TraceId> {
        self.ids.map(|(t, _)| t)
    }

    /// The span's own id, if it is live.
    pub fn span_id(&self) -> Option<SpanId> {
        self.ids.map(|(_, s)| s)
    }

    /// Attach op counts (forwarded to `StageStats` by the observer
    /// bridge).
    pub fn annotate(&mut self, ops_in: usize, ops_out: usize) {
        self.ops_in = ops_in;
        self.ops_out = ops_out;
    }

    /// Replace the free-form annotation.
    pub fn set_detail(&mut self, detail: &str) {
        if self.tracer.is_some() {
            self.detail = detail.to_string();
        }
    }

    /// Disarm the span: restore the log context now and emit nothing on
    /// drop. Used when the measured operation failed — pre-telemetry
    /// observers reported nothing for failed stages, and the bridge
    /// keeps that contract.
    pub fn cancel(&mut self) {
        if self.tracer.take().is_some() {
            log::set_trace_context(self.prev_log_ctx);
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(tracer) = self.tracer else { return };
        let (trace, span) = self.ids.expect("live span has ids");
        log::set_trace_context(self.prev_log_ctx);
        tracer.emit(SpanRecord {
            trace,
            span,
            parent: self.parent,
            name: self.name,
            detail: std::mem::take(&mut self.detail),
            start_s: self.start_s,
            end_s: tracer.now_s(),
            thread: thread_track_id(),
            ops_in: self.ops_in,
            ops_out: self.ops_out,
        });
    }
}

/// Stable small-integer thread id, assigned in first-use order. Rust's
/// `ThreadId` has no stable integer form, and Chrome's `tid` renders
/// best as a small number.
pub fn thread_track_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static ID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|id| *id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn not_live_spans_are_inert_and_free() {
        let tracer = Tracer::new(128);
        assert!(!tracer.is_live());
        assert!(tracer.active_trace_id().is_none());
        {
            let root = tracer.request_span(None, "m-etf");
            assert!(root.trace_id().is_none());
            let child = tracer.child(&root, "place", "m-etf");
            assert!(child.span_id().is_none());
        }
        assert!(tracer.drain().is_empty());
        assert_eq!(tracer.stats(), TraceStats::default());
    }

    #[test]
    fn collecting_records_nested_spans() {
        let tracer = Tracer::new(128);
        tracer.set_collecting(true);
        assert!(tracer.is_live());
        let (root_trace, root_span);
        {
            let mut root = tracer.request_span(None, "m-sct");
            root_trace = root.trace_id().unwrap();
            root_span = root.span_id().unwrap();
            {
                let mut child = tracer.child(&root, "place", "m-sct");
                child.annotate(10, 12);
            }
            root.annotate(10, 12);
        }
        let spans = tracer.drain();
        assert_eq!(spans.len(), 2);
        let root = spans.iter().find(|s| s.name == "request").unwrap();
        let child = spans.iter().find(|s| s.name == "place").unwrap();
        assert_eq!(root.trace, root_trace);
        assert_eq!(root.span, root_span);
        assert_eq!(root.parent, None);
        assert_eq!(child.trace, root_trace);
        assert_eq!(child.parent, Some(root_span));
        assert_eq!((child.ops_in, child.ops_out), (10, 12));
        assert!(child.start_s >= root.start_s);
        assert!(child.end_s <= root.end_s);
        assert!(spans.iter().all(|s| s.end_s >= s.start_s));
        assert_eq!(tracer.stats().recorded, 2);
        // Drain empties the collector but keeps counters.
        assert!(tracer.drain().is_empty());
        assert_eq!(tracer.stats().recorded, 2);
    }

    #[test]
    fn explicit_trace_id_is_propagated() {
        let tracer = Tracer::new(16);
        tracer.set_collecting(true);
        drop(tracer.request_span(Some(0xbaec1), "m-topo"));
        let spans = tracer.drain();
        assert_eq!(spans[0].trace, TraceId(0xbaec1));
    }

    #[test]
    fn capacity_bounds_collection_and_counts_drops() {
        let tracer = Tracer::new(SHARDS); // one span per shard
        tracer.set_collecting(true);
        for _ in 0..40 {
            drop(tracer.request_span(None, "p"));
        }
        let stats = tracer.stats();
        assert_eq!(stats.recorded + stats.dropped, 40);
        assert!(stats.dropped > 0);
        assert!(tracer.drain().len() <= SHARDS);
    }

    #[test]
    fn listeners_fire_without_collection() {
        struct Count(AtomicU64);
        impl SpanListener for Count {
            fn on_close(&self, _: &SpanRecord) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let count = Arc::new(Count(AtomicU64::new(0)));
        let mut tracer = Tracer::new(16);
        tracer.add_listener(count.clone());
        assert!(tracer.is_live());
        assert!(!tracer.collecting());
        {
            let root = tracer.request_span(None, "m-etf");
            drop(tracer.child(&root, "optimize", "m-etf"));
        }
        assert_eq!(count.0.load(Ordering::Relaxed), 2);
        assert!(tracer.drain().is_empty());
        assert_eq!(tracer.stats().recorded, 0);
    }

    #[test]
    fn cancelled_spans_emit_nothing() {
        let tracer = Tracer::new(16);
        tracer.set_collecting(true);
        {
            let root = tracer.request_span(None, "m-etf");
            let mut child = tracer.child(&root, "place", "m-etf");
            child.cancel();
        }
        let spans = tracer.drain();
        assert_eq!(spans.len(), 1, "only the request span survives");
        assert_eq!(spans[0].name, "request");
        assert_eq!(log::trace_context(), 0, "cancel restores the log context");
    }

    #[test]
    fn record_at_books_manual_intervals() {
        let tracer = Tracer::new(16);
        tracer.set_collecting(true);
        let trace = tracer.mint_trace();
        tracer.record_at(trace, None, "cache_hit", "m-etf", 1.0, 1.5, 7, 7);
        let spans = tracer.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "cache_hit");
        assert_eq!(spans[0].trace, trace);
        assert_eq!(spans[0].start_s, 1.0);
        assert_eq!(spans[0].end_s, 1.5);
    }
}
