//! The `PlacementEngine`: a long-lived, service-grade placement API.
//!
//! The paper's headline result — algorithmic placement is 654×–206,000×
//! faster than learning-based planners — makes placement viable as an
//! *online service*. This module is that service surface: construct one
//! engine per target cluster via the builder, then serve typed
//! [`PlacementRequest`] → [`PlacementResponse`] calls:
//!
//! ```no_run
//! use baechi::engine::{PlacementEngine, PlacementRequest};
//! use baechi::profile::{Cluster, CommModel};
//!
//! let engine = PlacementEngine::builder()
//!     .cluster(Cluster::homogeneous(4, 8 << 30, CommModel::pcie_via_host()))
//!     .build()?;
//! let resp = engine.place(&PlacementRequest::new(
//!     baechi::models::linreg::linreg_graph(),
//!     "m-sct",
//! ))?;
//! assert!(resp.devices_used >= 1);
//! # Ok::<(), baechi::BaechiError>(())
//! ```
//!
//! * **Registry** — placers resolve by name through [`PlacerRegistry`];
//!   register your own with [`PlacementEngineBuilder::register_placer`].
//! * **Cache** — responses are memoized by (graph, cluster, optimizer,
//!   placer) fingerprint in a sharded, size-bounded LRU ([`cache`]);
//!   repeated requests (the serving scenario) return the cached `Arc`
//!   without re-running the placer, and observers see the hit as a
//!   [`Stage::CacheHit`]. Capacity and shard count are builder knobs.
//! * **Batching** — [`PlacementEngine::place_batch`] fans a slice of
//!   requests across OS threads via `std::thread::scope`.
//! * **Observability** — every request runs under a telemetry span tree
//!   ([`crate::telemetry::Tracer`]): a per-request trace id (stamped by
//!   the caller via [`PlacementRequest::with_trace`] or minted at
//!   intake) plus one child span per stage (optimize / place / expand /
//!   simulate; cache hits book a `cache_hit` span). When the tracer is
//!   not live the span guards are inert — a single relaxed atomic load.
//!   Legacy [`PlacementObserver`] hooks keep working: an internal
//!   bridge replays closed stage spans as `on_stage` callbacks.
//! * **Re-placement** — [`PlacementEngine::place_iterative`] closes the
//!   sim → placer loop: simulate, degrade saturated links by the
//!   observed queueing ([`crate::feedback`]), re-place, keep the best.
//!   [`PlacementEngine::place_iterative_measured`] seeds the loop with a
//!   *measured* contention report ([`crate::calibrate::measured_report`])
//!   instead of the simulator's.
//! * **Typed errors** — every failure is a [`BaechiError`] variant.

pub mod cache;
pub mod fingerprint;
pub mod observer;
pub mod registry;

pub use cache::{CacheStats, ShardedLru};
pub use observer::{LogObserver, PlacementObserver, RecordingObserver, Stage, StageStats};
pub use registry::{PlacerContext, PlacerRegistration, PlacerRegistry, ResolvedPlacer};

use crate::error::BaechiError;
use crate::explain::record::{AttributionTotals, FlightRecorder, RecorderStats, RunRecord};
use crate::feedback::{ReplacementPolicy, ReplacementRound, TopologyAdjustment};
use crate::graph::OpGraph;
use crate::hierarchy::CoarsenConfig;
use crate::models::Benchmark;
use crate::optimizer::{self, OptConfig, OptStats};
use crate::placer::Placement;
use crate::profile::Cluster;
use crate::sim::{self, SimConfig, SimResult};
use crate::telemetry::tracer::{SpanId, TraceId, Tracer, DEFAULT_SPAN_CAPACITY};
use crate::topology::Topology;
use std::borrow::Cow;
use std::sync::Arc;
use std::time::Instant;

/// Default total cost budget of the placement cache, in retained plan ops
/// (each entry costs its op count + 1). Generous: tens of thousands of
/// typical model graphs fit before anything is evicted.
pub const DEFAULT_CACHE_CAPACITY: u64 = 4 << 20;
/// Default shard count of the placement cache.
pub const DEFAULT_CACHE_SHARDS: usize = 16;

/// One placement request: the graph to place and how to place it.
#[derive(Debug, Clone)]
pub struct PlacementRequest {
    /// The operator graph to place.
    pub graph: OpGraph,
    /// Placer spec resolved against the registry (`"m-sct"`, `"rl:500"`).
    pub placer: String,
    /// Benchmark identity, required by model-keyed placers (the expert).
    pub benchmark: Option<Benchmark>,
    /// Per-request optimizer override (None = the engine's default).
    pub opt: Option<OptConfig>,
    /// Per-request interconnect-topology override (None = the engine
    /// cluster's own topology). Part of the cache fingerprint: requests
    /// differing only in topology never share a cached plan.
    pub topology: Option<Topology>,
    /// Hierarchical-coarsening knobs for the `hier` placer (None = the
    /// placer's defaults; a spec arg like `"hier:128"` still wins). Part
    /// of the cache fingerprint.
    pub coarsen: Option<CoarsenConfig>,
    /// Evaluate the expanded placement in the execution simulator.
    pub simulate: bool,
    /// Telemetry trace id to attribute this request's spans to (stamped
    /// by the serving layer at intake; `None` or `0` mints a fresh id
    /// when tracing is live). Deliberately **not** part of the cache
    /// key: tracing never changes what is served.
    pub trace: Option<u64>,
}

impl PlacementRequest {
    pub fn new(graph: OpGraph, placer: &str) -> PlacementRequest {
        PlacementRequest {
            graph,
            placer: placer.to_string(),
            benchmark: None,
            opt: None,
            topology: None,
            coarsen: None,
            simulate: true,
            trace: None,
        }
    }

    /// Request over a paper benchmark (generates the graph and carries
    /// the identity for the expert placer).
    pub fn for_benchmark(benchmark: Benchmark, placer: &str) -> PlacementRequest {
        PlacementRequest {
            benchmark: Some(benchmark),
            ..PlacementRequest::new(benchmark.graph(), placer)
        }
    }

    /// Override the optimizer configuration for this request.
    pub fn with_opt(mut self, opt: OptConfig) -> PlacementRequest {
        self.opt = Some(opt);
        self
    }

    /// Place against an explicit interconnect topology instead of the
    /// engine cluster's (must cover the same device count).
    pub fn with_topology(mut self, topology: Topology) -> PlacementRequest {
        self.topology = Some(topology);
        self
    }

    /// Override the hierarchical-coarsening knobs for this request
    /// (consumed by the `hier` placer; other placers ignore it).
    pub fn with_coarsening(mut self, cfg: CoarsenConfig) -> PlacementRequest {
        self.coarsen = Some(cfg);
        self
    }

    /// Skip the execution-simulator evaluation.
    pub fn without_simulation(mut self) -> PlacementRequest {
        self.simulate = false;
        self
    }

    /// Attribute this request's telemetry spans to an existing trace id
    /// (end-to-end propagation across service → engine → stages).
    pub fn with_trace(mut self, trace: u64) -> PlacementRequest {
        self.trace = Some(trace);
        self
    }
}

/// Everything one placement request produces.
#[derive(Debug, Clone)]
pub struct PlacementResponse {
    /// The resolved algorithm name (e.g. `"m-sct(lp)"`).
    pub placer: String,
    /// The placement, expanded onto the *original* request graph.
    /// `predicted_makespan` / `placement_time` / `peak_memory` come from
    /// the placement-time schedule on the optimized meta-graph.
    pub placement: Placement,
    /// Optimizer reduction statistics (Table 6 columns).
    pub stats: OptStats,
    /// Execution-simulator verdict (None when the request skipped it).
    pub sim: Option<SimResult>,
    /// Distinct devices used by the expanded placement.
    pub devices_used: usize,
}

/// Outcome of [`PlacementEngine::place_iterative`]: the best placement
/// found plus the per-round trajectory of the feedback loop.
#[derive(Debug, Clone)]
pub struct IterativePlacement {
    /// The best round's response; its `sim` field is the evaluation on
    /// the *real* (unadjusted) topology.
    pub response: Arc<PlacementResponse>,
    /// Simulated makespan of the single-shot (round 0) placement. NaN
    /// in exactly one case: a 0-round policy over a request that asked
    /// to skip simulation (the call is then bit-identical to `place`,
    /// so there is no simulator verdict to report; with rounds > 0 the
    /// request is upgraded to simulate instead).
    pub baseline_makespan: f64,
    /// Round trajectory, starting with round 0 (empty when the policy's
    /// round budget is 0 — the call degenerated to a plain `place`).
    pub rounds: Vec<ReplacementRound>,
}

impl IterativePlacement {
    /// Simulated makespan of the returned placement.
    pub fn final_makespan(&self) -> f64 {
        self.response
            .sim
            .as_ref()
            .map(|s| s.makespan)
            .unwrap_or(self.baseline_makespan)
    }

    /// Relative makespan recovered over the single-shot baseline
    /// (0 when re-placement never beat round 0).
    pub fn improvement(&self) -> f64 {
        crate::feedback::relative_gain(self.baseline_makespan, self.final_makespan())
    }
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct CacheKey {
    graph: u64,
    cluster: u64,
    opt: u64,
    sim: u64,
    /// Coarsening-override fingerprint (`0` = request carried none).
    coarsen: u64,
    placer: String,
    /// Benchmark identity — part of the key because benchmark-keyed
    /// placers (the expert) produce different placements for the same
    /// graph under different identities.
    benchmark: Option<String>,
}

impl CacheKey {
    /// Fingerprint of the whole key; its top bits pick the cache shard.
    fn shard_fp(&self) -> u64 {
        let mut h = fingerprint::Fnv::new();
        h.write_u64(self.graph);
        h.write_u64(self.cluster);
        h.write_u64(self.opt);
        h.write_u64(self.sim);
        h.write_u64(self.coarsen);
        h.write_str(&self.placer);
        h.write_opt_str(self.benchmark.as_deref());
        h.finish()
    }
}

/// Builder for [`PlacementEngine`]. `cluster` is mandatory; everything
/// else defaults (paper optimizer config, TF-semantics simulator, the
/// built-in placer registry, no observers, span collection from the
/// `BAECHI_TRACE` environment variable, a generously bounded sharded
/// cache).
pub struct PlacementEngineBuilder {
    cluster: Option<Cluster>,
    opt: OptConfig,
    sim: SimConfig,
    registry: PlacerRegistry,
    observers: Vec<Arc<dyn PlacementObserver>>,
    cache_capacity: u64,
    cache_shards: usize,
    /// `None` defers to `BAECHI_TRACE` at build time.
    tracing: Option<bool>,
    trace_capacity: usize,
    /// `None` defers to `BAECHI_RUN_HISTORY` at build time.
    run_history: Option<(String, u64)>,
}

impl PlacementEngineBuilder {
    fn new() -> PlacementEngineBuilder {
        PlacementEngineBuilder {
            cluster: None,
            opt: OptConfig::default(),
            sim: SimConfig::default(),
            registry: PlacerRegistry::with_builtins(),
            observers: Vec::new(),
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            cache_shards: DEFAULT_CACHE_SHARDS,
            tracing: None,
            trace_capacity: DEFAULT_SPAN_CAPACITY,
            run_history: None,
        }
    }

    /// Target cluster the engine serves placements for (required).
    pub fn cluster(mut self, cluster: Cluster) -> PlacementEngineBuilder {
        self.cluster = Some(cluster);
        self
    }

    /// Default optimizer configuration (overridable per request).
    pub fn optimizer(mut self, opt: OptConfig) -> PlacementEngineBuilder {
        self.opt = opt;
        self
    }

    /// Execution-simulator configuration.
    pub fn sim(mut self, sim: SimConfig) -> PlacementEngineBuilder {
        self.sim = sim;
        self
    }

    /// Replace the registry wholesale (e.g. [`PlacerRegistry::empty`]).
    pub fn registry(mut self, registry: PlacerRegistry) -> PlacementEngineBuilder {
        self.registry = registry;
        self
    }

    /// Register an additional placer by name.
    pub fn register_placer(
        mut self,
        name: &str,
        registration: PlacerRegistration,
    ) -> PlacementEngineBuilder {
        self.registry.register(name, registration);
        self
    }

    /// Attach a stage observer.
    pub fn observer(mut self, observer: Arc<dyn PlacementObserver>) -> PlacementEngineBuilder {
        self.observers.push(observer);
        self
    }

    /// Total cost budget of the placement cache (entry cost = plan ops + 1;
    /// clamped to ≥ 1). Least-recently-used entries are evicted beyond it.
    pub fn cache_capacity(mut self, capacity: u64) -> PlacementEngineBuilder {
        self.cache_capacity = capacity;
        self
    }

    /// Shard count of the placement cache (clamped to ≥ 1). More shards
    /// mean less lock contention between concurrent serving threads.
    pub fn cache_shards(mut self, shards: usize) -> PlacementEngineBuilder {
        self.cache_shards = shards;
        self
    }

    /// Enable or disable telemetry span collection explicitly. Without
    /// this call the engine defers to the `BAECHI_TRACE` environment
    /// variable (off unless set to a truthy value).
    pub fn tracing(mut self, on: bool) -> PlacementEngineBuilder {
        self.tracing = Some(on);
        self
    }

    /// Bound on spans held by the tracer before drops are counted
    /// instead (default [`DEFAULT_SPAN_CAPACITY`]).
    pub fn trace_capacity(mut self, capacity: usize) -> PlacementEngineBuilder {
        self.trace_capacity = capacity;
        self
    }

    /// Record every served placement to an append-only JSONL run
    /// history at `path` (rotated past `max_bytes` — see
    /// [`crate::explain::record::FlightRecorder`]). Without this call
    /// the engine defers to the `BAECHI_RUN_HISTORY` /
    /// `BAECHI_RUN_HISTORY_MAX_BYTES` environment variables (off unless
    /// set). Recording never changes what is served: the cache key is
    /// untouched and append failures are dropped, not surfaced.
    pub fn run_history(mut self, path: impl Into<String>, max_bytes: u64) -> PlacementEngineBuilder {
        self.run_history = Some((path.into(), max_bytes));
        self
    }

    pub fn build(self) -> crate::Result<PlacementEngine> {
        let cluster = self.cluster.ok_or_else(|| {
            BaechiError::invalid("PlacementEngine::builder(): a cluster is required")
        })?;
        if cluster.n() == 0 {
            return Err(BaechiError::invalid(
                "PlacementEngine::builder(): cluster has no devices",
            ));
        }
        let mut tracer = Tracer::new(self.trace_capacity);
        if !self.observers.is_empty() {
            tracer.add_listener(Arc::new(observer::ObserverBridge::new(self.observers)));
        }
        tracer.set_collecting(
            self.tracing
                .unwrap_or_else(crate::telemetry::env_tracing_enabled),
        );
        let recorder = match self.run_history.or_else(crate::explain::env_run_history) {
            Some((path, max_bytes)) => Some(Arc::new(FlightRecorder::open(path, max_bytes)?)),
            None => None,
        };
        Ok(PlacementEngine {
            recorder,
            last_attribution: std::sync::Mutex::new(None),
            cluster_fp: fingerprint::cluster_fingerprint(&cluster),
            topo_fp: fingerprint::topology_fingerprint(&cluster.effective_topology()),
            sim_fp: fingerprint::sim_fingerprint(&self.sim),
            cluster,
            opt: self.opt,
            sim: self.sim,
            registry: self.registry,
            tracer: Arc::new(tracer),
            cache: ShardedLru::new(self.cache_shards, self.cache_capacity),
        })
    }
}

/// A request's resolved cache identity (see [`PlacementEngine::keyed`]).
struct Keyed<'req> {
    key: CacheKey,
    override_t: Option<(&'req Topology, u64)>,
    ocfg: OptConfig,
    resolved: ResolvedPlacer,
}

/// The long-lived placement service. Thread-safe: share it by reference
/// (or `Arc`) and call [`PlacementEngine::place`] from many threads.
pub struct PlacementEngine {
    cluster: Cluster,
    opt: OptConfig,
    sim: SimConfig,
    registry: PlacerRegistry,
    tracer: Arc<Tracer>,
    cache: ShardedLru<CacheKey, Arc<PlacementResponse>>,
    /// Run-history flight recorder (None = recording disabled).
    recorder: Option<Arc<FlightRecorder>>,
    /// Most recent critical-path attribution totals (feeds the
    /// `baechi_critical_path_fraction` gauge). Only written when run
    /// history is enabled — the attribution walk rides the recorder.
    last_attribution: std::sync::Mutex<Option<AttributionTotals>>,
    cluster_fp: u64,
    /// Fingerprint of the engine cluster's own topology, to recognize
    /// per-request overrides that change nothing.
    topo_fp: u64,
    sim_fp: u64,
}

impl PlacementEngine {
    pub fn builder() -> PlacementEngineBuilder {
        PlacementEngineBuilder::new()
    }

    /// The cluster this engine places onto.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// The placer registry (for name listing / introspection).
    pub fn registry(&self) -> &PlacerRegistry {
        &self.registry
    }

    /// The engine's default simulator configuration.
    pub fn sim_config(&self) -> SimConfig {
        self.sim
    }

    /// The engine's default optimizer configuration.
    pub fn opt_config(&self) -> OptConfig {
        self.opt
    }

    /// Cache hit/miss/eviction counters so far.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Number of memoized responses.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Drop every memoized response (e.g. after profile refresh).
    pub fn clear_cache(&self) {
        self.cache.clear();
    }

    /// The engine's tracer: mint/propagate trace ids, toggle span
    /// collection, drain collected spans for export.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// The run-history flight recorder, when one is configured.
    pub fn run_recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.recorder.as_ref()
    }

    /// Recorder counters (records / bytes / rotations); `None` when run
    /// history is disabled.
    pub fn recorder_stats(&self) -> Option<RecorderStats> {
        self.recorder.as_ref().map(|r| r.stats())
    }

    /// Critical-path category totals of the most recently recorded run
    /// (`None` until a simulated run is recorded). Feeds the
    /// `baechi_critical_path_fraction` Prometheus gauge.
    pub fn last_attribution(&self) -> Option<AttributionTotals> {
        *self
            .last_attribution
            .lock()
            .unwrap_or_else(|p| p.into_inner())
    }

    /// Append a [`RunRecord`] for a served response. No-op without a
    /// recorder; append failures are swallowed (recording must never
    /// fail a placement). `serve_mode` is the serving-path label
    /// (`"full"`, `"cache_hit"`, `"incremental"`). Public so the
    /// serving layer can record paths that bypass [`Self::place`]
    /// (lookup hits, incremental deltas).
    pub fn record_served(
        &self,
        req: &PlacementRequest,
        resp: &PlacementResponse,
        serve_mode: &str,
    ) {
        let Some(rec) = &self.recorder else { return };
        let mut r = RunRecord::from_graph(&req.graph, self.cluster.n(), &resp.placer, serve_mode);
        r.coarsening = req.coarsen.map(|c| {
            if c.enabled {
                format!("members:{}", c.max_members)
            } else {
                "off".to_string()
            }
        });
        if let Some(sim) = &resp.sim {
            if sim.ok() {
                r.makespan = Some(sim.makespan);
                let a = crate::explain::attribute(&req.graph, &sim.schedule, sim.makespan);
                let totals = AttributionTotals {
                    compute: a.compute,
                    transfer: a.transfer,
                    queue_wait: a.queue_wait,
                    idle: a.idle,
                };
                r.attribution = Some(totals);
                *self
                    .last_attribution
                    .lock()
                    .unwrap_or_else(|p| p.into_inner()) = Some(totals);
            }
        }
        let _ = rec.append(&r);
    }

    /// The trace id this request's telemetry books under: the caller's
    /// (when stamped non-zero), else freshly minted. `None` when the
    /// tracer is not live — nothing is recorded at all.
    fn trace_for(&self, req: &PlacementRequest) -> Option<TraceId> {
        if !self.tracer.is_live() {
            return None;
        }
        Some(match req.trace {
            Some(t) if t != 0 => TraceId(t),
            _ => self.tracer.mint_trace(),
        })
    }

    /// Book an externally timed span (`t0` = when the interval began):
    /// cache hits measured around the lock-free lookup, round
    /// simulations in the iterative loop. No-op when `trace` is `None`.
    #[allow(clippy::too_many_arguments)]
    fn record_interval(
        &self,
        trace: Option<TraceId>,
        parent: Option<SpanId>,
        name: &'static str,
        placer: &str,
        t0: Instant,
        ops_in: usize,
        ops_out: usize,
    ) {
        let Some(trace) = trace else { return };
        let end_s = self.tracer.now_s();
        let start_s = end_s - t0.elapsed().as_secs_f64();
        self.tracer
            .record_at(trace, parent, name, placer, start_s, end_s, ops_in, ops_out);
    }

    /// The optimizer config a request resolves to. `comm` is the
    /// representative model of the cluster the request will be served
    /// against (the topology override's, when present).
    fn effective_opt(
        &self,
        req: &PlacementRequest,
        comm: crate::profile::CommModel,
        optimize_graph: bool,
    ) -> OptConfig {
        if !optimize_graph {
            return OptConfig::none();
        }
        let mut o = req.opt.unwrap_or(self.opt);
        if o.fusion && o.latency_equiv_bytes == 0 {
            // Price multi-tensor fused edges consistently with the ES.
            o.latency_equiv_bytes = (comm.latency * comm.bandwidth) as u64;
        }
        o
    }

    /// Resolve everything that identifies a request's cache entry: the
    /// placer, the (possibly overridden) topology, the effective optimizer
    /// config, and the full [`CacheKey`]. Shared by [`Self::place`] and
    /// [`Self::lookup`] so a peek and the subsequent placement agree on
    /// the key bit-for-bit.
    fn keyed<'req>(&self, req: &'req PlacementRequest) -> crate::Result<Keyed<'req>> {
        let resolved = self
            .registry
            .resolve_with(&req.placer, req.benchmark, req.coarsen)?;
        // Per-request topology override: fold the topology into the
        // cluster fingerprint so the cache cannot serve a stale plan.
        // An override identical to the engine's own topology is served
        // exactly like a plain request (same cache entry), and the
        // cluster is only rebuilt on a miss — a mismatched override can
        // never be cached, so hits need no re-validation.
        let override_t: Option<(&Topology, u64)> = req.topology.as_ref().and_then(|t| {
            let fp = fingerprint::topology_fingerprint(t);
            (fp != self.topo_fp).then_some((t, fp))
        });
        let (cluster_fp, comm) = match override_t {
            Some((t, fp)) => {
                let mut h = fingerprint::Fnv::new();
                h.write_u64(self.cluster_fp);
                h.write_u64(fp);
                (h.finish(), t.representative())
            }
            None => (self.cluster_fp, self.cluster.comm),
        };
        let ocfg = self.effective_opt(req, comm, resolved.optimize_graph);
        let key = CacheKey {
            graph: fingerprint::graph_fingerprint(&req.graph),
            cluster: cluster_fp,
            opt: fingerprint::opt_fingerprint(&ocfg),
            sim: if req.simulate { self.sim_fp } else { 0 },
            coarsen: req
                .coarsen
                .map(|c| fingerprint::coarsen_fingerprint(&c))
                .unwrap_or(0),
            placer: req.placer.clone(),
            benchmark: req.benchmark.map(|b| b.name()),
        };
        Ok(Keyed {
            key,
            override_t,
            ocfg,
            resolved,
        })
    }

    /// Probe the cache without placing on a miss: `Ok(Some)` is exactly
    /// the response [`Self::place`] would return (and counts a hit +
    /// reports a [`Stage::CacheHit`]); `Ok(None)` counts nothing — the
    /// follow-up `place` call records the miss. Serving layers use this
    /// to try cheaper strategies (incremental placement) before paying
    /// for a full pipeline run.
    pub fn lookup(&self, req: &PlacementRequest) -> crate::Result<Option<Arc<PlacementResponse>>> {
        let keyed = self.keyed(req)?;
        let t0 = Instant::now();
        match self.cache.peek(keyed.key.shard_fp(), &keyed.key) {
            Some(hit) => {
                let ops = hit.placement.device_of.len();
                self.record_interval(
                    self.trace_for(req),
                    None,
                    Stage::CacheHit.name(),
                    &req.placer,
                    t0,
                    ops,
                    ops,
                );
                Ok(Some(hit))
            }
            None => Ok(None),
        }
    }

    /// Serve one request. Identical requests (same graph, cluster,
    /// topology, optimizer config, and placer spec) are answered from
    /// the cache (visible to observers as a [`Stage::CacheHit`]).
    pub fn place(&self, req: &PlacementRequest) -> crate::Result<Arc<PlacementResponse>> {
        let keyed = self.keyed(req)?;
        let Keyed {
            key,
            override_t,
            ocfg,
            resolved,
        } = keyed;
        let mut root = self.tracer.request_span(req.trace, &req.placer);
        let t0 = Instant::now();
        if let Some(hit) = self.cache.get(key.shard_fp(), &key) {
            let ops = hit.placement.device_of.len();
            self.record_interval(
                root.trace_id(),
                root.span_id(),
                Stage::CacheHit.name(),
                &req.placer,
                t0,
                ops,
                ops,
            );
            self.record_served(req, &hit, "cache_hit");
            return Ok(hit);
        }
        let cluster: Cow<'_, Cluster> = match override_t {
            Some((t, _)) => Cow::Owned(self.cluster.clone().with_topology(t.clone())?),
            None => Cow::Borrowed(&self.cluster),
        };

        // Optimize (§3.1).
        let opt = {
            let mut sp = self.tracer.child(&root, Stage::Optimize.name(), &req.placer);
            let opt = optimizer::optimize(&req.graph, &ocfg);
            sp.annotate(opt.stats.original_ops, opt.stats.placed_ops);
            opt
        };

        // Place.
        let meta = {
            let mut sp = self.tracer.child(&root, Stage::Place.name(), &req.placer);
            match resolved.placer.place(&opt.graph, &cluster) {
                Ok(meta) => {
                    sp.annotate(opt.stats.placed_ops, meta.device_of.len());
                    meta
                }
                Err(e) => {
                    sp.cancel();
                    return Err(e);
                }
            }
        };

        // Expand onto the original graph.
        let placement = {
            let mut sp = self.tracer.child(&root, Stage::Expand.name(), &req.placer);
            let full = optimizer::expand_placement(&req.graph, &opt, &meta.device_of);
            let placement = Placement {
                device_of: full,
                ..meta
            };
            sp.annotate(opt.stats.placed_ops, placement.device_of.len());
            placement
        };

        // Simulate (optional).
        let sim = if req.simulate {
            let mut sp = self.tracer.child(&root, Stage::Simulate.name(), &req.placer);
            let s = sim::simulate(&req.graph, &cluster, &placement.device_of, self.sim);
            sp.annotate(placement.device_of.len(), placement.device_of.len());
            Some(s)
        } else {
            None
        };

        let devices_used = placement.devices_used();
        root.annotate(opt.stats.original_ops, placement.device_of.len());
        let resp = Arc::new(PlacementResponse {
            placer: placement.algorithm.clone(),
            placement,
            stats: opt.stats,
            sim,
            devices_used,
        });
        let cost = resp.placement.device_of.len() as u64 + 1;
        self.cache.insert(key.shard_fp(), key, resp.clone(), cost);
        self.record_served(req, &resp, "full");
        Ok(resp)
    }

    /// Contention-driven re-placement (the sim → engine → placer loop):
    /// place, simulate, degrade the topology by the observed per-link
    /// queueing ([`TopologyAdjustment`]), and re-place until the
    /// simulated makespan stops improving or `policy.max_rounds` is
    /// exhausted. The returned response is the best round's, always
    /// evaluated on the **real** topology — the adjusted topologies are
    /// only ever the placer's cost model.
    ///
    /// With `policy.max_rounds == 0` this is exactly [`Self::place`]
    /// (same cached `Arc`, empty round list). Otherwise the simulator
    /// verdict is required, so a request with `simulate == false` is
    /// served as if it had asked for simulation. Every intermediate
    /// placement goes through the cache keyed by the adjusted
    /// topology's fingerprint, so repeating the loop re-runs no placer.
    ///
    /// Works in both comm modes: sequential clusters report serialized
    /// link waits, parallel-comm clusters report max-min fair flow
    /// slowdown (see [`crate::sim::ContentionReport`]) — the loop
    /// thresholds and adjusts on either signal identically. (Before the
    /// flow simulator landed, parallel-comm reports were empty and this
    /// loop silently degenerated to a single-shot placement.)
    pub fn place_iterative(
        &self,
        req: &PlacementRequest,
        policy: &ReplacementPolicy,
    ) -> crate::Result<IterativePlacement> {
        self.iterate(req, policy, None)
    }

    /// [`Self::place_iterative`] driven by a **measured** contention
    /// report instead of the simulator's: the supplied report (built
    /// from runtime link observations via
    /// [`crate::calibrate::measured_report`]) seeds the first topology
    /// adjustment and the round-0 trigger decision, so the loop corrects
    /// for the queueing the *real* cluster exhibited rather than what
    /// the simulator predicted. Subsequent rounds are still judged and
    /// re-observed in the simulator (the only executor that can score a
    /// candidate without deploying it).
    ///
    /// The report must cover the links of the topology the request
    /// resolves to (typed [`BaechiError::InvalidRequest`] otherwise).
    /// With `policy.max_rounds == 0` the call degenerates to
    /// [`Self::place`] bit-for-bit, exactly like `place_iterative`.
    pub fn place_iterative_measured(
        &self,
        req: &PlacementRequest,
        policy: &ReplacementPolicy,
        report: &crate::sim::ContentionReport,
    ) -> crate::Result<IterativePlacement> {
        // Validate the report against the topology the loop will adjust
        // before doing any placement work (mismatches are caller bugs).
        let topo_links = match &req.topology {
            Some(t) => t.n_links(),
            None => self.cluster.effective_topology().n_links(),
        };
        if report.links.len() != topo_links {
            return Err(BaechiError::invalid(format!(
                "measured report covers {} links but the request's topology has {topo_links}",
                report.links.len()
            )));
        }
        self.iterate(req, policy, Some(report))
    }

    fn iterate(
        &self,
        req: &PlacementRequest,
        policy: &ReplacementPolicy,
        measured: Option<&crate::sim::ContentionReport>,
    ) -> crate::Result<IterativePlacement> {
        if policy.max_rounds == 0 {
            let response = self.place(req)?;
            let baseline_makespan = response
                .sim
                .as_ref()
                .map(|s| s.makespan)
                .unwrap_or(f64::NAN);
            return Ok(IterativePlacement {
                response,
                baseline_makespan,
                rounds: Vec::new(),
            });
        }
        // One trace id covers the whole loop: the base placement, every
        // candidate round, and the round simulations all book under it.
        let trace = self.trace_for(req);
        let base = if req.simulate && req.trace == trace.map(|t| t.0) {
            self.place(req)?
        } else {
            let mut r = req.clone();
            r.simulate = true;
            r.trace = trace.map(|t| t.0).or(req.trace);
            self.place(&r)?
        };
        let base_sim = base.sim.as_ref().expect("iterative base always simulates");
        let baseline_makespan = base_sim.makespan;
        // The report that drives the round-0 trigger and the first
        // adjustment: the measured one when supplied, else the
        // simulator's observation of the single-shot placement.
        let round0_report = measured.unwrap_or(&base_sim.contention);
        let round0 = ReplacementRound {
            round: 0,
            makespan: baseline_makespan,
            oom: !base_sim.ok(),
            saturated_links: policy.saturated_links(round0_report),
            blocked_fraction: round0_report.blocked_fraction(),
            max_utilization: round0_report.max_utilization(),
            improved: false,
        };
        let mut rounds = vec![round0];
        // A placement that OOMs at runtime has no meaningful makespan to
        // iterate on; surface the single-shot verdict unchanged.
        if !base_sim.ok() {
            return Ok(IterativePlacement {
                response: base,
                baseline_makespan,
                rounds,
            });
        }
        // The cluster candidates are judged on (per-request override or
        // the engine's own).
        let real_cluster: Cow<'_, Cluster> = match &req.topology {
            Some(t) => Cow::Owned(self.cluster.clone().with_topology(t.clone())?),
            None => Cow::Borrowed(&self.cluster),
        };
        let mut adjusted = real_cluster.effective_topology().into_owned();
        let mut report = round0_report.clone();
        let mut best = base;
        let mut best_makespan = baseline_makespan;
        for round in 1..=policy.max_rounds {
            if !policy.should_replace(&report) {
                break;
            }
            // Per-link-kind damping: NVLink observations are charged in
            // full, NIC trunk waits most cautiously (the compounding
            // loop must not slosh traffic between machines each round).
            let adj = TopologyAdjustment::for_topology(&report, policy, &adjusted)?;
            if adj.is_noop() {
                break;
            }
            // Adjustments compound: a trunk that stays saturated keeps
            // getting more expensive until traffic routes around it.
            adjusted = adj.apply(&adjusted)?;
            let cand = {
                let mut r = req.clone();
                r.topology = Some(adjusted.clone());
                r.simulate = false;
                r.trace = trace.map(|t| t.0).or(req.trace);
                self.place(&r)?
            };
            let t0 = Instant::now();
            let sim = sim::simulate(
                &req.graph,
                &real_cluster,
                &cand.placement.device_of,
                self.sim,
            );
            self.record_interval(
                trace,
                None,
                Stage::Simulate.name(),
                &req.placer,
                t0,
                cand.placement.device_of.len(),
                cand.placement.device_of.len(),
            );
            // Best-of-rounds: any strictly better round is adopted; the
            // min_improvement margin only decides whether iterating
            // further is worth it.
            let better = sim.ok() && sim.makespan < best_makespan;
            let significant =
                sim.ok() && sim.makespan < best_makespan * (1.0 - policy.min_improvement);
            rounds.push(ReplacementRound {
                round,
                makespan: sim.makespan,
                oom: !sim.ok(),
                saturated_links: policy.saturated_links(&sim.contention),
                blocked_fraction: sim.contention.blocked_fraction(),
                max_utilization: sim.contention.max_utilization(),
                improved: better,
            });
            report = sim.contention.clone();
            if better {
                best_makespan = sim.makespan;
                best = Arc::new(PlacementResponse {
                    sim: Some(sim),
                    ..(*cand).clone()
                });
            }
            if !significant {
                break;
            }
        }
        Ok(IterativePlacement {
            response: best,
            baseline_makespan,
            rounds,
        })
    }

    /// Serve a batch, fanning requests across OS threads. Results are in
    /// request order; each entry fails independently. Concurrency is
    /// bounded by the machine's available parallelism so an arbitrarily
    /// large batch cannot exhaust threads or memory.
    pub fn place_batch(
        &self,
        reqs: &[PlacementRequest],
    ) -> Vec<crate::Result<Arc<PlacementResponse>>> {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .max(1);
        let mut results = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(workers) {
            std::thread::scope(|s| {
                let handles: Vec<_> = chunk
                    .iter()
                    .map(|req| s.spawn(move || self.place(req)))
                    .collect();
                results.extend(handles.into_iter().map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(BaechiError::runtime("placement worker panicked")))
                }));
            });
        }
        results
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    fn engine(n: usize, mem: u64) -> PlacementEngine {
        PlacementEngine::builder()
            .cluster(Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap()))
            .build()
            .unwrap()
    }

    #[test]
    fn builder_requires_cluster() {
        assert!(matches!(
            PlacementEngine::builder().build(),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn place_covers_graph_and_simulates() {
        let e = engine(2, 1 << 20);
        let g = crate::models::linreg::linreg_graph();
        let n_ops = g.len();
        let resp = e.place(&PlacementRequest::new(g, "m-etf")).unwrap();
        assert_eq!(resp.placement.device_of.len(), n_ops);
        assert!(resp.sim.as_ref().unwrap().ok());
        assert_eq!(
            e.cache_stats(),
            CacheStats {
                hits: 0,
                misses: 1,
                evictions: 0
            }
        );
    }

    #[test]
    fn cache_serves_identical_request() {
        let e = engine(2, 1 << 20);
        let g = crate::models::linreg::linreg_graph();
        let req = PlacementRequest::new(g, "m-sct");
        let a = e.place(&req).unwrap();
        let b = e.place(&req).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second response must be the cached Arc");
        assert_eq!(
            e.cache_stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                evictions: 0
            }
        );
        // A different placer misses.
        let c = e.place(&PlacementRequest::new(
            crate::models::linreg::linreg_graph(),
            "m-topo",
        ));
        assert!(c.is_ok());
        assert_eq!(e.cache_stats().misses, 2);
        assert_eq!(e.cache_len(), 2);
        e.clear_cache();
        assert_eq!(e.cache_len(), 0);
    }

    #[test]
    fn cache_distinguishes_benchmark_identity() {
        // Same graph + same placer, different benchmark identity: the
        // expert places per-benchmark, so these must not share a cache
        // entry.
        let e = engine(2, 1 << 20);
        let g = crate::models::linreg::linreg_graph();
        let mut r1 = PlacementRequest::new(g.clone(), "expert");
        r1.benchmark = Some(Benchmark::Mlp);
        let mut r2 = PlacementRequest::new(g, "expert");
        r2.benchmark = Some(Benchmark::Gnmt {
            batch: 8,
            seq_len: 4,
        });
        let a = e.place(&r1).unwrap();
        let b = e.place(&r2).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "benchmark must be part of the key");
        assert_eq!(
            e.cache_stats(),
            CacheStats {
                hits: 0,
                misses: 2,
                evictions: 0
            }
        );
    }

    #[test]
    fn per_request_opt_override_changes_key() {
        let e = engine(2, 1 << 20);
        let g = crate::models::linreg::linreg_graph();
        let a = e.place(&PlacementRequest::new(g.clone(), "m-etf")).unwrap();
        let b = e
            .place(&PlacementRequest::new(g, "m-etf").with_opt(OptConfig::none()))
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(e.cache_stats().misses, 2);
    }

    #[test]
    fn coarsening_override_changes_key() {
        let e = engine(2, 1 << 30);
        let g = crate::models::synthetic::synthetic_graph(300);
        let a = e
            .place(&PlacementRequest::new(g.clone(), "hier").without_simulation())
            .unwrap();
        let b = e
            .place(
                &PlacementRequest::new(g, "hier")
                    .without_simulation()
                    .with_coarsening(CoarsenConfig::off()),
            )
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "coarsen must be part of the key");
        assert_eq!(a.placement.algorithm, "hier");
        // Disabled coarsening delegates wholesale to plain m-SCT.
        assert_eq!(b.placement.algorithm, "m-sct");
        assert_eq!(e.cache_stats().misses, 2);
    }

    #[test]
    fn run_history_records_full_and_cache_hit() {
        let dir = std::env::temp_dir().join(format!("baechi-engine-rh-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("runs.jsonl");
        let e = PlacementEngine::builder()
            .cluster(Cluster::homogeneous(2, 1 << 20, CommModel::new(0.0, 1.0).unwrap()))
            .run_history(path.to_string_lossy().into_owned(), 1 << 20)
            .build()
            .unwrap();
        let g = crate::models::linreg::linreg_graph();
        let req = PlacementRequest::new(g, "m-sct");
        e.place(&req).unwrap();
        e.place(&req).unwrap();
        let recs = crate::explain::FlightRecorder::read_all(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].serve_mode, "full");
        assert_eq!(recs[1].serve_mode, "cache_hit");
        // The attribution totals telescoped from the sim schedule must
        // reconstruct the recorded makespan.
        let m = recs[0].makespan.unwrap();
        let a = recs[0].attribution.unwrap();
        let sum = a.compute + a.transfer + a.queue_wait + a.idle;
        assert!((sum - m).abs() <= 1e-9 * m.abs().max(1.0), "{sum} vs {m}");
        assert_eq!(e.recorder_stats().unwrap().records, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn without_simulation_skips_sim() {
        let e = engine(2, 1 << 20);
        let g = crate::models::linreg::linreg_graph();
        let resp = e
            .place(&PlacementRequest::new(g, "m-etf").without_simulation())
            .unwrap();
        assert!(resp.sim.is_none());
    }
}
