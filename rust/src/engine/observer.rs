//! Stage observers: per-stage timing hooks for instrumenting the engine
//! (metrics export, tracing, progress display).
//!
//! Since the telemetry layer landed, observers are a *compatibility
//! adapter*: the engine itself emits [`crate::telemetry::tracer`] spans,
//! and [`ObserverBridge`] replays each closed stage span as the
//! equivalent [`PlacementObserver::on_stage`] callback. Existing
//! observers see exactly the events they always did (one per pipeline
//! stage, in completion order, plus cache hits), whether or not span
//! collection is enabled.

use crate::telemetry::tracer::{SpanListener, SpanRecord};
use std::sync::{Arc, Mutex};

/// A pipeline stage the engine reports on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Graph optimizer (§3.1): coplacement + fusion + projection.
    Optimize,
    /// The placement algorithm itself.
    Place,
    /// Expansion of the meta-graph placement onto the original graph.
    Expand,
    /// Execution-simulator evaluation of the expanded placement.
    Simulate,
    /// Request served from the placement cache — no pipeline stage ran.
    /// `duration` is the lookup time; `ops_in`/`ops_out` are the cached
    /// plan's op count.
    CacheHit,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Optimize => "optimize",
            Stage::Place => "place",
            Stage::Expand => "expand",
            Stage::Simulate => "simulate",
            Stage::CacheHit => "cache_hit",
        }
    }
}

/// Measurements for one stage of one request.
#[derive(Debug, Clone)]
pub struct StageStats {
    /// The request's placer spec (e.g. `"m-sct"`, `"rl:50"`).
    pub placer: String,
    /// Wall-clock duration of the stage, seconds.
    pub duration: f64,
    /// Ops entering the stage.
    pub ops_in: usize,
    /// Ops leaving the stage (post-fusion count for `Optimize`).
    pub ops_out: usize,
}

/// Observer hook invoked by the engine after each stage completes.
/// Implementations must be `Send + Sync`: `place_batch` fans requests
/// across threads and every thread reports through the same observers.
pub trait PlacementObserver: Send + Sync {
    fn on_stage(&self, stage: Stage, stats: &StageStats);
}

/// Replays closed telemetry spans as legacy observer callbacks. Spans
/// that do not correspond to a pipeline stage (the request envelope,
/// service queue waits) are filtered out, so observers keep their
/// pre-telemetry event stream.
pub(crate) struct ObserverBridge {
    observers: Vec<Arc<dyn PlacementObserver>>,
}

impl ObserverBridge {
    pub(crate) fn new(observers: Vec<Arc<dyn PlacementObserver>>) -> ObserverBridge {
        ObserverBridge { observers }
    }
}

impl SpanListener for ObserverBridge {
    fn on_close(&self, record: &SpanRecord) {
        let stage = match record.name {
            "optimize" => Stage::Optimize,
            "place" => Stage::Place,
            "expand" => Stage::Expand,
            "simulate" => Stage::Simulate,
            "cache_hit" => Stage::CacheHit,
            _ => return,
        };
        let stats = StageStats {
            placer: record.detail.clone(),
            duration: record.end_s - record.start_s,
            ops_in: record.ops_in,
            ops_out: record.ops_out,
        };
        for obs in &self.observers {
            obs.on_stage(stage, &stats);
        }
    }
}

/// Observer that records every event — introspection and tests.
#[derive(Default)]
pub struct RecordingObserver {
    events: Mutex<Vec<(Stage, StageStats)>>,
}

impl RecordingObserver {
    pub fn new() -> Arc<RecordingObserver> {
        Arc::new(RecordingObserver::default())
    }

    /// Snapshot of the events recorded so far.
    pub fn events(&self) -> Vec<(Stage, StageStats)> {
        self.events.lock().unwrap().clone()
    }
}

impl PlacementObserver for RecordingObserver {
    fn on_stage(&self, stage: Stage, stats: &StageStats) {
        self.events.lock().unwrap().push((stage, stats.clone()));
    }
}

/// Observer that logs stage timings through [`crate::util::log`].
#[derive(Debug, Default, Clone, Copy)]
pub struct LogObserver;

impl PlacementObserver for LogObserver {
    fn on_stage(&self, stage: Stage, stats: &StageStats) {
        crate::util::log::log(
            crate::util::log::Level::Debug,
            format_args!(
                "engine[{}] {}: {:.3} ms ({} -> {} ops)",
                stats.placer,
                stage.name(),
                stats.duration * 1e3,
                stats.ops_in,
                stats.ops_out,
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_observer_collects() {
        let obs = RecordingObserver::new();
        obs.on_stage(
            Stage::Place,
            &StageStats {
                placer: "m-etf".into(),
                duration: 0.5,
                ops_in: 10,
                ops_out: 10,
            },
        );
        let ev = obs.events();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].0, Stage::Place);
        assert_eq!(ev[0].1.placer, "m-etf");
    }

    #[test]
    fn stage_names() {
        assert_eq!(Stage::Optimize.name(), "optimize");
        assert_eq!(Stage::Simulate.name(), "simulate");
        assert_eq!(Stage::CacheHit.name(), "cache_hit");
    }
}
