//! Sharded, size-bounded LRU cache for placement responses.
//!
//! Replaces the engine's original unbounded single-`Mutex<BTreeMap>`
//! fingerprint cache. Keys are spread across N shards by a caller-supplied
//! shard key (the top bits of the entry's fingerprint), so concurrent
//! serving threads contend on different locks. Each shard evicts
//! least-recently-used entries by *cost* (for placements: ops in the plan),
//! keeping the total retained cost under a configurable capacity.
//! Hit/miss/eviction counters are lock-free atomics so a metrics snapshot
//! never blocks the serving path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Cache counters. `hits + misses` equals the number of [`ShardedLru::get`]
/// probes ([`ShardedLru::peek`] counts hits only — the caller is expected to
/// follow a peek-miss with a full `get`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct Entry<V> {
    value: V,
    cost: u64,
    tick: u64,
}

struct Shard<K, V> {
    map: BTreeMap<K, Entry<V>>,
    /// tick → key, ordered oldest-first; the LRU victim is the first entry.
    recency: BTreeMap<u64, K>,
    tick: u64,
    used: u64,
}

impl<K: Ord + Clone, V> Shard<K, V> {
    fn new() -> Shard<K, V> {
        Shard { map: BTreeMap::new(), recency: BTreeMap::new(), tick: 0, used: 0 }
    }

    fn touch(&mut self, key: &K) {
        let e = self.map.get_mut(key).expect("touched key present");
        self.recency.remove(&e.tick);
        self.tick += 1;
        e.tick = self.tick;
        self.recency.insert(self.tick, key.clone());
    }
}

/// N-way sharded bounded LRU. `V` is cloned out on hits, so callers store
/// `Arc`s for anything non-trivial.
pub struct ShardedLru<K, V> {
    shards: Vec<Mutex<Shard<K, V>>>,
    per_shard_capacity: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Ord + Clone, V: Clone> ShardedLru<K, V> {
    /// `capacity` is the total cost budget, split evenly across `shards`
    /// (each rounded up, so small capacities still admit one entry per
    /// shard). Both are clamped to at least 1.
    pub fn new(shards: usize, capacity: u64) -> ShardedLru<K, V> {
        let shards = shards.max(1);
        let capacity = capacity.max(1);
        ShardedLru {
            per_shard_capacity: (capacity + shards as u64 - 1) / shards as u64,
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_index(&self, shard_key: u64) -> usize {
        // Fingerprint-prefix sharding: the top bits pick the shard so that
        // keys hashed by the same function spread evenly.
        ((shard_key >> 48) as usize) % self.shards.len()
    }

    /// Look up `key`, counting a hit or a miss and refreshing recency.
    pub fn get(&self, shard_key: u64, key: &K) -> Option<V> {
        let mut guard = self.shards[self.shard_index(shard_key)].lock().unwrap();
        let s = &mut *guard;
        if s.map.contains_key(key) {
            s.touch(key);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(s.map[key].value.clone())
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Look up `key` without counting a miss (hits still count and refresh
    /// recency). Serving paths probe with `peek` before deciding how to
    /// produce the response; the eventual `get` on the placement path
    /// records the miss exactly once.
    pub fn peek(&self, shard_key: u64, key: &K) -> Option<V> {
        let mut guard = self.shards[self.shard_index(shard_key)].lock().unwrap();
        let s = &mut *guard;
        if s.map.contains_key(key) {
            s.touch(key);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(s.map[key].value.clone())
        } else {
            None
        }
    }

    /// Insert `key` with the given cost (clamped to ≥ 1), then evict
    /// least-recently-used entries until the shard is back under budget.
    /// The newest entry always survives, even if its cost alone exceeds
    /// the per-shard capacity.
    pub fn insert(&self, shard_key: u64, key: K, value: V, cost: u64) {
        let cost = cost.max(1);
        let mut guard = self.shards[self.shard_index(shard_key)].lock().unwrap();
        let s = &mut *guard;
        if let Some(old) = s.map.get(&key) {
            s.used -= old.cost;
            let old_tick = old.tick;
            s.recency.remove(&old_tick);
        }
        s.tick += 1;
        let tick = s.tick;
        s.used += cost;
        s.map.insert(key.clone(), Entry { value, cost, tick });
        s.recency.insert(tick, key);
        let mut evicted = 0u64;
        while s.used > self.per_shard_capacity && s.map.len() > 1 {
            let (&oldest, _) = s.recency.iter().next().expect("recency tracks map");
            let victim = s.recency.remove(&oldest).expect("victim key");
            let entry = s.map.remove(&victim).expect("victim entry");
            s.used -= entry.cost;
            evicted += 1;
        }
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Number of resident entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total retained cost across all shards.
    pub fn used(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().used).sum()
    }

    /// Drop every entry; counters are preserved (they describe lifetime
    /// traffic, not residency).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut guard = shard.lock().unwrap();
            *guard = Shard::new();
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_counts_hits_and_misses() {
        let c: ShardedLru<u64, &str> = ShardedLru::new(1, 100);
        assert_eq!(c.get(0, &1), None);
        c.insert(0, 1, "a", 1);
        assert_eq!(c.get(0, &1), Some("a"));
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
    }

    #[test]
    fn peek_never_counts_a_miss() {
        let c: ShardedLru<u64, u32> = ShardedLru::new(2, 100);
        assert_eq!(c.peek(0, &7), None);
        c.insert(0, 7, 42, 1);
        assert_eq!(c.peek(0, &7), Some(42));
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 0, evictions: 0 });
    }

    #[test]
    fn evicts_least_recently_used_by_cost() {
        // Single shard, capacity 7: two cost-3 entries fit, a third evicts.
        let c: ShardedLru<u64, &str> = ShardedLru::new(1, 7);
        c.insert(0, 1, "a", 3);
        c.insert(0, 2, "b", 3);
        assert_eq!(c.len(), 2);
        c.get(0, &1); // refresh 1 → 2 is now the LRU victim
        c.insert(0, 3, "c", 3);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(0, &2), None, "LRU entry evicted");
        assert_eq!(c.get(0, &1), Some("a"));
        assert_eq!(c.get(0, &3), Some("c"));
        assert_eq!(c.stats().evictions, 1);
        assert!(c.used() <= 7);
    }

    #[test]
    fn oversized_entry_survives_alone() {
        let c: ShardedLru<u64, &str> = ShardedLru::new(1, 4);
        c.insert(0, 1, "a", 2);
        c.insert(0, 2, "big", 100);
        assert_eq!(c.get(0, &2), Some("big"), "newest entry always resident");
        assert_eq!(c.get(0, &1), None);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn reinsert_replaces_cost_without_eviction() {
        let c: ShardedLru<u64, &str> = ShardedLru::new(1, 10);
        c.insert(0, 1, "a", 4);
        c.insert(0, 1, "a2", 6);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used(), 6);
        assert_eq!(c.get(0, &1), Some("a2"));
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn shard_keys_spread_and_clear_keeps_counters() {
        let c: ShardedLru<u64, u32> = ShardedLru::new(4, 1000);
        for i in 0..32u64 {
            c.insert(i << 48, i, i as u32, 1);
        }
        assert_eq!(c.len(), 32);
        for i in 0..32u64 {
            assert_eq!(c.get(i << 48, &i), Some(i as u32));
        }
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().hits, 32, "clear preserves lifetime counters");
    }
}
