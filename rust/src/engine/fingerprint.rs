//! Structural fingerprints for the engine's placement cache.
//!
//! FNV-1a over every field that influences a placement: the graph
//! (nodes, costs, memory, groups, edges), the cluster spec, the
//! optimizer config, and the simulator config. Two requests with equal
//! fingerprints produce identical placements (all placers are
//! deterministic for a fixed input), so the cache can serve the memoized
//! response.

use crate::error::BaechiError;
use crate::graph::OpGraph;
use crate::optimizer::OptConfig;
use crate::profile::Cluster;
use crate::sim::{Framework, SimConfig};
use crate::topology::Topology;

/// Incremental FNV-1a 64-bit hasher.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_bytes(&[v as u8]);
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_bytes(s.as_bytes());
        // Separator so ("ab","c") and ("a","bc") differ.
        self.write_bytes(&[0xff]);
    }

    pub fn write_opt_str(&mut self, s: Option<&str>) {
        match s {
            Some(s) => {
                self.write_bool(true);
                self.write_str(s);
            }
            None => self.write_bool(false),
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv::new()
    }
}

/// Fingerprint of an operator graph's placement-relevant structure.
pub fn graph_fingerprint(g: &OpGraph) -> u64 {
    let mut h = Fnv::new();
    h.write_str(&g.name);
    h.write_usize(g.len());
    for n in g.iter_nodes() {
        h.write_usize(n.id.0);
        h.write_str(&n.name);
        h.write_str(&n.kind.name());
        h.write_f64(n.compute);
        for v in [
            n.mem.params,
            n.mem.output,
            n.mem.param_grad,
            n.mem.upstream_grad,
            n.mem.temp,
            n.output_bytes,
        ] {
            h.write_u64(v);
        }
        h.write_opt_str(n.colocation_group.as_deref());
        h.write_opt_str(n.coplacement_group.as_deref());
        h.write_bool(n.is_backward);
        h.write_usize(n.forward_of.map(|f| f.0 + 1).unwrap_or(0));
    }
    for e in g.edges() {
        h.write_usize(e.src.0);
        h.write_usize(e.dst.0);
        h.write_u64(e.bytes);
    }
    h.finish()
}

/// Merkle-style per-op *cone* fingerprints: each op's hash covers its own
/// placement-relevant attributes plus the cone hashes of its predecessors
/// (with edge payloads), so a node's fingerprint changes iff something in
/// its ancestor cone changed. Incremental placement diffs two graph
/// versions by these hashes to find the dirty cone that needs re-placing.
///
/// Hashes are **name-based**, not id-based: node ids can shift between
/// versions of a graph (nodes added/removed), but an op whose name,
/// attributes, and upstream cone are unchanged keeps its fingerprint.
/// Returns one hash per id slot (`0` for dead slots); fails with
/// [`BaechiError::Cyclic`] on cyclic graphs.
pub fn cone_fingerprints(g: &OpGraph) -> crate::Result<Vec<u64>> {
    let order = g.topo_order().ok_or(BaechiError::Cyclic)?;
    let mut cones = vec![0u64; g.capacity()];
    for &id in &order {
        let n = g.node(id);
        let mut h = Fnv::new();
        h.write_str(&n.name);
        h.write_str(&n.kind.name());
        h.write_f64(n.compute);
        for v in [
            n.mem.params,
            n.mem.output,
            n.mem.param_grad,
            n.mem.upstream_grad,
            n.mem.temp,
            n.output_bytes,
        ] {
            h.write_u64(v);
        }
        h.write_opt_str(n.colocation_group.as_deref());
        h.write_opt_str(n.coplacement_group.as_deref());
        h.write_bool(n.is_backward);
        let forward_name = n
            .forward_of
            .filter(|&f| g.is_alive(f))
            .map(|f| g.node(f).name.clone());
        h.write_opt_str(forward_name.as_deref());
        // Predecessor cones, sorted so the hash is order-independent.
        let mut preds: Vec<(u64, u64)> = g
            .predecessors(id)
            .iter()
            .map(|&(p, bytes)| (cones[p.0], bytes))
            .collect();
        preds.sort_unstable();
        h.write_usize(preds.len());
        for (cone, bytes) in preds {
            h.write_u64(cone);
            h.write_u64(bytes);
        }
        cones[id.0] = h.finish();
    }
    Ok(cones)
}

/// Fingerprint of the cluster spec (devices + comm model + topology).
pub fn cluster_fingerprint(c: &Cluster) -> u64 {
    let mut h = Fnv::new();
    h.write_usize(c.n());
    for d in &c.devices {
        h.write_u64(d.memory);
        h.write_f64(d.speed);
    }
    h.write_f64(c.comm.latency);
    h.write_f64(c.comm.bandwidth);
    h.write_bool(c.sequential_comm);
    write_topology(&mut h, &c.effective_topology());
    h.finish()
}

/// Fingerprint of a topology alone (links, islands, speeds determine the
/// pair matrix and contention paths, so hashing them covers everything
/// placement-relevant).
pub fn topology_fingerprint(t: &Topology) -> u64 {
    let mut h = Fnv::new();
    write_topology(&mut h, t);
    h.finish()
}

fn write_topology(h: &mut Fnv, t: &Topology) {
    h.write_usize(t.n());
    h.write_bool(t.is_uniform());
    if let Some(m) = t.uniform_model() {
        h.write_f64(m.latency);
        h.write_f64(m.bandwidth);
    }
    for d in 0..t.n() {
        h.write_f64(t.speed(d));
        h.write_usize(t.island_of(d));
    }
    h.write_usize(t.links().len());
    for l in t.links() {
        h.write_usize(l.a);
        h.write_usize(l.b);
        h.write_str(l.kind.name());
        h.write_f64(l.comm.latency);
        h.write_f64(l.comm.bandwidth);
    }
}

/// Fingerprint of the effective optimizer configuration.
pub fn opt_fingerprint(o: &OptConfig) -> u64 {
    let mut h = Fnv::new();
    h.write_bool(o.coplacement);
    h.write_bool(o.fusion);
    h.write_bool(o.forward_only);
    h.write_u64(o.latency_equiv_bytes);
    h.finish()
}

/// Fingerprint of a hierarchical-coarsening override. The cache key
/// reserves `0` for "no override", so this is only called for `Some`
/// configs (an FNV collision with 0 is as unlikely as any other).
pub fn coarsen_fingerprint(c: &crate::hierarchy::CoarsenConfig) -> u64 {
    let mut h = Fnv::new();
    h.write_bool(c.enabled);
    h.write_usize(c.max_members);
    h.write_usize(c.rounds);
    h.write_bool(c.fuse_chains);
    h.write_bool(c.fuse_groups);
    h.finish()
}

/// Fingerprint of the simulator configuration.
pub fn sim_fingerprint(s: &SimConfig) -> u64 {
    let mut h = Fnv::new();
    h.write_bool(matches!(s.framework, Framework::PyTorch));
    h.write_bool(s.overlap_comm);
    h.write_usize(s.queue_limit);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::profile::CommModel;

    #[test]
    fn graph_fingerprint_sensitive_to_structure() {
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        g.add_edge(a, b, 10);
        let f1 = graph_fingerprint(&g);
        assert_eq!(f1, graph_fingerprint(&g.clone()), "deterministic");
        g.node_mut(a).compute = 1.5;
        let f2 = graph_fingerprint(&g);
        assert_ne!(f1, f2, "compute change must alter the fingerprint");
        g.add_edge(a, b, 20);
        assert_ne!(f2, graph_fingerprint(&g), "edge bytes alter it too");
    }

    #[test]
    fn cluster_fingerprint_sensitive_to_memory() {
        let c1 = Cluster::homogeneous(4, 1000, CommModel::new(0.0, 1.0).unwrap());
        let c2 = Cluster::homogeneous(4, 2000, CommModel::new(0.0, 1.0).unwrap());
        assert_ne!(cluster_fingerprint(&c1), cluster_fingerprint(&c2));
        assert_eq!(cluster_fingerprint(&c1), cluster_fingerprint(&c1.clone()));
    }

    #[test]
    fn cluster_fingerprint_sensitive_to_topology() {
        let comm = CommModel::pcie_via_host();
        let uniform = Cluster::homogeneous(4, 1000, comm);
        let islands = Cluster::homogeneous(4, 1000, comm)
            .with_topology(
                Topology::nvlink_islands(4, 2, CommModel::nvlink_like(), comm).unwrap(),
            )
            .unwrap();
        assert_ne!(cluster_fingerprint(&uniform), cluster_fingerprint(&islands));
        // Same topology → same fingerprint.
        let islands2 = Cluster::homogeneous(4, 1000, comm)
            .with_topology(
                Topology::nvlink_islands(4, 2, CommModel::nvlink_like(), comm).unwrap(),
            )
            .unwrap();
        assert_eq!(cluster_fingerprint(&islands), cluster_fingerprint(&islands2));
        // Bandwidth of one link matters.
        let slower = Cluster::homogeneous(4, 1000, comm)
            .with_topology(
                Topology::nvlink_islands(4, 2, CommModel::new(5e-6, 25e9).unwrap(), comm)
                    .unwrap(),
            )
            .unwrap();
        assert_ne!(cluster_fingerprint(&islands), cluster_fingerprint(&slower));
        assert_ne!(
            topology_fingerprint(islands.topology()),
            topology_fingerprint(uniform.topology())
        );
    }

    #[test]
    fn cone_fingerprints_localize_mutations_to_descendants() {
        // a → b → c, plus an unrelated d.
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        g.add_edge(a, b, 10);
        g.add_edge(b, c, 10);
        let base = cone_fingerprints(&g).unwrap();
        assert_eq!(base, cone_fingerprints(&g.clone()).unwrap(), "deterministic");

        let mut tail = g.clone();
        tail.node_mut(c).compute += 1.0;
        let cones = cone_fingerprints(&tail).unwrap();
        assert_eq!(cones[a.0], base[a.0]);
        assert_eq!(cones[b.0], base[b.0]);
        assert_ne!(cones[c.0], base[c.0], "mutated node is dirty");
        assert_eq!(cones[d.0], base[d.0], "unrelated node untouched");

        let mut head = g.clone();
        head.node_mut(a).compute += 1.0;
        let cones = cone_fingerprints(&head).unwrap();
        assert_ne!(cones[a.0], base[a.0]);
        assert_ne!(cones[b.0], base[b.0], "descendants inherit the dirt");
        assert_ne!(cones[c.0], base[c.0]);
        assert_eq!(cones[d.0], base[d.0]);
    }

    #[test]
    fn cone_fingerprints_are_name_based_not_id_based() {
        // Same logical graph built in a different insertion order: the ops
        // keep their cones even though their ids differ.
        let mut g1 = OpGraph::new("t");
        let x1 = g1.add_node("x", OpKind::MatMul);
        let y1 = g1.add_node("y", OpKind::MatMul);
        g1.add_edge(x1, y1, 7);

        let mut g2 = OpGraph::new("t");
        let pad = g2.add_node("pad", OpKind::MatMul);
        let x2 = g2.add_node("x", OpKind::MatMul);
        let y2 = g2.add_node("y", OpKind::MatMul);
        g2.add_edge(x2, y2, 7);
        g2.remove_node(pad);

        let c1 = cone_fingerprints(&g1).unwrap();
        let c2 = cone_fingerprints(&g2).unwrap();
        assert_ne!(x1.0, x2.0, "ids shifted by construction");
        assert_eq!(c1[x1.0], c2[x2.0]);
        assert_eq!(c1[y1.0], c2[y2.0]);
    }

    #[test]
    fn opt_fingerprint_distinguishes_configs() {
        assert_ne!(
            opt_fingerprint(&OptConfig::default()),
            opt_fingerprint(&OptConfig::none())
        );
    }

    #[test]
    fn coarsen_fingerprint_distinguishes_configs() {
        use crate::hierarchy::CoarsenConfig;
        let base = CoarsenConfig::default();
        assert_eq!(coarsen_fingerprint(&base), coarsen_fingerprint(&base));
        assert_ne!(
            coarsen_fingerprint(&base),
            coarsen_fingerprint(&CoarsenConfig::off())
        );
        assert_ne!(
            coarsen_fingerprint(&base),
            coarsen_fingerprint(&CoarsenConfig::with_max_members(8))
        );
    }
}
