//! The placer registry: name → factory of `Box<dyn Placer>`.
//!
//! Replaces the hard-coded `PlacerKind` match arms so baselines, the m-*
//! algorithms, and external strategies (an RL planner à la Placeto, an
//! optimal-partitioning solver à la Tarnawski et al.) register through
//! one mechanism. A spec string `"name"` or `"name:arg"` resolves to a
//! fresh placer instance; the colon suffix is handed to the factory
//! (e.g. `"rl:500"` → 500 episodes).

use crate::baselines::{expert::Expert, rl::RlConfig, rl::RlPlacer, single::SingleDevice};
use crate::error::BaechiError;
use crate::hierarchy::{CoarsenConfig, HierPlacer};
use crate::models::Benchmark;
use crate::placer::{metf::MEtf, msct::MSct, mtopo::MTopo, Placer};
use std::collections::BTreeMap;

/// Context handed to placer factories at resolution time.
#[derive(Debug, Clone, Copy)]
pub struct PlacerContext<'a> {
    /// The part of the spec after `:`, if any (`"rl:500"` → `Some("500")`).
    pub arg: Option<&'a str>,
    /// Benchmark identity, for placers keyed to a model (the expert).
    pub benchmark: Option<Benchmark>,
    /// Request-level coarsening override for the `hier` placer
    /// (`PlacementRequest::with_coarsening`); the spec arg still wins.
    pub coarsen: Option<CoarsenConfig>,
}

/// Factory producing a fresh placer per request. `Send + Sync` because
/// `place_batch` resolves placers from worker threads.
pub type PlacerFactory =
    Box<dyn Fn(&PlacerContext<'_>) -> crate::Result<Box<dyn Placer>> + Send + Sync>;

/// A registry entry: the factory plus pipeline policy.
pub struct PlacerRegistration {
    factory: PlacerFactory,
    /// Run the §3.1 graph optimizer before placement. The m-* algorithms
    /// and the RL baseline want the reduced meta-graph; the single/expert
    /// baselines place the raw graph (the paper's baseline protocol).
    pub optimize_graph: bool,
}

impl PlacerRegistration {
    /// Registration that places the optimizer-reduced graph (the default
    /// for real placement algorithms).
    pub fn new(
        factory: impl Fn(&PlacerContext<'_>) -> crate::Result<Box<dyn Placer>>
            + Send
            + Sync
            + 'static,
    ) -> PlacerRegistration {
        PlacerRegistration {
            factory: Box::new(factory),
            optimize_graph: true,
        }
    }

    /// Registration that places the raw, un-optimized graph (baselines).
    pub fn raw(
        factory: impl Fn(&PlacerContext<'_>) -> crate::Result<Box<dyn Placer>>
            + Send
            + Sync
            + 'static,
    ) -> PlacerRegistration {
        PlacerRegistration {
            optimize_graph: false,
            ..PlacerRegistration::new(factory)
        }
    }
}

/// A resolved spec: the placer instance plus its pipeline policy.
pub struct ResolvedPlacer {
    pub placer: Box<dyn Placer>,
    pub optimize_graph: bool,
}

/// Name → registration map with alias support.
pub struct PlacerRegistry {
    entries: BTreeMap<String, PlacerRegistration>,
    aliases: BTreeMap<String, String>,
}

impl PlacerRegistry {
    /// An empty registry (no built-ins).
    pub fn empty() -> PlacerRegistry {
        PlacerRegistry {
            entries: BTreeMap::new(),
            aliases: BTreeMap::new(),
        }
    }

    /// Registry pre-populated with every built-in placer:
    /// `single`, `expert`, `m-topo`, `m-etf`, `m-sct`, `m-sct-heur`,
    /// `m-sct-lp`, `hier[:off|:<max_members>]`, and `rl[:episodes]`
    /// (plus dash-less aliases).
    pub fn with_builtins() -> PlacerRegistry {
        let mut r = PlacerRegistry::empty();
        r.register(
            "single",
            PlacerRegistration::raw(|_| Ok(Box::new(SingleDevice))),
        );
        r.register(
            "expert",
            PlacerRegistration::raw(|ctx| match ctx.benchmark {
                Some(b) => Ok(Box::new(Expert::new(b))),
                None => Err(BaechiError::invalid(
                    "placer 'expert' needs the request's benchmark identity",
                )),
            }),
        );
        r.register("m-topo", PlacerRegistration::new(|_| Ok(Box::new(MTopo))));
        r.register("m-etf", PlacerRegistration::new(|_| Ok(Box::new(MEtf))));
        r.register(
            "m-sct",
            PlacerRegistration::new(|_| Ok(Box::new(MSct::default()))),
        );
        r.register(
            "m-sct-heur",
            PlacerRegistration::new(|_| Ok(Box::new(MSct::with_heuristic()))),
        );
        r.register(
            "m-sct-lp",
            PlacerRegistration::new(|_| Ok(Box::new(MSct::with_lp()))),
        );
        r.register(
            "hier",
            PlacerRegistration::new(|ctx| {
                let mut cfg = ctx.coarsen.unwrap_or_default();
                match ctx.arg {
                    None => {}
                    Some("off") => cfg.enabled = false,
                    Some(a) => {
                        let n: usize = a.parse().map_err(|_| {
                            BaechiError::invalid(format!(
                                "hier arg must be 'off' or a max super-op size, got '{a}'"
                            ))
                        })?;
                        cfg.enabled = true;
                        cfg.max_members = n.max(2);
                    }
                }
                Ok(Box::new(HierPlacer::new(cfg)))
            }),
        );
        r.register(
            "rl",
            PlacerRegistration::new(|ctx| {
                let episodes = match ctx.arg {
                    None => 200,
                    Some(a) => a.parse().map_err(|_| {
                        BaechiError::invalid(format!("rl episodes must be an integer, got '{a}'"))
                    })?,
                };
                Ok(Box::new(RlPlacer::new(RlConfig {
                    episodes,
                    ..Default::default()
                })))
            }),
        );
        r.alias("mtopo", "m-topo");
        r.alias("metf", "m-etf");
        r.alias("msct", "m-sct");
        r
    }

    /// Register (or replace) a placer under `name`.
    pub fn register(&mut self, name: &str, registration: PlacerRegistration) {
        self.entries.insert(name.to_string(), registration);
    }

    /// Register `alias` as another spelling of `target`.
    pub fn alias(&mut self, alias: &str, target: &str) {
        self.aliases.insert(alias.to_string(), target.to_string());
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name) || self.aliases.contains_key(name)
    }

    /// Registered placer names, sorted (aliases excluded).
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Resolve a spec (`"m-sct"`, `"rl:500"`) to a fresh placer.
    pub fn resolve(
        &self,
        spec: &str,
        benchmark: Option<Benchmark>,
    ) -> crate::Result<ResolvedPlacer> {
        self.resolve_with(spec, benchmark, None)
    }

    /// [`Self::resolve`] with a request-level coarsening override for the
    /// `hier` placer (the engine threads `PlacementRequest::coarsen`
    /// through here; a spec arg like `hier:128` still wins).
    pub fn resolve_with(
        &self,
        spec: &str,
        benchmark: Option<Benchmark>,
        coarsen: Option<CoarsenConfig>,
    ) -> crate::Result<ResolvedPlacer> {
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (spec, None),
        };
        let name = self.aliases.get(name).map(String::as_str).unwrap_or(name);
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| BaechiError::UnknownPlacer {
                name: spec.to_string(),
                known: self.names(),
            })?;
        let ctx = PlacerContext {
            arg,
            benchmark,
            coarsen,
        };
        Ok(ResolvedPlacer {
            placer: (entry.factory)(&ctx)?,
            optimize_graph: entry.optimize_graph,
        })
    }
}

impl Default for PlacerRegistry {
    fn default() -> PlacerRegistry {
        PlacerRegistry::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve() {
        let r = PlacerRegistry::with_builtins();
        for name in ["single", "m-topo", "m-etf", "m-sct", "m-sct-heur", "hier", "rl"] {
            let resolved = r.resolve(name, None).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!resolved.placer.name().is_empty());
        }
        // Baselines skip the optimizer, algorithms use it.
        assert!(!r.resolve("single", None).unwrap().optimize_graph);
        assert!(r.resolve("m-sct", None).unwrap().optimize_graph);
    }

    #[test]
    fn aliases_and_args() {
        let r = PlacerRegistry::with_builtins();
        assert!(r.contains("metf"));
        assert_eq!(r.resolve("metf", None).unwrap().placer.name(), "m-etf");
        // rl takes an episode-count argument.
        assert!(r.resolve("rl:50", None).is_ok());
        assert!(matches!(
            r.resolve("rl:xx", None),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn hier_args_and_context_override() {
        let r = PlacerRegistry::with_builtins();
        assert_eq!(r.resolve("hier", None).unwrap().placer.name(), "hier");
        assert_eq!(
            r.resolve("hier:off", None).unwrap().placer.name(),
            "hier(off)"
        );
        assert!(r.resolve("hier:128", None).is_ok());
        assert!(matches!(
            r.resolve("hier:huge", None),
            Err(BaechiError::InvalidRequest(_))
        ));
        // A request-level CoarsenConfig reaches the factory…
        let off = r
            .resolve_with("hier", None, Some(CoarsenConfig::off()))
            .unwrap();
        assert_eq!(off.placer.name(), "hier(off)");
        // …but an explicit spec arg still wins over it.
        let on = r
            .resolve_with("hier:16", None, Some(CoarsenConfig::off()))
            .unwrap();
        assert_eq!(on.placer.name(), "hier");
    }

    #[test]
    fn unknown_placer_is_typed() {
        let r = PlacerRegistry::with_builtins();
        match r.resolve("nope", None) {
            Err(BaechiError::UnknownPlacer { name, known }) => {
                assert_eq!(name, "nope");
                assert!(known.contains(&"m-sct".to_string()));
            }
            Err(e) => panic!("expected UnknownPlacer, got {e}"),
            Ok(_) => panic!("'nope' resolved unexpectedly"),
        }
    }

    #[test]
    fn expert_requires_benchmark() {
        let r = PlacerRegistry::with_builtins();
        assert!(matches!(
            r.resolve("expert", None),
            Err(BaechiError::InvalidRequest(_))
        ));
        assert!(r
            .resolve("expert", Some(Benchmark::Mlp))
            .is_ok());
    }

    #[test]
    fn custom_registration_round_trips() {
        let mut r = PlacerRegistry::empty();
        r.register("mine", PlacerRegistration::new(|_| Ok(Box::new(MTopo))));
        assert_eq!(r.names(), vec!["mine".to_string()]);
        assert_eq!(r.resolve("mine", None).unwrap().placer.name(), "m-topo");
    }
}
