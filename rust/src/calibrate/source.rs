//! Where calibration measurements come from.
//!
//! A [`MeasurementSource`] answers two probe questions: "how long does a
//! `bytes`-sized transfer from device `src` to device `dst` take?" and
//! "how long does an operator with reference cost `ref_secs` take on
//! device `d`?". Two implementations:
//!
//! * [`SyntheticSource`] replays a ground-truth [`Topology`] plus seeded
//!   multiplicative log-normal noise — the deterministic source the
//!   property tests, benches, and CI run against (no GPUs required);
//! * [`RuntimeSource`] times the real host: pairwise transfers are
//!   host-memory copies ([`crate::profile::pjrt::time_host_copy`] — the
//!   paper's no-P2P testbed moves every tensor through host memory,
//!   §5.1), and op probes run a dependent-FMA chain against a fixed
//!   reference rate. When AOT artifacts are available, feed
//!   [`crate::profile::pjrt::profile_exec`] timings into
//!   [`Measurements`](super::Measurements) directly — the fitter only
//!   sees `(reference, measured)` pairs.

use crate::error::BaechiError;
use crate::profile::pjrt;
use crate::topology::Topology;
use crate::util::rng::Pcg;

/// A device cluster that can be probed for calibration measurements.
pub trait MeasurementSource {
    /// Human-readable identity for reports (`"synthetic(noise=0.02)"`).
    fn name(&self) -> String;

    /// Number of devices this source can probe.
    fn devices(&self) -> usize;

    /// Measured wall time of one `bytes`-sized transfer `src → dst`,
    /// seconds. `src == dst` is free.
    fn measure_transfer(&mut self, src: usize, dst: usize, bytes: u64) -> crate::Result<f64>;

    /// Measured wall time on `device` of an operator whose reference
    /// cost (on the profiling device, speed 1.0) is `ref_secs`.
    fn measure_op(&mut self, device: usize, ref_secs: f64) -> crate::Result<f64>;
}

fn check_pair(n: usize, src: usize, dst: usize) -> crate::Result<()> {
    if src >= n || dst >= n {
        return Err(BaechiError::invalid(format!(
            "calibration probe: pair {src}→{dst} out of range for {n} devices"
        )));
    }
    Ok(())
}

/// Deterministic measurement source: replays a ground-truth topology
/// with seeded multiplicative log-normal noise (`sigma` in log space;
/// 0.0 = exact replay). Lets every calibration test and bench run
/// without hardware while still exercising the full fit pipeline.
#[derive(Debug, Clone)]
pub struct SyntheticSource {
    topo: Topology,
    noise: f64,
    rng: Pcg,
}

impl SyntheticSource {
    pub fn new(topo: Topology, noise: f64, seed: u64) -> crate::Result<SyntheticSource> {
        if !noise.is_finite() || noise < 0.0 {
            return Err(BaechiError::invalid(format!(
                "synthetic source: noise must be non-negative and finite, got {noise}"
            )));
        }
        Ok(SyntheticSource {
            topo,
            noise,
            rng: Pcg::seed(seed),
        })
    }

    /// The ground truth this source replays.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    fn factor(&mut self) -> f64 {
        if self.noise == 0.0 {
            1.0
        } else {
            self.rng.log_normal(0.0, self.noise)
        }
    }
}

impl MeasurementSource for SyntheticSource {
    fn name(&self) -> String {
        format!("synthetic(noise={})", self.noise)
    }

    fn devices(&self) -> usize {
        self.topo.n()
    }

    fn measure_transfer(&mut self, src: usize, dst: usize, bytes: u64) -> crate::Result<f64> {
        check_pair(self.topo.n(), src, dst)?;
        let f = self.factor(); // draw even for src == dst: keeps the
                               // rng stream independent of the plan
        Ok(self.topo.time(src, dst, bytes) * f)
    }

    fn measure_op(&mut self, device: usize, ref_secs: f64) -> crate::Result<f64> {
        check_pair(self.topo.n(), device, device)?;
        let f = self.factor();
        Ok(ref_secs / self.topo.speed(device) * f)
    }
}

/// Runtime-backed measurement source: times the actual host this
/// process runs on. Transfers are host-memory copies (all "devices"
/// share the host interconnect, exactly the paper's PCIe-through-host
/// substitution). Op probes run a dependent-FMA chain whose length is
/// fixed by the probe's reference cost against
/// [`RuntimeSource::REF_CHAIN_RATE`] — a *constant* anchor, so the
/// fitted speed is a genuine measurement of the host's serial FMA rate
/// relative to that reference (sizing the workload by a self-measured
/// host rate would make every speed ≈ 1.0 by construction).
#[derive(Debug)]
pub struct RuntimeSource {
    devices: usize,
    /// Repetitions per transfer probe (median taken).
    reps: usize,
}

impl RuntimeSource {
    /// The op-probe anchor: a 1 GHz dependent-FMA chain defines speed
    /// 1.0. A probe with reference cost `t` runs `t × 1e9` chained
    /// FMAs; a host retiring them at `r` iterations/sec measures
    /// `t × 1e9 / r` seconds, so its fitted speed is `r / 1e9`.
    pub const REF_CHAIN_RATE: f64 = 1e9;

    pub fn new(devices: usize) -> crate::Result<RuntimeSource> {
        if devices == 0 {
            return Err(BaechiError::invalid("runtime source: need ≥ 1 device"));
        }
        Ok(RuntimeSource { devices, reps: 5 })
    }

    /// Override the per-probe repetition count.
    pub fn with_reps(mut self, reps: usize) -> RuntimeSource {
        self.reps = reps.max(1);
        self
    }

    /// Run `iters` dependent FMAs; returns elapsed seconds.
    fn fma_block(iters: u64) -> f64 {
        let t0 = std::time::Instant::now();
        let mut x = 1.000000001f64;
        for _ in 0..iters {
            x = x.mul_add(1.000000001, 1e-12);
        }
        std::hint::black_box(x);
        t0.elapsed().as_secs_f64()
    }
}

impl MeasurementSource for RuntimeSource {
    fn name(&self) -> String {
        format!("runtime({} devices)", self.devices)
    }

    fn devices(&self) -> usize {
        self.devices
    }

    fn measure_transfer(&mut self, src: usize, dst: usize, bytes: u64) -> crate::Result<f64> {
        check_pair(self.devices, src, dst)?;
        if src == dst {
            return Ok(0.0);
        }
        Ok(pjrt::time_host_copy(bytes as usize, self.reps))
    }

    fn measure_op(&mut self, device: usize, ref_secs: f64) -> crate::Result<f64> {
        check_pair(self.devices, device, device)?;
        if !ref_secs.is_finite() || ref_secs <= 0.0 {
            return Err(BaechiError::invalid(format!(
                "runtime source: op reference cost must be positive, got {ref_secs}"
            )));
        }
        let iters = ((ref_secs * Self::REF_CHAIN_RATE) as u64).max(1);
        Ok(Self::fma_block(iters))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    #[test]
    fn synthetic_zero_noise_replays_exactly() {
        let topo = Topology::uniform(3, CommModel::new(1e-5, 1e9).unwrap());
        let mut s = SyntheticSource::new(topo.clone(), 0.0, 1).unwrap();
        for bytes in [1u64 << 10, 1 << 20] {
            let t = s.measure_transfer(0, 2, bytes).unwrap();
            assert_eq!(t.to_bits(), topo.time(0, 2, bytes).to_bits());
        }
        assert_eq!(s.measure_transfer(1, 1, 1 << 20).unwrap(), 0.0);
        // Speed 1.0 everywhere: op probes echo the reference cost.
        assert_eq!(s.measure_op(1, 0.25).unwrap(), 0.25);
    }

    #[test]
    fn synthetic_noise_is_seeded_and_multiplicative() {
        let topo = Topology::uniform(2, CommModel::new(0.0, 1e9).unwrap());
        let mut a = SyntheticSource::new(topo.clone(), 0.1, 7).unwrap();
        let mut b = SyntheticSource::new(topo, 0.1, 7).unwrap();
        let (ta, tb) = (
            a.measure_transfer(0, 1, 1 << 20).unwrap(),
            b.measure_transfer(0, 1, 1 << 20).unwrap(),
        );
        assert_eq!(ta.to_bits(), tb.to_bits(), "same seed, same draw");
        assert!(ta > 0.0);
        assert!(matches!(
            SyntheticSource::new(
                Topology::uniform(2, CommModel::new(0.0, 1e9).unwrap()),
                -0.1,
                0
            ),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn synthetic_rejects_out_of_range_probes() {
        let topo = Topology::uniform(2, CommModel::new(0.0, 1e9).unwrap());
        let mut s = SyntheticSource::new(topo, 0.0, 1).unwrap();
        assert!(matches!(
            s.measure_transfer(0, 5, 1024),
            Err(BaechiError::InvalidRequest(_))
        ));
        assert!(matches!(
            s.measure_op(9, 1.0),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn runtime_source_probes_are_positive_and_scale() {
        let mut s = RuntimeSource::new(2).unwrap().with_reps(3);
        let t = s.measure_transfer(0, 1, 1 << 20).unwrap();
        assert!(t > 0.0);
        assert_eq!(s.measure_transfer(1, 1, 1 << 20).unwrap(), 0.0);
        let small = s.measure_op(0, 1e-5).unwrap();
        let large = s.measure_op(0, 1e-2).unwrap();
        assert!(small > 0.0);
        assert!(large > small, "1e-2 s probe ({large}) ≤ 1e-5 s probe ({small})");
        assert!(matches!(
            s.measure_op(0, f64::NAN),
            Err(BaechiError::InvalidRequest(_))
        ));
    }
}
