//! Calibration subsystem: learn the cluster model from measurements.
//!
//! The paper's pipeline *starts* with a Profiler (§4.1) that measures
//! per-op compute times and fits the linear communication model
//! `t = a + b·bytes` — everything downstream consumes measured numbers.
//! This module closes the same gap for the reproduction: instead of
//! hand-specifying topologies via JSON and op costs via the analytic
//! model, it turns raw probe measurements into the cluster model the
//! rest of the stack consumes.
//!
//! * [`MeasurementSource`] — where probes run: [`RuntimeSource`] times
//!   real host transfers and op kernels
//!   ([`crate::profile::pjrt`]/[`crate::exec`] substrate), while
//!   [`SyntheticSource`] replays a ground-truth [`Topology`] with seeded
//!   noise so tests and CI calibrate without GPUs.
//! * [`collect`] — drives a source through a [`CalibrationPlan`]
//!   (payload sweep × repeats per pair, op probes per device) into raw
//!   [`Measurements`].
//! * [`fit::fit_cluster`] — robust least squares per *link*: per-pair
//!   medians → [`crate::profile::CommModel::fit`], island inference by
//!   bandwidth clustering, cross-island spoke costs solved via the
//!   [`crate::lp::matrix`] normal equations so path-composed costs
//!   reproduce the measured all-pairs matrix, and per-device speed
//!   factors from op timings.
//! * [`CalibratedCluster`] — the resulting artifact: a [`Topology`]
//!   plus a [`CalibrationReport`] (per-pair residuals, condition
//!   warnings), with JSON save/load.
//! * [`measured_report`] — converts runtime per-link observations into
//!   the [`ContentionReport`](crate::sim::ContentionReport) shape, so
//!   [`PlacementEngine::place_iterative_measured`](crate::engine::PlacementEngine::place_iterative_measured)
//!   can drive re-placement from *measured* feedback instead of the
//!   simulator's.
//!
//! CLI: `baechi calibrate --source synthetic[:noise] …` prints the
//! quality report and saves the artifact; `--calibrate <source>` on
//! `place`/`compare` swaps the hand-specified topology for a measured
//! one.

pub mod fit;
pub mod source;

pub use fit::{fit_cluster, pair_matrix_error};
pub use source::{MeasurementSource, RuntimeSource, SyntheticSource};

use crate::error::BaechiError;
use crate::profile::Cluster;
use crate::sim::{ContentionReport, LinkUse, QUEUE_DEPTH_BUCKETS};
use crate::topology::{json as topo_json, Topology};
use crate::util::json::Json;

/// What to probe: the transfer payload sweep and the op-probe workload.
#[derive(Debug, Clone)]
pub struct CalibrationPlan {
    /// Transfer payload sizes, bytes (≥ 2 distinct sizes required to
    /// identify latency and bandwidth).
    pub payload_sizes: Vec<u64>,
    /// Repetitions per (pair, size); the fitter takes per-size medians.
    pub repeats: usize,
    /// Reference op costs (seconds on the speed-1.0 profiling device)
    /// probed on every device; see
    /// [`crate::models::calibration_probe_costs`].
    pub op_probes: Vec<f64>,
    /// Repetitions per (device, probe).
    pub op_repeats: usize,
}

impl Default for CalibrationPlan {
    fn default() -> CalibrationPlan {
        CalibrationPlan {
            payload_sizes: vec![64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20],
            repeats: 3,
            op_probes: crate::models::calibration_probe_costs(),
            op_repeats: 3,
        }
    }
}

impl CalibrationPlan {
    fn validate(&self) -> crate::Result<()> {
        let mut sizes = self.payload_sizes.clone();
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.len() < 2 {
            return Err(BaechiError::invalid(format!(
                "calibration plan: need ≥ 2 distinct payload sizes, got {}",
                sizes.len()
            )));
        }
        if sizes[0] == 0 {
            return Err(BaechiError::invalid(
                "calibration plan: zero-byte transfers are free and unfittable",
            ));
        }
        if self.repeats == 0 {
            return Err(BaechiError::invalid("calibration plan: repeats must be ≥ 1"));
        }
        Ok(())
    }
}

/// Raw calibration measurements, the fitter's input. Construct via
/// [`collect`] or build by hand (e.g. from
/// [`crate::profile::pjrt::profile_exec`] timings of real kernels).
#[derive(Debug, Clone)]
pub struct Measurements {
    /// Device count; pair cells are row-major `src * n + dst`.
    pub n: usize,
    /// Per ordered pair: `(payload bytes, seconds)` transfer samples.
    pub transfers: Vec<Vec<(u64, f64)>>,
    /// Per device: `(reference seconds, measured seconds)` op samples.
    pub ops: Vec<Vec<(f64, f64)>>,
    /// Which source produced these (carried into the report).
    pub source: String,
}

impl Measurements {
    pub fn new(n: usize, source: impl Into<String>) -> Measurements {
        Measurements {
            n,
            transfers: vec![Vec::new(); n * n],
            ops: vec![Vec::new(); n],
            source: source.into(),
        }
    }

    /// Record one transfer sample (`src != dst`).
    pub fn push_transfer(&mut self, src: usize, dst: usize, bytes: u64, secs: f64) {
        assert!(
            src < self.n && dst < self.n && src != dst,
            "push_transfer({src}, {dst}) on a {}-device measurement set",
            self.n
        );
        self.transfers[src * self.n + dst].push((bytes, secs));
    }

    /// Record one op-probe sample.
    pub fn push_op(&mut self, device: usize, reference: f64, measured: f64) {
        assert!(
            device < self.n,
            "push_op({device}) on a {}-device measurement set",
            self.n
        );
        self.ops[device].push((reference, measured));
    }

    /// Total samples collected (transfers + op probes).
    pub fn len(&self) -> usize {
        self.transfers.iter().map(Vec::len).sum::<usize>()
            + self.ops.iter().map(Vec::len).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Drive `source` through `plan`: every ordered device pair gets the
/// full payload sweep, every device the op probes.
pub fn collect(
    source: &mut dyn MeasurementSource,
    plan: &CalibrationPlan,
) -> crate::Result<Measurements> {
    plan.validate()?;
    let n = source.devices();
    let mut m = Measurements::new(n, source.name());
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            for &bytes in &plan.payload_sizes {
                for _ in 0..plan.repeats {
                    let t = source.measure_transfer(src, dst, bytes)?;
                    m.push_transfer(src, dst, bytes, t);
                }
            }
        }
    }
    for device in 0..n {
        for &reference in &plan.op_probes {
            for _ in 0..plan.op_repeats.max(1) {
                let t = source.measure_op(device, reference)?;
                m.push_op(device, reference, t);
            }
        }
    }
    Ok(m)
}

/// Collect and fit in one call: the `baechi calibrate` entry point.
pub fn calibrate(
    source: &mut dyn MeasurementSource,
    plan: &CalibrationPlan,
) -> crate::Result<CalibratedCluster> {
    fit_cluster(&collect(source, plan)?)
}

/// Quality of one calibration run: how well the recovered topology's
/// effective pair matrix reproduces the measurements, plus condition
/// warnings (thin sweeps, rank-deficient splits, off-reference speeds).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationReport {
    pub source: String,
    pub devices: usize,
    pub n_islands: usize,
    /// Mean relative error of the recovered vs measured pair costs.
    pub mean_rel_error: f64,
    /// Worst single-pair relative error.
    pub max_rel_error: f64,
    /// Per ordered pair (row-major `src * n + dst`, 0 on the diagonal).
    pub pair_rel_error: Vec<f64>,
    pub warnings: Vec<String>,
}

impl CalibrationReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("source", self.source.as_str())
            .set("devices", self.devices)
            .set("islands", self.n_islands)
            .set("mean_rel_error", self.mean_rel_error)
            .set("max_rel_error", self.max_rel_error)
            .set(
                "pair_rel_error",
                Json::Arr(self.pair_rel_error.iter().map(|&e| Json::from(e)).collect()),
            )
            .set(
                "warnings",
                Json::Arr(
                    self.warnings
                        .iter()
                        .map(|w| Json::from(w.as_str()))
                        .collect(),
                ),
            );
        j
    }

    pub fn from_json(doc: &Json) -> crate::Result<CalibrationReport> {
        let invalid = |what: &str| {
            BaechiError::invalid(format!("calibration report: missing/invalid '{what}'"))
        };
        let get_f = |key: &str| doc.get(key).and_then(Json::as_f64).ok_or_else(|| invalid(key));
        Ok(CalibrationReport {
            source: doc
                .get("source")
                .and_then(Json::as_str)
                .ok_or_else(|| invalid("source"))?
                .to_string(),
            devices: get_f("devices")? as usize,
            n_islands: get_f("islands")? as usize,
            mean_rel_error: get_f("mean_rel_error")?,
            max_rel_error: get_f("max_rel_error")?,
            pair_rel_error: doc
                .get("pair_rel_error")
                .and_then(Json::as_arr)
                .ok_or_else(|| invalid("pair_rel_error"))?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| invalid("pair_rel_error")))
                .collect::<crate::Result<_>>()?,
            warnings: doc
                .get("warnings")
                .and_then(Json::as_arr)
                .map(|arr| {
                    arr.iter()
                        .filter_map(|v| v.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

/// The calibration artifact: a measured [`Topology`] plus its quality
/// report, serializable so a cluster is calibrated once and reused by
/// every subsequent run (`--calibrate <artifact>.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibratedCluster {
    pub topology: Topology,
    pub report: CalibrationReport,
}

impl CalibratedCluster {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", 1u64)
            .set("topology", topo_json::to_json(&self.topology))
            .set("report", self.report.to_json());
        j
    }

    pub fn from_json(doc: &Json) -> crate::Result<CalibratedCluster> {
        let topo = doc
            .get("topology")
            .ok_or_else(|| BaechiError::invalid("calibrated cluster: missing 'topology'"))?;
        let report = doc
            .get("report")
            .ok_or_else(|| BaechiError::invalid("calibrated cluster: missing 'report'"))?;
        Ok(CalibratedCluster {
            topology: topo_json::from_json(topo)?,
            report: CalibrationReport::from_json(report)?,
        })
    }

    pub fn from_json_str(text: &str) -> crate::Result<CalibratedCluster> {
        CalibratedCluster::from_json(&Json::parse(text)?)
    }

    /// Write the artifact to `path` (pretty JSON).
    pub fn save(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| BaechiError::io(format!("writing {path}: {e}")))
    }

    /// Load an artifact previously written by [`CalibratedCluster::save`].
    pub fn load(path: &str) -> crate::Result<CalibratedCluster> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| BaechiError::io(format!("reading {path}: {e}")))?;
        CalibratedCluster::from_json_str(&text)
    }

    /// Attach the measured topology to a cluster (device counts must
    /// match); the calibrated speeds and pairwise costs replace the
    /// hand-specified ones.
    pub fn apply_to(&self, cluster: Cluster) -> crate::Result<Cluster> {
        cluster.with_topology(self.topology.clone())
    }
}

/// One runtime observation of a link's usage during a measured step —
/// the fields a runtime harness can actually record (no queue-depth
/// histogram; that stays simulator-only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkObservation {
    /// Link index into [`Topology::links`].
    pub link: usize,
    /// Seconds the link spent mid-transfer.
    pub busy: f64,
    /// Seconds transfers crossing this link spent queued.
    pub blocked: f64,
    /// Transfers that crossed the link.
    pub transfers: usize,
    /// Payload bytes carried.
    pub bytes: u64,
}

/// Assemble a [`ContentionReport`] from runtime measurements, validated
/// against `topo` (link indices in range, non-negative finite times;
/// per-link busy time is capped at the step time like the simulator's
/// accounting). Multiple observations of one link accumulate. The
/// result is exactly the shape
/// [`place_iterative_measured`](crate::engine::PlacementEngine::place_iterative_measured)
/// consumes.
pub fn measured_report(
    topo: &Topology,
    makespan: f64,
    observations: &[LinkObservation],
) -> crate::Result<ContentionReport> {
    if !makespan.is_finite() || makespan <= 0.0 {
        return Err(BaechiError::invalid(format!(
            "measured report: step time must be positive and finite, got {makespan}"
        )));
    }
    let n_links = topo.n_links();
    let mut links: Vec<LinkUse> = (0..n_links)
        .map(|link| LinkUse {
            link,
            ..LinkUse::default()
        })
        .collect();
    for o in observations {
        if o.link >= n_links {
            return Err(BaechiError::invalid(format!(
                "measured report: link {} out of range ({} links)",
                o.link, n_links
            )));
        }
        if !o.busy.is_finite() || o.busy < 0.0 || !o.blocked.is_finite() || o.blocked < 0.0 {
            return Err(BaechiError::invalid(format!(
                "measured report: link {}: busy/blocked must be non-negative finite \
                 (got {} / {})",
                o.link, o.busy, o.blocked
            )));
        }
        if o.blocked > 0.0 && o.transfers == 0 {
            // The adjustment charges the observed wait per transfer, so
            // blocked seconds without a transfer count would pass the
            // trigger yet silently produce a no-op adjustment — reject
            // instead, telling the harness what it forgot to record.
            return Err(BaechiError::invalid(format!(
                "measured report: link {}: {} blocked seconds with 0 transfers — \
                 per-link transfer counts are required to attribute queueing",
                o.link, o.blocked
            )));
        }
        let u = &mut links[o.link];
        u.busy = (u.busy + o.busy).min(makespan);
        u.blocked += o.blocked;
        u.transfers += o.transfers;
        u.bytes += o.bytes;
    }
    let busy_seconds = links.iter().map(|u| u.busy).sum();
    let blocked_seconds = links.iter().map(|u| u.blocked).sum();
    Ok(ContentionReport {
        makespan,
        links,
        queue_depth_hist: vec![0; QUEUE_DEPTH_BUCKETS],
        blocked_seconds,
        busy_seconds,
        drop_warnings: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    fn comm(lat: f64, bw: f64) -> CommModel {
        CommModel::new(lat, bw).unwrap()
    }

    #[test]
    fn collect_covers_every_pair_and_device() {
        let topo = Topology::uniform(3, comm(1e-5, 1e9));
        let mut src = SyntheticSource::new(topo, 0.0, 5).unwrap();
        let plan = CalibrationPlan::default();
        let m = collect(&mut src, &plan).unwrap();
        assert_eq!(m.n, 3);
        for i in 0..3 {
            for j in 0..3 {
                let cell = &m.transfers[i * 3 + j];
                if i == j {
                    assert!(cell.is_empty());
                } else {
                    assert_eq!(cell.len(), plan.payload_sizes.len() * plan.repeats);
                }
            }
            assert_eq!(m.ops[i].len(), plan.op_probes.len() * plan.op_repeats);
        }
        assert!(!m.is_empty());
    }

    #[test]
    fn plan_validation_is_typed() {
        let topo = Topology::uniform(2, comm(0.0, 1e9));
        let mut src = SyntheticSource::new(topo, 0.0, 5).unwrap();
        for plan in [
            CalibrationPlan {
                payload_sizes: vec![1 << 20],
                ..CalibrationPlan::default()
            },
            CalibrationPlan {
                payload_sizes: vec![1 << 20, 1 << 20],
                ..CalibrationPlan::default()
            },
            CalibrationPlan {
                payload_sizes: vec![0, 1 << 20],
                ..CalibrationPlan::default()
            },
            CalibrationPlan {
                repeats: 0,
                ..CalibrationPlan::default()
            },
        ] {
            assert!(matches!(
                collect(&mut src, &plan),
                Err(BaechiError::InvalidRequest(_))
            ));
        }
    }

    #[test]
    fn artifact_round_trips_through_json() {
        let topo = Topology::two_tier(2, 2, comm(1e-5, 10e9), comm(8e-5, 1.25e9)).unwrap();
        let mut src = SyntheticSource::new(topo, 0.0, 9).unwrap();
        let cal = calibrate(&mut src, &CalibrationPlan::default()).unwrap();
        let text = cal.to_json().pretty();
        let back = CalibratedCluster::from_json_str(&text).unwrap();
        assert_eq!(cal, back);
        // And applies onto a matching cluster.
        let cluster = Cluster::homogeneous(4, 1 << 30, comm(8e-5, 1.25e9));
        let c = cal.apply_to(cluster).unwrap();
        assert_eq!(c.topology(), &cal.topology);
        // Mismatched device count is typed.
        let c2 = Cluster::homogeneous(3, 1 << 30, comm(8e-5, 1.25e9));
        assert!(matches!(
            cal.apply_to(c2),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn measured_report_validates_and_accumulates() {
        let topo = Topology::two_tier(2, 2, comm(1e-5, 10e9), comm(8e-5, 1.25e9)).unwrap();
        let obs = [
            LinkObservation {
                link: 0,
                busy: 0.4,
                blocked: 0.2,
                transfers: 3,
                bytes: 1 << 20,
            },
            LinkObservation {
                link: 0,
                busy: 0.8,
                blocked: 0.1,
                transfers: 1,
                bytes: 1 << 10,
            },
        ];
        let r = measured_report(&topo, 1.0, &obs).unwrap();
        assert_eq!(r.links.len(), topo.n_links());
        assert_eq!(r.links[0].transfers, 4);
        // Accumulated busy capped at the step time.
        assert!((r.links[0].busy - 1.0).abs() < 1e-12);
        assert!((r.links[0].blocked - 0.3).abs() < 1e-12);
        assert!((r.blocked_seconds - 0.3).abs() < 1e-12);
        assert!(r.max_utilization() >= 1.0 - 1e-12);
        // Out-of-range link and bad step time are typed.
        let bad = [LinkObservation {
            link: 999,
            busy: 0.0,
            blocked: 0.0,
            transfers: 0,
            bytes: 0,
        }];
        assert!(matches!(
            measured_report(&topo, 1.0, &bad),
            Err(BaechiError::InvalidRequest(_))
        ));
        assert!(matches!(
            measured_report(&topo, 0.0, &[]),
            Err(BaechiError::InvalidRequest(_))
        ));
        // Blocked time without a transfer count can never be attributed
        // by the adjustment — typed error, not a silent no-op loop.
        let unattributable = [LinkObservation {
            link: 0,
            busy: 0.1,
            blocked: 5.0,
            transfers: 0,
            bytes: 0,
        }];
        match measured_report(&topo, 1.0, &unattributable) {
            Err(BaechiError::InvalidRequest(msg)) => {
                assert!(msg.contains("transfer counts"), "{msg}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }
}
