//! Turning raw measurements into a cluster model.
//!
//! The pipeline (paper §4.1's Profiler, generalized from one fitted
//! model to a whole interconnect):
//!
//! 1. **Per-pair models** — for every ordered device pair, group the
//!    transfer samples by payload size, take the per-size median (robust
//!    to scheduler outliers), and run the least-squares
//!    [`CommModel::fit`].
//! 2. **Island inference** — cluster the symmetrized pairwise
//!    bandwidths: if the spread exceeds [`ISLAND_GAP`], devices joined
//!    by above-threshold bandwidth (geometric midpoint) form islands.
//! 3. **Link fit** — intra-island pairs become direct links carrying the
//!    symmetrized pair model. Cross-island traffic is explained by a
//!    star through one core switch: per-device spoke latencies and
//!    inverse bandwidths are solved by least squares over all cross
//!    pairs (normal equations assembled in [`crate::lp::matrix`]), so
//!    the path-composed spoke+spoke cost reproduces the measured matrix.
//! 4. **Speed fit** — per-device speed factors are the median of
//!    `reference / measured` over the op probes (1.0 = the profiling
//!    device of the analytic cost model).
//!
//! The result carries a quality report: per-pair relative error of the
//! recovered effective matrix against the measured medians, plus
//! condition warnings (thin sweeps, rank-deficient spoke splits, poor
//! residuals).

use super::{CalibratedCluster, CalibrationReport, Measurements};
use crate::error::BaechiError;
use crate::lp::matrix::{Cholesky, Mat};
use crate::profile::CommModel;
use crate::topology::{Link, LinkKind, Topology};
use std::collections::BTreeMap;

/// Pair spread below which a single-island cluster collapses to the
/// bit-exact [`Topology::uniform`] representation.
const UNIFORM_TOL: f64 = 0.02;
/// Max/min pairwise-bandwidth ratio below which everything is one island.
const ISLAND_GAP: f64 = 2.0;
/// Fitted speeds within this of 1.0 collapse to "inherit the cluster's".
const SPEED_TOL: f64 = 0.02;
/// Pair residual above which a warning is recorded.
const RESIDUAL_WARN: f64 = 0.10;
/// Link-kind classification thresholds on end-to-end pair bandwidth.
const NVLINK_BW: f64 = 25e9;
const PCIE_BW: f64 = 4e9;

/// Payloads the recovered topology is scored at (per-pair relative
/// error in the report): one latency-dominated, one bandwidth-dominated.
const SCORE_BYTES: [u64; 2] = [64 << 10, 8 << 20];

/// Mean relative error of `rec`'s effective all-pairs matrix against
/// `truth`'s, scored at [`SCORE_BYTES`] (one latency-dominated, one
/// bandwidth-dominated payload) — the single definition behind the
/// report's self-assessment, the round-trip property tests, and the
/// fig11 bench. Panics if the two topologies disagree on device count
/// (comparing matrices of different clusters is a caller bug).
pub fn pair_matrix_error(rec: &Topology, truth: &Topology) -> f64 {
    assert_eq!(
        rec.n(),
        truth.n(),
        "pair_matrix_error: {} vs {} devices",
        rec.n(),
        truth.n()
    );
    let n = truth.n();
    if n < 2 {
        return 0.0;
    }
    let mut sum = 0.0;
    let mut k = 0usize;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            for &bytes in &SCORE_BYTES {
                let t = truth.time(i, j, bytes).max(1e-12);
                sum += (rec.time(i, j, bytes) - t).abs() / t;
                k += 1;
            }
        }
    }
    sum / k as f64
}

/// Classify a link by the end-to-end bandwidth it sustains.
fn classify(pair_bandwidth: f64) -> LinkKind {
    if !pair_bandwidth.is_finite() {
        // Zero-cost wiring (infinite bandwidth): kind is cosmetic.
        LinkKind::Pcie
    } else if pair_bandwidth >= NVLINK_BW {
        LinkKind::NvLink
    } else if pair_bandwidth >= PCIE_BW {
        LinkKind::Pcie
    } else {
        LinkKind::Nic
    }
}

fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Union-find with path halving.
struct Dsu(Vec<usize>);

impl Dsu {
    fn new(n: usize) -> Dsu {
        Dsu((0..n).collect())
    }
    fn find(&mut self, mut x: usize) -> usize {
        while self.0[x] != x {
            self.0[x] = self.0[self.0[x]];
            x = self.0[x];
        }
        x
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        self.0[ra] = rb;
    }
}

/// Fit the full cluster model from raw measurements. Errors with
/// [`BaechiError::InvalidRequest`] on unmeasured pairs, degenerate
/// sweeps, or non-physical samples; soft quality issues land in
/// [`CalibrationReport::warnings`] instead.
pub fn fit_cluster(m: &Measurements) -> crate::Result<CalibratedCluster> {
    let n = m.n;
    if n < 2 {
        return Err(BaechiError::invalid(format!(
            "calibration: need at least 2 devices, got {n}"
        )));
    }
    if m.transfers.len() != n * n {
        return Err(BaechiError::invalid(format!(
            "calibration: {} transfer cells for {n} devices (need {})",
            m.transfers.len(),
            n * n
        )));
    }
    let mut warnings = Vec::new();

    // 1. Per-pair medians and least-squares models.
    let mut pair = vec![CommModel { latency: 0.0, bandwidth: f64::INFINITY }; n * n];
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            let cell = &m.transfers[src * n + dst];
            let mut by_size: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
            for &(bytes, secs) in cell {
                if bytes == 0 || !secs.is_finite() || secs < 0.0 {
                    return Err(BaechiError::invalid(format!(
                        "calibration: non-physical transfer sample {src}→{dst}: \
                         ({bytes} B, {secs} s)"
                    )));
                }
                by_size.entry(bytes).or_default().push(secs);
            }
            if by_size.len() < 2 {
                return Err(BaechiError::invalid(format!(
                    "calibration: pair {src}→{dst} has {} distinct payload sizes \
                     (need ≥ 2 to identify latency and bandwidth)",
                    by_size.len()
                )));
            }
            if by_size.len() < 3 {
                warnings.push(format!(
                    "pair {src}→{dst}: thin sweep ({} payload sizes)",
                    by_size.len()
                ));
            }
            let meds: BTreeMap<u64, f64> = by_size
                .into_iter()
                .map(|(b, mut ts)| (b, median(&mut ts)))
                .collect();
            let samples: Vec<(u64, f64)> = meds.iter().map(|(&b, &t)| (b, t)).collect();
            pair[src * n + dst] = CommModel::fit(&samples).map_err(|e| {
                BaechiError::invalid(format!("calibration: pair {src}→{dst}: {e}"))
            })?;
        }
    }

    // Symmetrized pair costs: mean latency, harmonic-mean bandwidth.
    let sym = |i: usize, j: usize| -> CommModel {
        let (a, b) = (&pair[i * n + j], &pair[j * n + i]);
        CommModel {
            latency: (a.latency + b.latency) / 2.0,
            bandwidth: 2.0 / (1.0 / a.bandwidth + 1.0 / b.bandwidth),
        }
    };

    // 2. Island inference from bandwidth clustering.
    let mut bw_min = f64::INFINITY;
    let mut bw_max = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let bw = sym(i, j).bandwidth;
            bw_min = bw_min.min(bw);
            bw_max = bw_max.max(bw);
        }
    }
    let mut dsu = Dsu::new(n);
    if bw_min.is_finite() && bw_max / bw_min > ISLAND_GAP {
        let threshold = (bw_min * bw_max).sqrt();
        for i in 0..n {
            for j in (i + 1)..n {
                if sym(i, j).bandwidth >= threshold {
                    dsu.union(i, j);
                }
            }
        }
    } else {
        for d in 1..n {
            dsu.union(0, d);
        }
    }
    let mut island_id: BTreeMap<usize, usize> = BTreeMap::new();
    let mut islands = Vec::with_capacity(n);
    for d in 0..n {
        let root = dsu.find(d);
        let next = island_id.len();
        islands.push(*island_id.entry(root).or_insert(next));
    }
    let n_islands = island_id.len();

    // 3. Per-device speed factors from op probes.
    let speeds = fit_speeds(m, &mut warnings)?;

    // 4. Structure + link fit.
    let topology = if n_islands == 1 && is_uniform(&pair, n) {
        let mut t = Topology::uniform(n, mean_model(&pair, n));
        if let Some(s) = &speeds {
            t = t.with_speeds(s.clone())?;
        }
        t
    } else {
        let mut links = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if islands[i] == islands[j] {
                    let c = sym(i, j);
                    links.push(Link {
                        a: i,
                        b: j,
                        kind: classify(c.bandwidth),
                        comm: c,
                    });
                }
            }
        }
        if n_islands > 1 {
            if n_islands == 2 {
                warnings.push(
                    "2 islands: the cross-island spoke split is rank-deficient \
                     (only spoke sums are identifiable); costs are split evenly"
                        .to_string(),
                );
            }
            let (lat, inv_bw) = fit_spokes(&pair, n, &islands)?;
            let core = n;
            for d in 0..n {
                let spoke_bw = if inv_bw[d] > 0.0 {
                    1.0 / inv_bw[d]
                } else {
                    f64::INFINITY
                };
                // Classify by the composed pair bandwidth two such
                // spokes sustain end-to-end.
                let kind = classify(spoke_bw / 2.0);
                links.push(Link {
                    a: d,
                    b: core,
                    kind,
                    comm: CommModel {
                        latency: lat[d],
                        bandwidth: spoke_bw,
                    },
                });
            }
        }
        let n_switches = if n_islands > 1 { 1 } else { 0 };
        Topology::from_links(n, n_switches, links, Some(islands), speeds)?
    };

    // 5. Quality report: recovered effective matrix vs measured medians.
    let mut pair_rel_error = vec![0.0; n * n];
    let mut sum = 0.0;
    let mut worst = 0.0f64;
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            let meas = &pair[src * n + dst];
            let mut err = 0.0;
            for &b in &SCORE_BYTES {
                let t_meas = meas.time(b).max(1e-12);
                err += (topology.time(src, dst, b) - t_meas).abs() / t_meas;
            }
            err /= SCORE_BYTES.len() as f64;
            pair_rel_error[src * n + dst] = err;
            sum += err;
            worst = worst.max(err);
            if err > RESIDUAL_WARN {
                warnings.push(format!(
                    "pair {src}→{dst}: recovered model off by {:.1}% from measurements",
                    err * 100.0
                ));
            }
        }
    }
    let pairs = (n * n - n) as f64;
    let report = CalibrationReport {
        source: m.source.clone(),
        devices: n,
        n_islands,
        mean_rel_error: sum / pairs,
        max_rel_error: worst,
        pair_rel_error,
        warnings,
    };
    Ok(CalibratedCluster { topology, report })
}

/// Mean latency + harmonic-mean bandwidth over all ordered pairs.
fn mean_model(pair: &[CommModel], n: usize) -> CommModel {
    let mut latency = 0.0;
    let mut inv_bw = 0.0;
    let mut k = 0usize;
    for src in 0..n {
        for dst in 0..n {
            if src != dst {
                latency += pair[src * n + dst].latency;
                inv_bw += 1.0 / pair[src * n + dst].bandwidth;
                k += 1;
            }
        }
    }
    CommModel {
        latency: latency / k as f64,
        bandwidth: if inv_bw > 0.0 {
            k as f64 / inv_bw
        } else {
            f64::INFINITY
        },
    }
}

/// All ordered pairs within [`UNIFORM_TOL`] of the mean at both score
/// payloads: the cluster is a single-model star.
fn is_uniform(pair: &[CommModel], n: usize) -> bool {
    let mean = mean_model(pair, n);
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            for &b in &SCORE_BYTES {
                let t_mean = mean.time(b).max(1e-12);
                if (pair[src * n + dst].time(b) - t_mean).abs() / t_mean > UNIFORM_TOL {
                    return false;
                }
            }
        }
    }
    true
}

/// Least-squares spoke fit: find per-device `(latency, 1/bandwidth)`
/// such that `spoke_i + spoke_j` reproduces every measured cross-island
/// pair cost. Normal equations `AᵀA x = Aᵀb` are assembled densely and
/// solved with the regularized [`Cholesky`] from the LP substrate (with
/// two islands the system has a one-dimensional null space — the ridge
/// picks the even split).
fn fit_spokes(
    pair: &[CommModel],
    n: usize,
    islands: &[usize],
) -> crate::Result<(Vec<f64>, Vec<f64>)> {
    let mut normal = Mat::zeros(n, n);
    let mut rhs_lat = vec![0.0; n];
    let mut rhs_ibw = vec![0.0; n];
    let mut rows = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            if islands[i] == islands[j] {
                continue;
            }
            // Symmetrize the two directions into one equation.
            let (a, b) = (&pair[i * n + j], &pair[j * n + i]);
            let lat = (a.latency + b.latency) / 2.0;
            let ibw = (1.0 / a.bandwidth + 1.0 / b.bandwidth) / 2.0;
            normal.add_at(i, i, 1.0);
            normal.add_at(j, j, 1.0);
            normal.add_at(i, j, 1.0);
            normal.add_at(j, i, 1.0);
            rhs_lat[i] += lat;
            rhs_lat[j] += lat;
            rhs_ibw[i] += ibw;
            rhs_ibw[j] += ibw;
            rows += 1;
        }
    }
    if rows == 0 {
        return Err(BaechiError::invalid(
            "calibration: no cross-island pairs to fit spokes from",
        ));
    }
    // Tikhonov ridge: keeps the 2-island null-space direction harmless
    // and the factorization PD; the bias is ~1e-8 of the pair cost.
    let max_diag = (0..n).map(|d| normal.at(d, d)).fold(0.0, f64::max);
    let ridge = 1e-8 * (1.0 + max_diag);
    for d in 0..n {
        normal.add_at(d, d, ridge);
    }
    let ch = Cholesky::factor(normal, 1e-12)
        .map_err(|e| BaechiError::invalid(format!("calibration: spoke fit: {e}")))?;
    let lat: Vec<f64> = ch.solve(&rhs_lat).into_iter().map(|x| x.max(0.0)).collect();
    let ibw: Vec<f64> = ch.solve(&rhs_ibw).into_iter().map(|x| x.max(0.0)).collect();
    Ok((lat, ibw))
}

/// Median `reference / measured` per device; `None` when no op probes
/// ran or when every device sits within [`SPEED_TOL`] of the profiling
/// reference (speed 1.0) — the homogeneous case stays homogeneous.
fn fit_speeds(
    m: &Measurements,
    warnings: &mut Vec<String>,
) -> crate::Result<Option<Vec<f64>>> {
    if m.ops.iter().all(|cell| cell.is_empty()) {
        if !m.ops.is_empty() {
            warnings.push("no op probes: device speeds inherit the cluster's".to_string());
        }
        return Ok(None);
    }
    let mut speeds = Vec::with_capacity(m.n);
    for (d, cell) in m.ops.iter().enumerate() {
        if cell.is_empty() {
            return Err(BaechiError::invalid(format!(
                "calibration: device {d} has no op probes while others do"
            )));
        }
        let mut ratios = Vec::with_capacity(cell.len());
        for &(reference, measured) in cell {
            if !reference.is_finite()
                || reference <= 0.0
                || !measured.is_finite()
                || measured <= 0.0
            {
                return Err(BaechiError::invalid(format!(
                    "calibration: non-physical op sample on device {d}: \
                     (ref {reference} s, measured {measured} s)"
                )));
            }
            ratios.push(reference / measured);
        }
        let s = median(&mut ratios);
        if !(0.2..=5.0).contains(&s) {
            warnings.push(format!(
                "device {d}: measured speed {s:.2}× the profiling reference \
                 (op cost annotations may not transfer)"
            ));
        }
        speeds.push(s);
    }
    if speeds.iter().all(|s| (s - 1.0).abs() <= SPEED_TOL) {
        return Ok(None);
    }
    Ok(Some(speeds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::source::SyntheticSource;
    use crate::calibrate::{collect, CalibrationPlan};

    fn comm(lat: f64, bw: f64) -> CommModel {
        CommModel::new(lat, bw).unwrap()
    }

    fn calibrate_synthetic(topo: Topology, noise: f64, seed: u64) -> CalibratedCluster {
        let mut src = SyntheticSource::new(topo, noise, seed).unwrap();
        let m = collect(&mut src, &CalibrationPlan::default()).unwrap();
        fit_cluster(&m).unwrap()
    }

    #[test]
    fn uniform_ground_truth_collapses_to_uniform() {
        let truth = Topology::uniform(4, comm(5e-5, 6e9));
        let cal = calibrate_synthetic(truth.clone(), 0.0, 1);
        assert!(cal.topology.is_uniform(), "{:?}", cal.report);
        assert!(pair_matrix_error(&cal.topology, &truth) < 1e-6);
        assert!(cal.report.mean_rel_error < 1e-6);
        assert_eq!(cal.report.n_islands, 1);
    }

    #[test]
    fn two_tier_ground_truth_recovers_islands_and_matrix() {
        let truth = Topology::two_tier(2, 2, comm(1e-5, 10e9), comm(8e-5, 1.25e9)).unwrap();
        let cal = calibrate_synthetic(truth.clone(), 0.0, 2);
        assert_eq!(cal.report.n_islands, 2, "{:?}", cal.report.warnings);
        for d in 0..4 {
            assert_eq!(cal.topology.island_of(d), truth.island_of(d));
        }
        let err = pair_matrix_error(&cal.topology, &truth);
        assert!(err < 0.05, "mean rel error {err}");
        assert!(cal.report.mean_rel_error < 0.05);
        // The recovered spokes are NIC-class: the measured cross
        // bandwidth sits below the PCIe threshold.
        let cross: Vec<_> = cal
            .topology
            .links()
            .iter()
            .filter(|l| l.b == 4 || l.a == 4)
            .collect();
        assert_eq!(cross.len(), 4);
        assert!(cross.iter().all(|l| l.kind == LinkKind::Nic));
    }

    #[test]
    fn nvlink_islands_recover_kinds_and_speeds() {
        let truth = Topology::nvlink_islands(4, 2, comm(5e-6, 48e9), comm(5e-5, 6e9))
            .unwrap()
            .with_speeds(vec![1.0, 1.0, 2.0, 2.0])
            .unwrap();
        let cal = calibrate_synthetic(truth.clone(), 0.0, 3);
        assert_eq!(cal.report.n_islands, 2);
        assert!(pair_matrix_error(&cal.topology, &truth) < 0.05);
        // Intra links classified NVLink, spokes PCIe.
        for l in cal.topology.links() {
            if l.a < 4 && l.b < 4 {
                assert_eq!(l.kind, LinkKind::NvLink, "intra {l:?}");
            } else {
                assert_eq!(l.kind, LinkKind::Pcie, "spoke {l:?}");
            }
        }
        let speeds = cal.topology.speeds().expect("heterogeneous speeds kept");
        for (d, &s) in speeds.iter().enumerate() {
            assert!(
                (s - truth.speed(d)).abs() < 0.05,
                "device {d}: {s} vs {}",
                truth.speed(d)
            );
        }
    }

    #[test]
    fn unmeasured_pair_and_degenerate_sweep_are_typed() {
        let mut m = Measurements::new(2, "test");
        assert!(matches!(
            fit_cluster(&m),
            Err(BaechiError::InvalidRequest(_))
        ));
        // One size only: latency/bandwidth unidentifiable.
        m.push_transfer(0, 1, 1 << 20, 1e-3);
        m.push_transfer(1, 0, 1 << 20, 1e-3);
        assert!(matches!(
            fit_cluster(&m),
            Err(BaechiError::InvalidRequest(_))
        ));
        // Single device is meaningless to calibrate.
        assert!(matches!(
            fit_cluster(&Measurements::new(1, "test")),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn noisy_measurements_stay_close_and_warn_on_thin_sweeps() {
        let truth = Topology::two_tier(2, 2, comm(1e-5, 10e9), comm(8e-5, 1.25e9)).unwrap();
        let mut src = SyntheticSource::new(truth.clone(), 0.03, 11).unwrap();
        let plan = CalibrationPlan {
            payload_sizes: vec![64 << 10, 8 << 20],
            repeats: 5,
            ..CalibrationPlan::default()
        };
        let m = collect(&mut src, &plan).unwrap();
        let cal = fit_cluster(&m).unwrap();
        assert!(
            cal.report.warnings.iter().any(|w| w.contains("thin sweep")),
            "{:?}",
            cal.report.warnings
        );
        let err = pair_matrix_error(&cal.topology, &truth);
        assert!(err < 0.15, "3% noise should stay near truth, got {err}");
    }
}
