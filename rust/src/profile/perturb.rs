//! Profile perturbation for the sensitivity study (paper Fig. 8).
//!
//! "All computation and communication profiles are randomly and
//! independently perturbed by up to ±20%" — we perturb every node's
//! compute time and every edge's byte count (which the comm model maps
//! linearly to time) by an independent uniform factor in `[1-ε, 1+ε]`.

use crate::graph::OpGraph;
use crate::util::rng::Pcg;

/// Return a copy of `graph` with compute times and edge bytes perturbed
/// by independent uniform factors in `[1 - eps, 1 + eps]`.
pub fn perturb_graph(graph: &OpGraph, eps: f64, rng: &mut Pcg) -> OpGraph {
    assert!((0.0..1.0).contains(&eps), "eps must be in [0,1)");
    let mut g = graph.clone();
    let ids: Vec<_> = g.node_ids().collect();
    for id in ids {
        let factor = rng.uniform(1.0 - eps, 1.0 + eps);
        let n = g.node_mut(id);
        n.compute *= factor;
    }
    // Edges: rebuild with perturbed byte counts.
    let edges = g.edges();
    let mut out = OpGraph::new(&g.name);
    // Clone nodes in id order into a fresh graph to perturb edge weights.
    // Simpler: mutate in place via add_edge max-merge won't reduce bytes,
    // so we construct a new graph mirroring node ids.
    for i in 0..g.capacity() {
        let id = crate::graph::NodeId(i);
        if g.is_alive(id) {
            let n = g.node(id).clone();
            let new_id = out.add_node(&n.name, n.kind.clone());
            assert_eq!(new_id.0, i, "perturb requires dense live ids");
            *out.node_mut(new_id) = crate::graph::OpNode { id: new_id, ..n };
        } else {
            // Preserve id density with a dead placeholder.
            let placeholder = out.add_node("dead", crate::graph::OpKind::Generic(0));
            out.remove_node(placeholder);
        }
    }
    for e in edges {
        let factor = rng.uniform(1.0 - eps, 1.0 + eps);
        let bytes = ((e.bytes as f64) * factor).round().max(0.0) as u64;
        out.add_edge(e.src, e.dst, bytes.max(1));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpGraph, OpKind};

    fn sample() -> OpGraph {
        let mut g = OpGraph::new("p");
        let a = g.add_node("a", OpKind::Input);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::Loss);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 2.0;
        g.node_mut(c).compute = 3.0;
        g.add_edge(a, b, 1000);
        g.add_edge(b, c, 2000);
        g
    }

    #[test]
    fn bounds_respected() {
        let g = sample();
        let mut rng = Pcg::seed(1);
        for _ in 0..50 {
            let p = perturb_graph(&g, 0.2, &mut rng);
            for id in g.node_ids() {
                let ratio = p.node(id).compute / g.node(id).compute;
                assert!((0.8..=1.2).contains(&ratio), "ratio {ratio}");
            }
            for e in g.edges() {
                let pb = p.edge_bytes(e.src, e.dst).unwrap() as f64;
                let ratio = pb / e.bytes as f64;
                assert!((0.79..=1.21).contains(&ratio), "edge ratio {ratio}");
            }
        }
    }

    #[test]
    fn structure_preserved() {
        let g = sample();
        let mut rng = Pcg::seed(2);
        let p = perturb_graph(&g, 0.2, &mut rng);
        assert_eq!(p.len(), g.len());
        assert_eq!(p.edge_count(), g.edge_count());
        assert!(p.is_acyclic());
        for e in g.edges() {
            assert!(p.edge_bytes(e.src, e.dst).is_some());
        }
    }

    #[test]
    fn zero_eps_identity_compute() {
        let g = sample();
        let mut rng = Pcg::seed(3);
        let p = perturb_graph(&g, 0.0, &mut rng);
        for id in g.node_ids() {
            assert!((p.node(id).compute - g.node(id).compute).abs() < 1e-12);
        }
    }

    #[test]
    fn survives_tombstones() {
        let mut g = sample();
        let dead = g.add_node("x", OpKind::Shape);
        g.remove_node(dead);
        let mut rng = Pcg::seed(4);
        let p = perturb_graph(&g, 0.1, &mut rng);
        assert_eq!(p.len(), g.len());
    }
}
