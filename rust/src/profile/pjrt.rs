//! PJRT-backed operator profiler.
//!
//! Mirrors the paper's Profiler (§4.1.1): runs each compiled kernel a few
//! warmup iterations (ignoring bootstrap steps, §4.4), then measures
//! steady-state wall time. Used by the end-to-end example to annotate the
//! real model's graph with measured compute times; the synthetic paper
//! benchmarks use the analytic cost model in [`crate::models`] instead.

use crate::runtime::artifact::LoadedExec;
use crate::runtime::xla;
use crate::util::stats::Summary;
use std::time::Instant;

/// Result of profiling one executable.
#[derive(Debug, Clone)]
pub struct OpProfile {
    pub name: String,
    /// Steady-state mean wall time, seconds.
    pub compute: f64,
    pub summary: Summary,
}

/// Profile an executable with the given literal inputs.
///
/// `warmup` iterations are discarded (TF-style bootstrap skipping), then
/// `iters` timed runs are summarized.
pub fn profile_exec(
    exec: &LoadedExec,
    inputs: &[xla::Literal],
    warmup: usize,
    iters: usize,
) -> crate::Result<OpProfile> {
    for _ in 0..warmup {
        exec.run(inputs)?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        let out = exec.run(inputs)?;
        // Force materialization so we time the full execution.
        std::hint::black_box(&out);
        samples.push(t0.elapsed().as_secs_f64());
    }
    let summary = Summary::of(&samples);
    Ok(OpProfile {
        name: exec.name.clone(),
        compute: summary.p50, // median is robust to scheduler noise
        summary,
    })
}

/// Microbenchmark host-side buffer copies of increasing size and fit the
/// linear communication model from the samples. This stands in for the
/// paper's GPU-to-GPU transfer microbenchmark: in our substitution the
/// interconnect is host memory, so a memcpy-based model is the honest
/// equivalent (DESIGN.md §2).
pub fn microbench_comm(max_mb: usize) -> crate::Result<super::CommModel> {
    let mut samples = Vec::new();
    let mut size = 64 * 1024; // 64 KiB
    let max = max_mb * 1024 * 1024;
    while size <= max {
        let src = vec![0u8; size];
        let mut dst = vec![0u8; size];
        // Warm.
        dst.copy_from_slice(&src);
        let reps = (8 * 1024 * 1024 / size).clamp(3, 64);
        let t0 = Instant::now();
        for _ in 0..reps {
            dst.copy_from_slice(&src);
            std::hint::black_box(&dst);
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        samples.push((size as u64, per));
        size *= 2;
    }
    super::CommModel::fit(&samples)
}

/// Time one host-memory copy of `bytes` between two freshly-allocated
/// buffers, seconds (median over `reps` runs). The single-transfer probe
/// behind [`crate::calibrate`]'s runtime measurement source — the paper's
/// GPU-pair transfer microbenchmark, restated for the host-mediated
/// testbed (§5.1: no P2P, every transfer goes through host memory).
pub fn time_host_copy(bytes: usize, reps: usize) -> f64 {
    let bytes = bytes.max(1);
    let src = vec![0u8; bytes];
    let mut dst = vec![0u8; bytes];
    dst.copy_from_slice(&src); // warm both buffers
    let mut samples = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbench_produces_sane_model() {
        let m = microbench_comm(4).unwrap();
        // Host memcpy bandwidth should be between 100 MB/s and 1 TB/s.
        assert!(m.bandwidth > 1e8, "bandwidth {}", m.bandwidth);
        assert!(m.bandwidth < 1e13, "bandwidth {}", m.bandwidth);
        assert!(m.latency >= 0.0);
        // Larger transfers take longer.
        assert!(m.time(64 * 1024 * 1024) > m.time(1024 * 1024));
    }

    #[test]
    fn host_copy_probe_is_positive_and_monotone_ish() {
        let small = time_host_copy(64 << 10, 5);
        let large = time_host_copy(16 << 20, 5);
        assert!(small > 0.0);
        assert!(large > small, "16 MiB copy ({large}) ≤ 64 KiB copy ({small})");
    }
}
