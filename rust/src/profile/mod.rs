//! Profiling substrate: device specifications, the linear communication
//! cost model (paper §4.1), and profile perturbation (paper Fig. 8).
//!
//! The paper profiles each operator on the target GPU and fits a linear
//! communication-cost model `t(bytes) = a + b·bytes` from a microbenchmark.
//! We reproduce both: [`CommModel::fit`] performs the least-squares fit,
//! and [`pjrt`] measures real per-op wall times of the AOT HLO kernels.

pub mod perturb;
pub mod pjrt;

use crate::util::stats::linear_fit;

/// Static description of one device in the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Usable memory in bytes (possibly capped to a fraction, Table 5).
    pub memory: u64,
    /// Relative compute speed (1.0 = the profiling device).
    pub speed: f64,
}

/// Cluster description handed to placers and the ES.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<DeviceSpec>,
    pub comm: CommModel,
    /// If true, each device performs at most one transfer at a time and
    /// transfers queue up (paper §3.1.4 — the PCIe-through-host testbed).
    pub sequential_comm: bool,
}

impl Cluster {
    /// Homogeneous cluster of `n` devices with `memory` bytes each.
    pub fn homogeneous(n: usize, memory: u64, comm: CommModel) -> Cluster {
        Cluster {
            devices: vec![DeviceSpec { memory, speed: 1.0 }; n],
            comm,
            sequential_comm: true,
        }
    }

    /// Cap every device's memory to `fraction` of its current value
    /// (the paper's "insufficient memory" regime, Table 5).
    pub fn with_memory_fraction(mut self, fraction: f64) -> Cluster {
        for d in &mut self.devices {
            d.memory = (d.memory as f64 * fraction) as u64;
        }
        self
    }

    pub fn with_sequential_comm(mut self, seq: bool) -> Cluster {
        self.sequential_comm = seq;
        self
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Total cluster memory, bytes.
    pub fn total_memory(&self) -> u64 {
        self.devices.iter().map(|d| d.memory).sum()
    }
}

/// Linear communication cost model `t(bytes) = latency + bytes / bandwidth`
/// (paper §4.1: "we use a linear model proportional to data size ...
/// generated a communication cost function through linear regression").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// Fixed per-transfer latency, seconds.
    pub latency: f64,
    /// Bandwidth, bytes per second.
    pub bandwidth: f64,
}

impl CommModel {
    pub fn new(latency: f64, bandwidth: f64) -> CommModel {
        assert!(bandwidth > 0.0);
        CommModel { latency, bandwidth }
    }

    /// The paper's testbed: GPUs on PCIe 3.0 x16 through host memory, no
    /// P2P — effective ~6 GB/s with high (~50 µs) per-transfer latency.
    /// (Paper §5.3 reports a 4-byte transfer costs 50–200 µs.)
    pub fn pcie_via_host() -> CommModel {
        CommModel::new(50e-6, 6e9)
    }

    /// A fast NVLink-like interconnect (ablation; paper footnote 4).
    pub fn nvlink_like() -> CommModel {
        CommModel::new(5e-6, 50e9)
    }

    /// Transfer time for a payload, seconds. Zero-byte transfers are free
    /// (no tensor moves).
    pub fn time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency + bytes as f64 / self.bandwidth
        }
    }

    /// Least-squares fit from `(bytes, seconds)` microbenchmark samples.
    pub fn fit(samples: &[(u64, f64)]) -> CommModel {
        let xs: Vec<f64> = samples.iter().map(|&(b, _)| b as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, t)| t).collect();
        let (a, b, _r2) = linear_fit(&xs, &ys);
        CommModel {
            latency: a.max(0.0),
            bandwidth: if b > 0.0 { 1.0 / b } else { f64::INFINITY },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_model_linear() {
        let m = CommModel::new(1e-4, 1e9);
        assert_eq!(m.time(0), 0.0);
        assert!((m.time(1_000_000) - (1e-4 + 1e-3)).abs() < 1e-12);
        assert!(m.time(2_000_000) > m.time(1_000_000));
    }

    #[test]
    fn fit_recovers_parameters() {
        let truth = CommModel::new(5e-5, 2e9);
        let samples: Vec<(u64, f64)> = (1..20)
            .map(|i| {
                let b = i * 500_000;
                (b, truth.time(b))
            })
            .collect();
        let fitted = CommModel::fit(&samples);
        assert!((fitted.latency - truth.latency).abs() / truth.latency < 0.01);
        assert!((fitted.bandwidth - truth.bandwidth).abs() / truth.bandwidth < 0.01);
    }

    #[test]
    fn cluster_memory_fraction() {
        let c = Cluster::homogeneous(4, 8_000_000_000, CommModel::pcie_via_host())
            .with_memory_fraction(0.3);
        assert_eq!(c.n(), 4);
        assert_eq!(c.devices[0].memory, 2_400_000_000);
        assert_eq!(c.total_memory(), 4 * 2_400_000_000);
    }
}
