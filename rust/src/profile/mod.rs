//! Profiling substrate: device specifications, the linear communication
//! cost model (paper §4.1), and profile perturbation (paper Fig. 8).
//!
//! The paper profiles each operator on the target GPU and fits a linear
//! communication-cost model `t(bytes) = a + b·bytes` from a microbenchmark.
//! We reproduce both: [`CommModel::fit`] performs the least-squares fit,
//! and [`pjrt`] measures real per-op wall times of the AOT HLO kernels.
//!
//! Since the topology subsystem, a [`Cluster`] also carries a
//! [`Topology`] describing its interconnect. [`Cluster::homogeneous`]
//! keeps the paper's uniform single-model behavior (bit-for-bit);
//! [`Cluster::with_topology`] attaches NVLink islands, two-tier
//! machines, or a JSON-loaded link graph, which the placers and the
//! execution simulator then consult pair-by-pair.

pub mod perturb;
pub mod pjrt;

use crate::error::BaechiError;
use crate::topology::Topology;
use crate::util::stats::linear_fit;
use std::borrow::Cow;

/// Static description of one device in the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Usable memory in bytes (possibly capped to a fraction, Table 5).
    pub memory: u64,
    /// Relative compute speed (1.0 = the profiling device).
    pub speed: f64,
}

/// Cluster description handed to placers and the ES.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<DeviceSpec>,
    /// Representative communication model: the fitted model for uniform
    /// clusters, a pair-averaged model under an explicit topology. Used
    /// where a single device-pair-agnostic cost is needed (the SCT LP,
    /// fused-edge pricing, ρ reporting); scheduling and simulation use
    /// the pairwise costs of [`Cluster::effective_topology`].
    pub comm: CommModel,
    /// If true, each interconnect link performs at most one transfer at
    /// a time and transfers queue up (paper §3.1.4 — the
    /// PCIe-through-host testbed; uniform topologies make this exactly
    /// the paper's per-device transfer engine).
    pub sequential_comm: bool,
    /// Interconnect description (uniform star by default). Kept private
    /// so it cannot drift out of sync with `devices`; mutate via
    /// [`Cluster::with_topology`].
    topology: Topology,
}

impl Cluster {
    /// Homogeneous cluster of `n` devices with `memory` bytes each.
    pub fn homogeneous(n: usize, memory: u64, comm: CommModel) -> Cluster {
        Cluster {
            devices: vec![DeviceSpec { memory, speed: 1.0 }; n],
            comm,
            sequential_comm: true,
            topology: Topology::uniform(n, comm),
        }
    }

    /// Attach an explicit interconnect topology. The topology must cover
    /// exactly this cluster's devices; declared speed factors (if any)
    /// are applied to the device specs and `comm` becomes the topology's
    /// representative model.
    pub fn with_topology(mut self, topology: Topology) -> crate::Result<Cluster> {
        if topology.n() != self.devices.len() {
            return Err(BaechiError::invalid(format!(
                "topology covers {} devices but the cluster has {}",
                topology.n(),
                self.devices.len()
            )));
        }
        if let Some(speeds) = topology.speeds() {
            for (d, &s) in self.devices.iter_mut().zip(speeds) {
                d.speed = s;
            }
        }
        self.comm = topology.representative();
        self.topology = topology;
        Ok(self)
    }

    /// The topology consulted by placement and simulation. Legacy code
    /// edits `devices` or `comm` in place; a uniform topology that no
    /// longer matches either is rebuilt from the current `comm` — so
    /// `cluster.comm = CommModel::nvlink_like()` keeps re-pricing every
    /// transfer exactly as before the topology subsystem. (An explicit
    /// non-uniform topology keeps its pairwise models; there `comm` is
    /// only the derived representative.)
    pub fn effective_topology(&self) -> Cow<'_, Topology> {
        let stale_n = self.topology.n() != self.devices.len();
        let stale_model = self
            .topology
            .uniform_model()
            .map_or(false, |m| m != self.comm);
        if stale_n || stale_model {
            Cow::Owned(Topology::uniform(self.devices.len(), self.comm))
        } else {
            Cow::Borrowed(&self.topology)
        }
    }

    /// The stored topology (may be stale after hand-editing `devices`;
    /// prefer [`Cluster::effective_topology`] for cost resolution).
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Cap every device's memory to `fraction` of its current value
    /// (the paper's "insufficient memory" regime, Table 5).
    pub fn with_memory_fraction(mut self, fraction: f64) -> Cluster {
        for d in &mut self.devices {
            d.memory = (d.memory as f64 * fraction) as u64;
        }
        self
    }

    pub fn with_sequential_comm(mut self, seq: bool) -> Cluster {
        self.sequential_comm = seq;
        self
    }

    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Total cluster memory, bytes.
    pub fn total_memory(&self) -> u64 {
        self.devices.iter().map(|d| d.memory).sum()
    }
}

/// Linear communication cost model `t(bytes) = latency + bytes / bandwidth`
/// (paper §4.1: "we use a linear model proportional to data size ...
/// generated a communication cost function through linear regression").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// Fixed per-transfer latency, seconds.
    pub latency: f64,
    /// Bandwidth, bytes per second.
    pub bandwidth: f64,
}

impl CommModel {
    /// Validated constructor: returns
    /// [`BaechiError::InvalidRequest`] for non-positive or non-finite
    /// bandwidth and negative or non-finite latency (malformed profile
    /// or topology specs must not panic).
    pub fn new(latency: f64, bandwidth: f64) -> crate::Result<CommModel> {
        if !bandwidth.is_finite() || bandwidth <= 0.0 {
            return Err(BaechiError::invalid(format!(
                "comm model: bandwidth must be positive and finite, got {bandwidth}"
            )));
        }
        if !latency.is_finite() || latency < 0.0 {
            return Err(BaechiError::invalid(format!(
                "comm model: latency must be non-negative and finite, got {latency}"
            )));
        }
        Ok(CommModel { latency, bandwidth })
    }

    /// The paper's testbed: GPUs on PCIe 3.0 x16 through host memory, no
    /// P2P — effective ~6 GB/s with high (~50 µs) per-transfer latency.
    /// (Paper §5.3 reports a 4-byte transfer costs 50–200 µs.)
    pub fn pcie_via_host() -> CommModel {
        CommModel {
            latency: 50e-6,
            bandwidth: 6e9,
        }
    }

    /// A fast NVLink-like interconnect (ablation; paper footnote 4).
    pub fn nvlink_like() -> CommModel {
        CommModel {
            latency: 5e-6,
            bandwidth: 50e9,
        }
    }

    /// Transfer time for a payload, seconds. Zero-byte transfers are free
    /// (no tensor moves).
    pub fn time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency + bytes as f64 / self.bandwidth
        }
    }

    /// Least-squares fit from `(bytes, seconds)` microbenchmark samples.
    /// Degenerate sample sets (fewer than 2 points, or all at one payload
    /// size) are a typed [`BaechiError::InvalidRequest`] — a calibration
    /// sweep that collapsed must not produce NaN cost models.
    pub fn fit(samples: &[(u64, f64)]) -> crate::Result<CommModel> {
        let xs: Vec<f64> = samples.iter().map(|&(b, _)| b as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|&(_, t)| t).collect();
        let (a, b, _r2) = linear_fit(&xs, &ys)?;
        Ok(CommModel {
            latency: a.max(0.0),
            bandwidth: if b > 0.0 { 1.0 / b } else { f64::INFINITY },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_model_linear() {
        let m = CommModel::new(1e-4, 1e9).unwrap();
        assert_eq!(m.time(0), 0.0);
        assert!((m.time(1_000_000) - (1e-4 + 1e-3)).abs() < 1e-12);
        assert!(m.time(2_000_000) > m.time(1_000_000));
    }

    #[test]
    fn comm_model_rejects_malformed() {
        for (lat, bw) in [
            (0.0, 0.0),
            (0.0, -1.0),
            (0.0, f64::NAN),
            (0.0, f64::INFINITY),
            (-1.0, 1e9),
            (f64::NAN, 1e9),
        ] {
            match CommModel::new(lat, bw) {
                Err(BaechiError::InvalidRequest(_)) => {}
                other => panic!("({lat}, {bw}): expected InvalidRequest, got {other:?}"),
            }
        }
    }

    #[test]
    fn fit_recovers_parameters() {
        let truth = CommModel::new(5e-5, 2e9).unwrap();
        let samples: Vec<(u64, f64)> = (1..20)
            .map(|i| {
                let b = i * 500_000;
                (b, truth.time(b))
            })
            .collect();
        let fitted = CommModel::fit(&samples).unwrap();
        assert!((fitted.latency - truth.latency).abs() / truth.latency < 0.01);
        assert!((fitted.bandwidth - truth.bandwidth).abs() / truth.bandwidth < 0.01);
    }

    #[test]
    fn fit_rejects_degenerate_sweeps() {
        // One sample, and many samples at one payload size: both leave
        // the linear model unidentifiable.
        for samples in [vec![(1024u64, 1e-3)], vec![(1024, 1e-3), (1024, 2e-3)]] {
            assert!(matches!(
                CommModel::fit(&samples),
                Err(BaechiError::InvalidRequest(_))
            ));
        }
    }

    #[test]
    fn cluster_memory_fraction() {
        let c = Cluster::homogeneous(4, 8_000_000_000, CommModel::pcie_via_host())
            .with_memory_fraction(0.3);
        assert_eq!(c.n(), 4);
        assert_eq!(c.devices[0].memory, 2_400_000_000);
        assert_eq!(c.total_memory(), 4 * 2_400_000_000);
    }

    #[test]
    fn homogeneous_carries_uniform_topology() {
        let comm = CommModel::pcie_via_host();
        let c = Cluster::homogeneous(4, 1000, comm);
        assert!(c.topology().is_uniform());
        assert_eq!(c.topology().uniform_model(), Some(comm));
        assert!(matches!(c.effective_topology(), Cow::Borrowed(_)));
    }

    #[test]
    fn with_topology_checks_device_count_and_applies_speeds() {
        let comm = CommModel::pcie_via_host();
        let t = Topology::uniform(2, comm).with_speeds(vec![1.0, 2.0]).unwrap();
        let c = Cluster::homogeneous(2, 1000, comm).with_topology(t).unwrap();
        assert_eq!(c.devices[1].speed, 2.0);
        assert_eq!(c.comm, comm, "uniform representative is the model itself");
        let t3 = Topology::uniform(3, comm);
        assert!(matches!(
            Cluster::homogeneous(2, 1000, comm).with_topology(t3),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn stale_topology_falls_back_to_uniform() {
        // Legacy tests push devices by hand; cost resolution must then
        // behave as a uniform cluster over `comm`.
        let mut c = Cluster::homogeneous(2, 1000, CommModel::pcie_via_host());
        c.devices.push(DeviceSpec {
            memory: 1000,
            speed: 1.0,
        });
        c.comm = CommModel::nvlink_like();
        let eff = c.effective_topology();
        assert_eq!(eff.n(), 3);
        assert_eq!(eff.uniform_model(), Some(CommModel::nvlink_like()));
        assert!(matches!(eff, Cow::Owned(_)));
    }

    #[test]
    fn mutated_comm_reprices_uniform_topology() {
        // The legacy ablation pattern: mutate `comm` in place on a
        // homogeneous cluster. The effective topology must follow.
        let mut c = Cluster::homogeneous(4, 1000, CommModel::pcie_via_host());
        c.comm = CommModel::nvlink_like();
        let eff = c.effective_topology();
        assert_eq!(eff.uniform_model(), Some(CommModel::nvlink_like()));
        assert_eq!(
            eff.time(0, 1, 1 << 20).to_bits(),
            CommModel::nvlink_like().time(1 << 20).to_bits()
        );
    }
}
