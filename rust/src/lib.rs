//! # Baechi — fast algorithmic device placement of ML graphs
//!
//! Rust + JAX + Pallas reproduction of *"Baechi: Fast Device Placement of
//! Machine Learning Graphs"* (Jeon et al., CS.DC 2023 / SoCC '20).
//!
//! The library is organized bottom-up:
//!
//! * [`util`] — in-repo substrates (RNG, JSON, CLI, stats, bench & property
//!   harnesses) that replace crates unavailable in the offline registry.
//! * [`graph`] — the annotated operator DAG that every stage consumes.
//! * [`models`] — synthetic profiled-graph generators matching the paper's
//!   benchmarks (Inception-V3, GNMT, Transformer) plus small real models.
//! * [`profile`] — device specs, communication cost model, perturbation.
//! * [`optimizer`] — colocation / co-placement / cycle-safe fusion /
//!   forward-only placement (paper §3.1).
//! * [`lp`] — dense interior-point LP solver + the SCT favorite-child LP.
//! * [`placer`] — m-TOPO, m-ETF, m-SCT (paper §2).
//! * [`sim`] — the event-driven Execution Simulator (paper §4.2).
//! * [`baselines`] — single-device, expert, and RL placers (paper §5).
//! * [`runtime`] — PJRT client + AOT HLO artifact registry.
//! * [`exec`] — real multi-device executor + trainer (end-to-end example).
//! * [`coordinator`] — the full profile→optimize→place→evaluate pipeline.
//!
//! See `DESIGN.md` for the per-experiment index and substitution notes.

pub mod baselines;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod lp;
pub mod models;
pub mod optimizer;
pub mod placer;
pub mod profile;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
