//! # Baechi — fast algorithmic device placement of ML graphs
//!
//! Rust + JAX + Pallas reproduction of *"Baechi: Fast Device Placement of
//! Machine Learning Graphs"* (Jeon et al., CS.DC 2023 / SoCC '20).
//!
//! ## Placement API
//!
//! Placement is served by a long-lived [`engine::PlacementEngine`]:
//! build one per target cluster, then issue typed
//! [`engine::PlacementRequest`] → [`engine::PlacementResponse`] calls.
//! Algorithms are looked up by name in a [`engine::PlacerRegistry`]
//! (the built-ins plus anything you register), repeated requests are
//! served from an internal placement cache, `place_batch` fans a slice
//! of requests across threads, and failures surface as the structured
//! [`BaechiError`] enum rather than strings:
//!
//! ```no_run
//! use baechi::engine::{PlacementEngine, PlacementRequest};
//! use baechi::models::Benchmark;
//! use baechi::profile::{Cluster, CommModel};
//!
//! let engine = PlacementEngine::builder()
//!     .cluster(Cluster::homogeneous(4, 8 << 30, CommModel::pcie_via_host()))
//!     .build()?;
//! let req = PlacementRequest::for_benchmark(Benchmark::Transformer { batch: 64 }, "m-sct");
//! let resp = engine.place(&req)?;
//! println!(
//!     "{} ops on {} devices in {:.1} ms",
//!     resp.placement.device_of.len(),
//!     resp.devices_used,
//!     resp.placement.placement_time * 1e3,
//! );
//! # Ok::<(), baechi::BaechiError>(())
//! ```
//!
//! The CLI, the [`coordinator`] pipeline, the examples, and the benches
//! all route through the engine; see `examples/quickstart.rs` for the
//! registry / cache / typed-error walkthrough and README.md for the
//! full API tour.
//!
//! ## Layers
//!
//! The library is organized bottom-up:
//!
//! * [`util`] — in-repo substrates (RNG, JSON, CLI, stats, bench & property
//!   harnesses) that replace crates unavailable in the offline registry.
//! * [`error`] — the [`BaechiError`] enum behind [`Result`].
//! * [`graph`] — the annotated operator DAG that every stage consumes.
//! * [`models`] — synthetic profiled-graph generators matching the paper's
//!   benchmarks (Inception-V3, GNMT, Transformer) plus small real models.
//! * [`profile`] — device specs, communication cost model, perturbation.
//! * [`topology`] — heterogeneous clusters: typed interconnect links
//!   (NVLink / PCIe / NIC), all-pairs effective comm costs, per-link
//!   contention queues, island partitions, JSON specs. Uniform
//!   topologies reproduce the paper's single-model cluster exactly.
//! * [`optimizer`] — colocation / co-placement / cycle-safe fusion /
//!   forward-only placement (paper §3.1).
//! * [`lp`] — dense interior-point LP solver + the SCT favorite-child LP.
//! * [`placer`] — m-TOPO, m-ETF, m-SCT (paper §2).
//! * [`hierarchy`] — million-op scaling: coarsen chains/co-placement
//!   groups into super-ops (cycle-safe contraction), place the coarse
//!   graph with m-SCT, then refine members within each super-op's
//!   device budget. Exposed as the `hier` placer; with coarsening
//!   disabled it is bit-identical to plain m-SCT.
//! * [`sim`] — the event-driven Execution Simulator (paper §4.2), which
//!   also emits a per-link [`sim::ContentionReport`].
//! * [`baselines`] — single-device, expert, and RL placers (paper §5).
//! * [`calibrate`] — learn the cluster model from measurements: probe
//!   sources (runtime host timings, or a synthetic ground-truth replay
//!   with seeded noise), a per-link least-squares fitter, the
//!   `CalibratedCluster` JSON artifact with a quality report, and the
//!   bridge from runtime link observations to measured
//!   `ContentionReport`s.
//! * [`feedback`] — contention feedback: turns a simulator report into
//!   per-link topology degradations and a re-placement policy, closing
//!   the sim → engine → placer loop.
//! * [`engine`] — the `PlacementEngine` service layer: placer registry,
//!   request/response sessions, the sharded bounded placement cache,
//!   stage observers, and the `place_iterative` contention-driven
//!   re-placement loop.
//! * [`serve`] — placement as a service: `PlacementService` (bounded
//!   queue, worker pool, deadlines, micro-batching), incremental delta
//!   placement over cone fingerprints, and `ServiceMetrics`.
//! * [`telemetry`] — end-to-end observability over the engine and the
//!   service: per-request trace IDs and pipeline spans (`Tracer`),
//!   Chrome/Perfetto trace-event export of spans and simulated
//!   schedules, and Prometheus text exposition with a minimal HTTP
//!   listener.
//! * [`explain`] — placement explainability: opt-in per-op decision
//!   records (candidate ESTs, memory deficits, chosen-device reason),
//!   critical-path attribution of the simulated makespan into
//!   compute / transfer / queue-wait / idle, and a size-bounded JSONL
//!   run-history flight recorder. Off by default; surfaced by
//!   `baechi explain`, Prometheus gauges, and Chrome-trace span args.
//! * [`runtime`] — PJRT client + AOT HLO artifact registry (stubbed
//!   offline; see `runtime::xla`).
//! * [`exec`] — real multi-device executor + trainer (end-to-end example).
//! * [`coordinator`] — the profile→optimize→place→evaluate pipeline, a
//!   thin wrapper over the engine.

pub mod baselines;
pub mod calibrate;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod exec;
pub mod explain;
pub mod feedback;
pub mod graph;
pub mod hierarchy;
pub mod lp;
pub mod models;
pub mod optimizer;
pub mod placer;
pub mod profile;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod topology;
pub mod util;

pub use error::BaechiError;

/// Crate-wide result alias over [`BaechiError`].
pub type Result<T, E = BaechiError> = std::result::Result<T, E>;
