//! Run-history flight recorder: append-only JSONL of placement runs.
//!
//! Every recorded run is one [`RunRecord`] line — graph + topology
//! feature vector, placer + coarsening spec, serve mode, simulated
//! makespan, and the critical-path category breakdown. The store is
//! the training substrate for the roadmap's learned placement scorer:
//! features in, observed makespan out.
//!
//! [`FlightRecorder`] keeps the file bounded: when an append would
//! push the live file past `max_bytes`, the file is rotated to
//! `<path>.1` (replacing any previous rotation) and a fresh file is
//! started. Stats (records, cumulative bytes, rotations) are plain
//! atomics, surfaced through [`crate::serve::ServiceMetrics`] and the
//! Prometheus exposition.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::graph::OpGraph;
use crate::util::json::Json;
use crate::BaechiError;

/// Schema version stamped on every line; bump on breaking changes.
pub const RUN_RECORD_SCHEMA: u64 = 1;

/// Default rotation bound (16 MiB of JSONL ≈ tens of thousands of
/// runs).
pub const DEFAULT_MAX_BYTES: u64 = 16 << 20;

/// Critical-path category totals carried in a record.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttributionTotals {
    pub compute: f64,
    pub transfer: f64,
    pub queue_wait: f64,
    pub idle: f64,
}

/// One placement run, one JSONL line.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub schema: u64,
    /// Graph name (benchmark or caller-supplied).
    pub graph: String,
    pub placer: String,
    /// Coarsening spec when the hierarchical path was requested.
    pub coarsening: Option<String>,
    /// How the request was served: `full`, `cache_hit`, `incremental`.
    pub serve_mode: String,
    // Graph + topology feature vector (the learned-scorer inputs).
    pub ops: u64,
    pub edges: u64,
    pub devices: u64,
    pub total_compute: f64,
    pub total_permanent_memory: u64,
    pub total_edge_bytes: u64,
    /// Simulated step time; `None` when simulation was skipped or hit
    /// OOM.
    pub makespan: Option<f64>,
    pub attribution: Option<AttributionTotals>,
}

impl RunRecord {
    /// Build a record from a graph about to be (or just) placed.
    pub fn from_graph(graph: &OpGraph, devices: usize, placer: &str, serve_mode: &str) -> RunRecord {
        RunRecord {
            schema: RUN_RECORD_SCHEMA,
            graph: graph.name.clone(),
            placer: placer.to_string(),
            coarsening: None,
            serve_mode: serve_mode.to_string(),
            ops: graph.len() as u64,
            edges: graph.edge_count() as u64,
            devices: devices as u64,
            total_compute: graph.total_compute(),
            total_permanent_memory: graph.total_permanent_memory(),
            total_edge_bytes: graph.edges().iter().map(|e| e.bytes).sum(),
            makespan: None,
            attribution: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", self.schema)
            .set("graph", self.graph.as_str())
            .set("placer", self.placer.as_str())
            .set("serve_mode", self.serve_mode.as_str())
            .set("ops", self.ops)
            .set("edges", self.edges)
            .set("devices", self.devices)
            .set("total_compute", self.total_compute)
            .set("total_permanent_memory", self.total_permanent_memory)
            .set("total_edge_bytes", self.total_edge_bytes);
        match &self.coarsening {
            Some(c) => j.set("coarsening", c.as_str()),
            None => j.set("coarsening", Json::Null),
        };
        match self.makespan {
            Some(m) => j.set("makespan", m),
            None => j.set("makespan", Json::Null),
        };
        match &self.attribution {
            Some(a) => {
                let mut o = Json::obj();
                o.set("compute", a.compute)
                    .set("transfer", a.transfer)
                    .set("queue_wait", a.queue_wait)
                    .set("idle", a.idle);
                j.set("attribution", o)
            }
            None => j.set("attribution", Json::Null),
        };
        j
    }

    pub fn from_json(j: &Json) -> crate::Result<RunRecord> {
        let field = |name: &str| {
            j.get(name)
                .ok_or_else(|| BaechiError::invalid(format!("run record missing '{name}'")))
        };
        let str_field = |name: &str| {
            field(name).and_then(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| BaechiError::invalid(format!("run record '{name}' not a string")))
            })
        };
        let num_field = |name: &str| {
            field(name).and_then(|v| {
                v.as_f64()
                    .ok_or_else(|| BaechiError::invalid(format!("run record '{name}' not a number")))
            })
        };
        let schema = num_field("schema")? as u64;
        if schema != RUN_RECORD_SCHEMA {
            return Err(BaechiError::invalid(format!(
                "run record schema {schema} (this build reads {RUN_RECORD_SCHEMA})"
            )));
        }
        let coarsening = match j.get("coarsening") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| BaechiError::invalid("run record 'coarsening' not a string"))?
                    .to_string(),
            ),
        };
        let makespan = match j.get("makespan") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| BaechiError::invalid("run record 'makespan' not a number"))?,
            ),
        };
        let attribution = match j.get("attribution") {
            None | Some(Json::Null) => None,
            Some(a) => {
                let get = |name: &str| {
                    a.get(name).and_then(Json::as_f64).ok_or_else(|| {
                        BaechiError::invalid(format!("run record attribution missing '{name}'"))
                    })
                };
                Some(AttributionTotals {
                    compute: get("compute")?,
                    transfer: get("transfer")?,
                    queue_wait: get("queue_wait")?,
                    idle: get("idle")?,
                })
            }
        };
        Ok(RunRecord {
            schema,
            graph: str_field("graph")?,
            placer: str_field("placer")?,
            coarsening,
            serve_mode: str_field("serve_mode")?,
            ops: num_field("ops")? as u64,
            edges: num_field("edges")? as u64,
            devices: num_field("devices")? as u64,
            total_compute: num_field("total_compute")?,
            total_permanent_memory: num_field("total_permanent_memory")? as u64,
            total_edge_bytes: num_field("total_edge_bytes")? as u64,
            makespan,
            attribution,
        })
    }

    /// One JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse_line(line: &str) -> crate::Result<RunRecord> {
        RunRecord::from_json(&Json::parse(line.trim())?)
    }
}

/// Point-in-time recorder counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RecorderStats {
    /// Records appended since open.
    pub records: u64,
    /// Cumulative bytes written (across rotations).
    pub bytes: u64,
    /// Times the live file was rotated to `<path>.1`.
    pub rotations: u64,
}

/// Size-bounded append-only JSONL store. Appends are serialized by an
/// internal mutex; stats reads are lock-free.
pub struct FlightRecorder {
    path: PathBuf,
    max_bytes: u64,
    /// Serializes append + rotate against each other.
    write_lock: Mutex<()>,
    /// Bytes currently in the live file (reset on rotation).
    file_bytes: AtomicU64,
    records: AtomicU64,
    bytes: AtomicU64,
    rotations: AtomicU64,
}

impl FlightRecorder {
    /// Open (creating or appending to) the store at `path`. A
    /// pre-existing file counts toward the rotation bound.
    pub fn open(path: impl Into<PathBuf>, max_bytes: u64) -> crate::Result<FlightRecorder> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .map_err(|e| BaechiError::io(format!("creating {}: {e}", parent.display())))?;
            }
        }
        let existing = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        Ok(FlightRecorder {
            path,
            max_bytes: max_bytes.max(1),
            write_lock: Mutex::new(()),
            file_bytes: AtomicU64::new(existing),
            records: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            rotations: AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record, rotating first if it would overflow the
    /// bound.
    pub fn append(&self, record: &RunRecord) -> crate::Result<()> {
        use std::io::Write;
        let mut line = record.to_line();
        line.push('\n');
        let guard = self
            .write_lock
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let current = self.file_bytes.load(Ordering::Relaxed);
        if current > 0 && current + line.len() as u64 > self.max_bytes {
            let rotated = self.rotated_path();
            std::fs::rename(&self.path, &rotated)
                .map_err(|e| BaechiError::io(format!("rotating {}: {e}", self.path.display())))?;
            self.file_bytes.store(0, Ordering::Relaxed);
            self.rotations.fetch_add(1, Ordering::Relaxed);
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .map_err(|e| BaechiError::io(format!("opening {}: {e}", self.path.display())))?;
        f.write_all(line.as_bytes())
            .map_err(|e| BaechiError::io(format!("appending {}: {e}", self.path.display())))?;
        drop(guard);
        self.file_bytes
            .fetch_add(line.len() as u64, Ordering::Relaxed);
        self.records.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(line.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Where rotated history goes (one generation kept).
    pub fn rotated_path(&self) -> PathBuf {
        let mut name = self
            .path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "run-history.jsonl".to_string());
        name.push_str(".1");
        self.path.with_file_name(name)
    }

    pub fn stats(&self) -> RecorderStats {
        RecorderStats {
            records: self.records.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            rotations: self.rotations.load(Ordering::Relaxed),
        }
    }

    /// Read every record in the live file (skips the rotated
    /// generation).
    pub fn read_all(path: &Path) -> crate::Result<Vec<RunRecord>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| BaechiError::io(format!("reading {}: {e}", path.display())))?;
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(RunRecord::parse_line)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "baechi-recorder-{tag}-{}-{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(serve_mode: &str, makespan: Option<f64>) -> RunRecord {
        RunRecord {
            schema: RUN_RECORD_SCHEMA,
            graph: "mlp".into(),
            placer: "m-sct".into(),
            coarsening: Some("members:8".into()),
            serve_mode: serve_mode.into(),
            ops: 42,
            edges: 63,
            devices: 4,
            total_compute: 0.125,
            total_permanent_memory: 1 << 20,
            total_edge_bytes: 4096,
            makespan,
            attribution: makespan.map(|m| AttributionTotals {
                compute: m * 0.5,
                transfer: m * 0.25,
                queue_wait: m * 0.125,
                idle: m * 0.125,
            }),
        }
    }

    #[test]
    fn jsonl_round_trip() {
        for rec in [sample("full", Some(0.25)), sample("cache_hit", None)] {
            let back = RunRecord::parse_line(&rec.to_line()).unwrap();
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn rejects_future_schema_and_garbage() {
        let mut j = sample("full", None).to_json();
        j.set("schema", 99u64);
        assert!(RunRecord::from_json(&j).is_err());
        assert!(RunRecord::parse_line("not json").is_err());
        assert!(RunRecord::parse_line("{}").is_err());
    }

    #[test]
    fn append_and_read_back() {
        let dir = temp_dir("append");
        let path = dir.join("runs.jsonl");
        let rec = FlightRecorder::open(&path, DEFAULT_MAX_BYTES).unwrap();
        rec.append(&sample("full", Some(1.5))).unwrap();
        rec.append(&sample("incremental", None)).unwrap();
        let got = FlightRecorder::read_all(&path).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].serve_mode, "full");
        assert_eq!(got[1].serve_mode, "incremental");
        let stats = rec.stats();
        assert_eq!(stats.records, 2);
        assert!(stats.bytes > 0);
        assert_eq!(stats.rotations, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rotation_bounds_the_live_file() {
        let dir = temp_dir("rotate");
        let path = dir.join("runs.jsonl");
        let line_len = sample("full", Some(1.0)).to_line().len() as u64 + 1;
        // Room for two lines per generation.
        let rec = FlightRecorder::open(&path, line_len * 2).unwrap();
        for _ in 0..5 {
            rec.append(&sample("full", Some(1.0))).unwrap();
        }
        let stats = rec.stats();
        assert_eq!(stats.records, 5);
        assert!(stats.rotations >= 1, "{stats:?}");
        assert!(std::fs::metadata(&path).unwrap().len() <= line_len * 2);
        assert!(rec.rotated_path().exists());
        // Every surviving line still parses.
        for p in [path.clone(), rec.rotated_path()] {
            for r in FlightRecorder::read_all(&p).unwrap() {
                assert_eq!(r.schema, RUN_RECORD_SCHEMA);
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn preexisting_bytes_count_toward_rotation() {
        let dir = temp_dir("preexist");
        let path = dir.join("runs.jsonl");
        std::fs::write(&path, "x".repeat(128)).unwrap();
        let rec = FlightRecorder::open(&path, 129).unwrap();
        rec.append(&sample("full", None)).unwrap();
        assert_eq!(rec.stats().rotations, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
