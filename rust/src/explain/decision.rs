//! Per-op placement decision records.
//!
//! Placers explain each commit by calling [`record`] with a
//! [`Decision`]: the op, every candidate device's EST split into its
//! data-ready (comm) and device-free (queue) components, the memory
//! deficit of each disqualified device, and a [`DecisionReason`] for
//! the winner. Collection is scoped: [`record_decisions`] installs a
//! thread-local sink and bumps a global active-scope counter;
//! [`DecisionScope::finish`] tears both down and returns the
//! [`DecisionLog`].
//!
//! **Hot-path contract:** with no scope active anywhere, [`is_live`]
//! is a single relaxed atomic load returning `false`, and placers do no
//! other explain work. The `Placer` trait signature is unchanged — the
//! sink rides the thread running the placement (engine placements run
//! on the caller's thread). A thread that observes `is_live()` without
//! a local sink (another caller's scope) records nothing; responses are
//! unaffected either way.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::graph::NodeId;
use crate::util::json::Json;

/// Number of [`DecisionScope`]s currently open across all threads.
static ACTIVE_SCOPES: AtomicUsize = AtomicUsize::new(0);

/// Process-lifetime count of decisions recorded (Prometheus
/// `baechi_explain_decisions_total`).
static DECISIONS_TOTAL: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static SINK: RefCell<Option<DecisionLog>> = const { RefCell::new(None) };
}

/// One relaxed load; `false` means every explain hook is skipped.
#[inline]
pub fn is_live() -> bool {
    ACTIVE_SCOPES.load(Ordering::Relaxed) != 0
}

/// Total decisions recorded since process start.
pub fn decisions_recorded() -> u64 {
    DECISIONS_TOTAL.load(Ordering::Relaxed)
}

/// Why a placer chose the device it chose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionReason {
    /// Plain earliest-start-time winner (m-ETF, refine interior ops).
    MinEst,
    /// m-SCT favorite-child preference overrode/confirmed the pick.
    SctFavoriteChild,
    /// Pinned by a colocation group or a coarsening boundary
    /// (hierarchy refine keeps the super-op's device).
    CoarsenPin,
    /// The preferred device did not fit; fell back to one that did.
    OomFallback,
}

impl DecisionReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            DecisionReason::MinEst => "min-est",
            DecisionReason::SctFavoriteChild => "sct-favorite-child",
            DecisionReason::CoarsenPin => "coarsen-pin",
            DecisionReason::OomFallback => "oom-fallback",
        }
    }
}

/// One device's bid for an op.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub device: usize,
    /// Earliest start time on this device; `None` when memory
    /// disqualified it.
    pub est: Option<f64>,
    /// When the op's inputs arrive on this device (the comm component
    /// of the EST).
    pub data_ready: f64,
    /// When this device's compute queue frees up (the queue component).
    pub device_free: f64,
    /// Bytes this device fell short by (0 when it fits).
    pub memory_deficit: u64,
}

impl Candidate {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("device", self.device)
            .set("data_ready", self.data_ready)
            .set("device_free", self.device_free)
            .set("memory_deficit", self.memory_deficit);
        match self.est {
            Some(e) => j.set("est", e),
            None => j.set("est", Json::Null),
        };
        j
    }
}

/// One committed op with every bid that was on the table.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub node: NodeId,
    pub name: String,
    pub chosen: usize,
    pub reason: DecisionReason,
    pub candidates: Vec<Candidate>,
}

impl Decision {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("node", self.node.0)
            .set("name", self.name.as_str())
            .set("chosen", self.chosen)
            .set("reason", self.reason.as_str())
            .set(
                "candidates",
                Json::Arr(self.candidates.iter().map(|c| c.to_json()).collect()),
            );
        j
    }
}

/// Everything one scope collected.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionLog {
    pub decisions: Vec<Decision>,
    /// Free-form pipeline notes (e.g. "hier: coarse placement OOM,
    /// falling back to flat m-SCT").
    pub notes: Vec<String>,
}

impl DecisionLog {
    /// The decision for a specific op, if it was placed in this scope.
    pub fn for_node(&self, node: NodeId) -> Option<&Decision> {
        // Last write wins: re-placement rounds may commit an op twice.
        self.decisions.iter().rev().find(|d| d.node == node)
    }

    /// Decision counts keyed by reason, in `DecisionReason` order.
    pub fn counts_by_reason(&self) -> [(DecisionReason, usize); 4] {
        let mut counts = [
            (DecisionReason::MinEst, 0),
            (DecisionReason::SctFavoriteChild, 0),
            (DecisionReason::CoarsenPin, 0),
            (DecisionReason::OomFallback, 0),
        ];
        for d in &self.decisions {
            for c in counts.iter_mut() {
                if c.0 == d.reason {
                    c.1 += 1;
                }
            }
        }
        counts
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "decisions",
            Json::Arr(self.decisions.iter().map(|d| d.to_json()).collect()),
        )
        .set(
            "notes",
            Json::Arr(self.notes.iter().map(|n| Json::from(n.as_str())).collect()),
        );
        j
    }
}

/// RAII handle for one recording scope on the current thread.
///
/// Scopes do not nest on a thread: opening a second one replaces the
/// first sink (the earlier scope then finishes empty). In practice one
/// scope wraps one `engine.place` call.
#[must_use = "finish() returns the collected DecisionLog"]
pub struct DecisionScope {
    _private: (),
}

/// Start collecting decisions on this thread.
pub fn record_decisions() -> DecisionScope {
    ACTIVE_SCOPES.fetch_add(1, Ordering::Relaxed);
    SINK.with(|s| *s.borrow_mut() = Some(DecisionLog::default()));
    DecisionScope { _private: () }
}

impl DecisionScope {
    /// Stop collecting and return what was recorded.
    pub fn finish(self) -> DecisionLog {
        SINK.with(|s| s.borrow_mut().take()).unwrap_or_default()
        // Drop decrements ACTIVE_SCOPES.
    }
}

impl Drop for DecisionScope {
    fn drop(&mut self) {
        ACTIVE_SCOPES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Append a decision to this thread's sink, if one is installed.
/// Callers gate on [`is_live`] first so the off path stays one load.
pub fn record(decision: Decision) {
    SINK.with(|s| {
        if let Some(log) = s.borrow_mut().as_mut() {
            DECISIONS_TOTAL.fetch_add(1, Ordering::Relaxed);
            log.decisions.push(decision);
        }
    });
}

/// Append a free-form note to this thread's sink, if one is installed.
pub fn note(msg: impl Into<String>) {
    SINK.with(|s| {
        if let Some(log) = s.borrow_mut().as_mut() {
            log.notes.push(msg.into());
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decision(node: usize, chosen: usize, reason: DecisionReason) -> Decision {
        Decision {
            node: NodeId(node),
            name: format!("op{node}"),
            chosen,
            reason,
            candidates: vec![
                Candidate {
                    device: 0,
                    est: Some(1.5),
                    data_ready: 1.5,
                    device_free: 1.0,
                    memory_deficit: 0,
                },
                Candidate {
                    device: 1,
                    est: None,
                    data_ready: 0.5,
                    device_free: 0.0,
                    memory_deficit: 64,
                },
            ],
        }
    }

    #[test]
    fn off_by_default_and_scope_toggles() {
        assert!(!is_live());
        record(decision(0, 0, DecisionReason::MinEst)); // no sink: dropped
        let scope = record_decisions();
        assert!(is_live());
        record(decision(1, 0, DecisionReason::MinEst));
        note("hello");
        let log = scope.finish();
        assert!(!is_live());
        assert_eq!(log.decisions.len(), 1);
        assert_eq!(log.notes, vec!["hello".to_string()]);
        assert_eq!(log.for_node(NodeId(1)).unwrap().chosen, 0);
        assert!(log.for_node(NodeId(0)).is_none());
    }

    #[test]
    fn counts_by_reason_and_last_write_wins() {
        let scope = record_decisions();
        record(decision(3, 0, DecisionReason::MinEst));
        record(decision(4, 1, DecisionReason::SctFavoriteChild));
        record(decision(3, 1, DecisionReason::OomFallback));
        let log = scope.finish();
        let counts = log.counts_by_reason();
        assert_eq!(counts[0], (DecisionReason::MinEst, 1));
        assert_eq!(counts[1], (DecisionReason::SctFavoriteChild, 1));
        assert_eq!(counts[3], (DecisionReason::OomFallback, 1));
        // Re-placement of node 3: the later decision is the answer.
        assert_eq!(log.for_node(NodeId(3)).unwrap().chosen, 1);
    }

    #[test]
    fn json_shape() {
        let scope = record_decisions();
        record(decision(2, 0, DecisionReason::CoarsenPin));
        let log = scope.finish();
        let j = log.to_json();
        let d = &j.get("decisions").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("reason").unwrap().as_str(), Some("coarsen-pin"));
        let cands = d.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[1].get("est"), Some(&Json::Null));
        assert_eq!(cands[1].get("memory_deficit").unwrap().as_u64(), Some(64));
    }

    #[test]
    fn decisions_counter_is_monotonic() {
        let before = decisions_recorded();
        let scope = record_decisions();
        record(decision(7, 0, DecisionReason::MinEst));
        let _ = scope.finish();
        assert!(decisions_recorded() >= before + 1);
    }
}
