//! Critical-path attribution of a simulated step.
//!
//! Walks the simulator's [`SimSchedule`] backward from the element
//! that ends at the makespan, at each step asking *what kept this from
//! starting earlier*: a dependency (a predecessor's compute, or the
//! transfer that delivered its tensor), or an occupancy blocker (an
//! unrelated op holding the device, an unrelated transfer holding a
//! link). The walk telescopes, so every second of the makespan lands
//! in exactly one of four categories:
//!
//! - **compute** — dependency/root op execution on the path,
//! - **transfer** — dependency tensor movement on the path,
//! - **queue-wait** — durations of blocking elements the critical
//!   chain sat behind,
//! - **idle** — gaps where nothing in the schedule explains the wait
//!   (scheduler slack), plus the stretch before the first element.
//!
//! The four totals sum to the makespan within 1e-9 (Kahan-compensated;
//! property-tested in `tests/explain.rs`). The per-device and per-link
//! breakdowns cover path elements only — a transfer's duration is
//! booked against *every* link it rides, so link blame intentionally
//! overlaps and only the category totals satisfy the sum invariant.

use std::collections::{BTreeMap, HashSet};

use crate::graph::{NodeId, OpGraph};
use crate::sim::SimSchedule;
use crate::util::json::Json;

/// Where a second of makespan went.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlameCategory {
    Compute,
    Transfer,
    QueueWait,
    Idle,
}

impl BlameCategory {
    pub fn as_str(&self) -> &'static str {
        match self {
            BlameCategory::Compute => "compute",
            BlameCategory::Transfer => "transfer",
            BlameCategory::QueueWait => "queue_wait",
            BlameCategory::Idle => "idle",
        }
    }
}

/// A schedule element on the critical path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PathElem {
    /// Index into [`SimSchedule::ops`].
    Op(usize),
    /// Index into [`SimSchedule::transfers`].
    Transfer(usize),
}

/// One step of the backward walk, in chronological order.
#[derive(Debug, Clone, PartialEq)]
pub struct PathStep {
    pub elem: PathElem,
    /// How the element's own duration was booked (`Compute`,
    /// `Transfer`, or `QueueWait`; never `Idle`).
    pub category: BlameCategory,
    pub start: f64,
    pub end: f64,
    /// Unexplained gap booked as idle immediately before this step.
    pub gap_before: f64,
}

/// Per-device share of the path (ops only).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceBlame {
    pub device: usize,
    pub compute: f64,
    pub queue_wait: f64,
    pub idle: f64,
}

/// Per-link share of the path (transfers only; overlapping by design).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LinkBlame {
    pub link: usize,
    pub transfer: f64,
    pub queue_wait: f64,
}

/// A compute op on the critical path, heaviest first.
#[derive(Debug, Clone, PartialEq)]
pub struct TopOp {
    pub node: NodeId,
    pub name: String,
    pub device: usize,
    pub seconds: f64,
    pub start: f64,
    pub end: f64,
}

/// The full blame summary for one simulated step.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attribution {
    pub makespan: f64,
    pub compute: f64,
    pub transfer: f64,
    pub queue_wait: f64,
    pub idle: f64,
    /// Critical path, earliest element first.
    pub path: Vec<PathStep>,
    pub per_device: Vec<DeviceBlame>,
    pub per_link: Vec<LinkBlame>,
    /// Compute ops on the path, sorted by duration descending.
    pub top_ops: Vec<TopOp>,
}

impl Attribution {
    /// `compute + transfer + queue_wait + idle - makespan` (the
    /// invariant bounds its magnitude by `1e-9 · max(1, makespan)`).
    pub fn residual(&self) -> f64 {
        (self.compute + self.transfer + self.queue_wait + self.idle) - self.makespan
    }

    /// Fraction of the makespan booked to `cat` (0 when makespan is 0).
    pub fn fraction(&self, cat: BlameCategory) -> f64 {
        let total = match cat {
            BlameCategory::Compute => self.compute,
            BlameCategory::Transfer => self.transfer,
            BlameCategory::QueueWait => self.queue_wait,
            BlameCategory::Idle => self.idle,
        };
        if self.makespan > 0.0 {
            total / self.makespan
        } else {
            0.0
        }
    }

    /// Schedule-op indices on the path with their booked category
    /// (feeds the Chrome-trace `crit` span args).
    pub fn crit_ops(&self) -> BTreeMap<usize, BlameCategory> {
        self.path
            .iter()
            .filter_map(|s| match s.elem {
                PathElem::Op(i) => Some((i, s.category)),
                PathElem::Transfer(_) => None,
            })
            .collect()
    }

    /// Schedule-transfer indices on the path with their booked category.
    pub fn crit_transfers(&self) -> BTreeMap<usize, BlameCategory> {
        self.path
            .iter()
            .filter_map(|s| match s.elem {
                PathElem::Transfer(i) => Some((i, s.category)),
                PathElem::Op(_) => None,
            })
            .collect()
    }

    pub fn to_json(&self, schedule: &SimSchedule, top_k: usize) -> Json {
        let mut j = Json::obj();
        j.set("makespan", self.makespan)
            .set("compute", self.compute)
            .set("transfer", self.transfer)
            .set("queue_wait", self.queue_wait)
            .set("idle", self.idle)
            .set("residual", self.residual());
        let mut fractions = Json::obj();
        for cat in [
            BlameCategory::Compute,
            BlameCategory::Transfer,
            BlameCategory::QueueWait,
            BlameCategory::Idle,
        ] {
            fractions.set(cat.as_str(), self.fraction(cat));
        }
        j.set("fractions", fractions);
        j.set(
            "per_device",
            Json::Arr(
                self.per_device
                    .iter()
                    .map(|d| {
                        let mut o = Json::obj();
                        o.set("device", d.device)
                            .set("compute", d.compute)
                            .set("queue_wait", d.queue_wait)
                            .set("idle", d.idle);
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "per_link",
            Json::Arr(
                self.per_link
                    .iter()
                    .map(|l| {
                        let mut o = Json::obj();
                        o.set("link", l.link)
                            .set("transfer", l.transfer)
                            .set("queue_wait", l.queue_wait);
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "top_ops",
            Json::Arr(
                self.top_ops
                    .iter()
                    .take(top_k)
                    .map(|t| {
                        let mut o = Json::obj();
                        o.set("node", t.node.0)
                            .set("name", t.name.as_str())
                            .set("device", t.device)
                            .set("seconds", t.seconds)
                            .set("start", t.start)
                            .set("end", t.end);
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "path",
            Json::Arr(
                self.path
                    .iter()
                    .map(|s| {
                        let mut o = Json::obj();
                        match s.elem {
                            PathElem::Op(i) => {
                                let sp = &schedule.ops[i];
                                o.set("kind", "op")
                                    .set("node", sp.node.0)
                                    .set("device", sp.device);
                            }
                            PathElem::Transfer(i) => {
                                let sp = &schedule.transfers[i];
                                o.set("kind", "transfer")
                                    .set("node", sp.node.0)
                                    .set("src", sp.src)
                                    .set("dst", sp.dst)
                                    .set("bytes", sp.bytes);
                            }
                        }
                        o.set("category", s.category.as_str())
                            .set("start", s.start)
                            .set("end", s.end)
                            .set("gap_before", s.gap_before);
                        o
                    })
                    .collect(),
            ),
        );
        j
    }
}

/// Kahan-compensated accumulator: keeps the four category sums exact
/// enough that the telescoped total meets the 1e-9 invariant even on
/// million-element paths.
#[derive(Default, Clone, Copy)]
struct Kahan {
    sum: f64,
    c: f64,
}

impl Kahan {
    fn add(&mut self, x: f64) {
        let y = x - self.c;
        let t = self.sum + y;
        self.c = (t - self.sum) - y;
        self.sum = t;
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Cause {
    Dependency(PathElem),
    Blocker(PathElem),
}

fn elem_key(e: PathElem) -> (u8, usize) {
    match e {
        PathElem::Op(i) => (0, i),
        PathElem::Transfer(i) => (1, i),
    }
}

/// Attribute `makespan` over `schedule`. `graph` supplies the
/// dependency structure (which earlier elements an op was actually
/// waiting for, as opposed to merely queued behind).
pub fn attribute(graph: &OpGraph, schedule: &SimSchedule, makespan: f64) -> Attribution {
    let mut out = Attribution {
        makespan,
        ..Default::default()
    };
    let eps = 1e-9 * makespan.abs().max(1.0);

    // Indexes: node → its op span, (producer, dst) → delivering
    // transfer, per-device op lists and per-link transfer lists for
    // blocker lookups. Later spans win so re-executed elements resolve
    // to their final interval.
    let mut op_of_node: BTreeMap<usize, usize> = BTreeMap::new();
    let mut ops_by_device: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, sp) in schedule.ops.iter().enumerate() {
        op_of_node.insert(sp.node.0, i);
        ops_by_device.entry(sp.device).or_default().push(i);
    }
    let mut xfer_to: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    let mut xfers_by_link: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, sp) in schedule.transfers.iter().enumerate() {
        xfer_to.insert((sp.node.0, sp.dst), i);
        for &l in &sp.links {
            xfers_by_link.entry(l).or_default().push(i);
        }
    }
    // Blocker lookups binary-search these lists, so sort by end time
    // (recording order is already close for ops, not guaranteed for
    // flow-mode transfers).
    for list in ops_by_device.values_mut() {
        list.sort_by(|&a, &b| {
            schedule.ops[a]
                .end
                .partial_cmp(&schedule.ops[b].end)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    }
    for list in xfers_by_link.values_mut() {
        list.sort_by(|&a, &b| {
            schedule.transfers[a]
                .end
                .partial_cmp(&schedule.transfers[b].end)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
    }

    let interval = |e: PathElem| -> (f64, f64) {
        match e {
            PathElem::Op(i) => (schedule.ops[i].start, schedule.ops[i].end),
            PathElem::Transfer(i) => (schedule.transfers[i].start, schedule.transfers[i].end),
        }
    };

    // Root: the element whose end is the makespan.
    let mut root: Option<PathElem> = None;
    let mut best_end = f64::NEG_INFINITY;
    for (i, sp) in schedule.ops.iter().enumerate() {
        if sp.end > best_end {
            best_end = sp.end;
            root = Some(PathElem::Op(i));
        }
    }
    for (i, sp) in schedule.transfers.iter().enumerate() {
        if sp.end > best_end {
            best_end = sp.end;
            root = Some(PathElem::Transfer(i));
        }
    }

    let mut compute = Kahan::default();
    let mut transfer = Kahan::default();
    let mut queue_wait = Kahan::default();
    let mut idle = Kahan::default();
    let mut dev_blame: BTreeMap<usize, DeviceBlame> = BTreeMap::new();
    let mut link_blame: BTreeMap<usize, LinkBlame> = BTreeMap::new();
    let mut steps_rev: Vec<PathStep> = Vec::new();

    let mut cur = root;
    // An OOM-truncated (or empty) schedule ends short of the makespan;
    // book the unexplained tail as idle so the invariant still holds.
    idle.add(makespan - best_end.max(0.0));

    // `true` while the current element is a dependency/root (its
    // duration is real work); `false` while it is a blocker we were
    // queued behind.
    let mut on_dependency = true;
    let mut visited: HashSet<(u8, usize)> = HashSet::new();
    let budget = schedule.ops.len() + schedule.transfers.len() + 1;

    while let Some(e) = cur {
        if steps_rev.len() > budget || visited.contains(&elem_key(e)) {
            // Defensive: a malformed schedule (overlapping zero-width
            // spans) could otherwise cycle. Close the walk as if the
            // element had no cause.
            let (start, _) = interval(e);
            idle.add(start);
            break;
        }
        visited.insert(elem_key(e));
        let (start, end) = interval(e);
        let dur = end - start;
        let category = match (on_dependency, e) {
            (true, PathElem::Op(_)) => BlameCategory::Compute,
            (true, PathElem::Transfer(_)) => BlameCategory::Transfer,
            (false, _) => BlameCategory::QueueWait,
        };
        match category {
            BlameCategory::Compute => compute.add(dur),
            BlameCategory::Transfer => transfer.add(dur),
            BlameCategory::QueueWait => queue_wait.add(dur),
            BlameCategory::Idle => unreachable!(),
        }
        match e {
            PathElem::Op(i) => {
                let d = dev_blame.entry(schedule.ops[i].device).or_default();
                d.device = schedule.ops[i].device;
                if category == BlameCategory::QueueWait {
                    d.queue_wait += dur;
                } else {
                    d.compute += dur;
                }
            }
            PathElem::Transfer(i) => {
                for &l in &schedule.transfers[i].links {
                    let lb = link_blame.entry(l).or_default();
                    lb.link = l;
                    if category == BlameCategory::QueueWait {
                        lb.queue_wait += dur;
                    } else {
                        lb.transfer += dur;
                    }
                }
            }
        }

        // What kept this element from starting earlier? Take the
        // latest-ending candidate; on a tie a dependency beats a
        // blocker (more informative).
        let mut cause: Option<Cause> = None;
        let mut cause_end = f64::NEG_INFINITY;
        let mut consider = |c: Cause, c_end: f64| {
            let better = c_end > cause_end + eps
                || (c_end > cause_end - eps && matches!(c, Cause::Dependency(_)));
            if c_end <= start + eps && better {
                cause = Some(c);
                cause_end = c_end;
            }
        };
        match e {
            PathElem::Op(i) => {
                let sp = &schedule.ops[i];
                for &(p, _) in graph.predecessors(sp.node) {
                    if let Some(&pi) = op_of_node.get(&p.0) {
                        if schedule.ops[pi].device == sp.device {
                            consider(Cause::Dependency(PathElem::Op(pi)), schedule.ops[pi].end);
                        }
                    }
                    if let Some(&ti) = xfer_to.get(&(p.0, sp.device)) {
                        consider(
                            Cause::Dependency(PathElem::Transfer(ti)),
                            schedule.transfers[ti].end,
                        );
                    }
                }
                if let Some(peers) = ops_by_device.get(&sp.device) {
                    let k = peers.partition_point(|&oi| schedule.ops[oi].end <= start + eps);
                    // The latest-ending peer that isn't this op (the
                    // last equal-end slot may be the op itself).
                    for &oi in peers[..k].iter().rev() {
                        if oi != i {
                            consider(Cause::Blocker(PathElem::Op(oi)), schedule.ops[oi].end);
                            break;
                        }
                    }
                }
            }
            PathElem::Transfer(i) => {
                let sp = &schedule.transfers[i];
                if let Some(&pi) = op_of_node.get(&sp.node.0) {
                    consider(Cause::Dependency(PathElem::Op(pi)), schedule.ops[pi].end);
                }
                for &l in &sp.links {
                    if let Some(peers) = xfers_by_link.get(&l) {
                        let k = peers
                            .partition_point(|&ti| schedule.transfers[ti].end <= start + eps);
                        for &ti in peers[..k].iter().rev() {
                            if ti != i {
                                consider(
                                    Cause::Blocker(PathElem::Transfer(ti)),
                                    schedule.transfers[ti].end,
                                );
                                break;
                            }
                        }
                    }
                }
            }
        }

        let gap_before = match cause {
            Some(_) => start - cause_end,
            None => start, // back at the beginning of time
        };
        idle.add(gap_before);
        steps_rev.push(PathStep {
            elem: e,
            category,
            start,
            end,
            gap_before,
        });
        match cause {
            Some(Cause::Dependency(next)) => {
                on_dependency = true;
                cur = Some(next);
            }
            Some(Cause::Blocker(next)) => {
                on_dependency = false;
                cur = Some(next);
            }
            None => cur = None,
        }
    }

    steps_rev.reverse();
    // Idle gaps belong to whatever the *later* element was waiting on.
    for s in &steps_rev {
        if let PathElem::Op(i) = s.elem {
            if s.gap_before > 0.0 {
                let d = dev_blame.entry(schedule.ops[i].device).or_default();
                d.device = schedule.ops[i].device;
                d.idle += s.gap_before;
            }
        }
    }

    out.compute = compute.sum;
    out.transfer = transfer.sum;
    out.queue_wait = queue_wait.sum;
    out.idle = idle.sum;
    out.per_device = dev_blame.into_values().collect();
    out.per_link = link_blame.into_values().collect();
    out.top_ops = steps_rev
        .iter()
        .filter_map(|s| match (s.elem, s.category) {
            (PathElem::Op(i), BlameCategory::Compute) => {
                let sp = &schedule.ops[i];
                Some(TopOp {
                    node: sp.node,
                    name: graph.node(sp.node).name.clone(),
                    device: sp.device,
                    seconds: sp.end - sp.start,
                    start: sp.start,
                    end: sp.end,
                })
            }
            _ => None,
        })
        .collect();
    out.top_ops.sort_by(|a, b| {
        b.seconds
            .partial_cmp(&a.seconds)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.node.0.cmp(&b.node.0))
    });
    out.path = steps_rev;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::sim::{OpSpan, TransferSpan};

    fn graph(edges: &[(usize, usize)], n: usize) -> OpGraph {
        let mut g = OpGraph::new("attr-test");
        let ids: Vec<NodeId> = (0..n)
            .map(|i| g.add_node(&format!("op{i}"), OpKind::Elementwise))
            .collect();
        for &(a, b) in edges {
            g.add_edge(ids[a], ids[b], 64);
        }
        g
    }

    fn op(node: usize, device: usize, start: f64, end: f64) -> OpSpan {
        OpSpan {
            node: NodeId(node),
            device,
            start,
            end,
        }
    }

    #[test]
    fn single_device_chain_is_all_compute() {
        let g = graph(&[(0, 1)], 2);
        let sched = SimSchedule {
            ops: vec![op(0, 0, 0.0, 2.0), op(1, 0, 2.0, 5.0)],
            transfers: vec![],
        };
        let a = attribute(&g, &sched, 5.0);
        assert_eq!(a.compute, 5.0);
        assert_eq!(a.transfer, 0.0);
        assert_eq!(a.queue_wait, 0.0);
        assert_eq!(a.idle, 0.0);
        assert!(a.residual().abs() <= 1e-9);
        assert_eq!(a.path.len(), 2);
        assert_eq!(a.top_ops[0].node, NodeId(1));
        assert_eq!(a.per_device.len(), 1);
        assert_eq!(a.per_device[0].compute, 5.0);
    }

    #[test]
    fn cross_device_transfer_is_booked() {
        let g = graph(&[(0, 1)], 2);
        let sched = SimSchedule {
            ops: vec![op(0, 0, 0.0, 2.0), op(1, 1, 3.0, 6.0)],
            transfers: vec![TransferSpan {
                node: NodeId(0),
                src: 0,
                dst: 1,
                bytes: 64,
                links: vec![4],
                start: 2.0,
                end: 3.0,
            }],
        };
        let a = attribute(&g, &sched, 6.0);
        assert_eq!(a.compute, 5.0);
        assert_eq!(a.transfer, 1.0);
        assert_eq!(a.queue_wait, 0.0);
        assert_eq!(a.idle, 0.0);
        assert!(a.residual().abs() <= 1e-9);
        assert_eq!(a.per_link.len(), 1);
        assert_eq!(a.per_link[0].link, 4);
        assert_eq!(a.per_link[0].transfer, 1.0);
        assert_eq!(a.crit_transfers().len(), 1);
    }

    #[test]
    fn occupancy_blocker_books_queue_wait() {
        // dev0: op0 [0,1] (pred of op2), op1 [1,4] (unrelated),
        // op2 [4,6]. op2's data was ready at 1; it queued behind op1.
        let g = graph(&[(0, 2)], 3);
        let sched = SimSchedule {
            ops: vec![op(0, 0, 0.0, 1.0), op(1, 0, 1.0, 4.0), op(2, 0, 4.0, 6.0)],
            transfers: vec![],
        };
        let a = attribute(&g, &sched, 6.0);
        // op2 is compute; op1 is a blocker (queue wait); op0 blocks op1
        // in turn (the device was simply busy end-to-end).
        assert_eq!(a.compute, 2.0);
        assert_eq!(a.queue_wait, 4.0);
        assert_eq!(a.idle, 0.0);
        assert!(a.residual().abs() <= 1e-9);
        assert_eq!(a.per_device[0].queue_wait, 4.0);
    }

    #[test]
    fn unexplained_gap_books_idle() {
        let g = graph(&[(0, 1)], 2);
        let sched = SimSchedule {
            ops: vec![op(0, 0, 0.0, 1.0), op(1, 0, 3.0, 5.0)],
            transfers: vec![],
        };
        let a = attribute(&g, &sched, 5.0);
        assert_eq!(a.compute, 3.0);
        assert_eq!(a.idle, 2.0);
        assert!(a.residual().abs() <= 1e-9);
        // The gap belongs to op1's device.
        assert_eq!(a.per_device[0].idle, 2.0);
    }

    #[test]
    fn empty_schedule_is_all_idle() {
        let g = graph(&[], 1);
        let a = attribute(&g, &SimSchedule::default(), 3.0);
        assert_eq!(a.idle, 3.0);
        assert!(a.residual().abs() <= 1e-9);
        assert!(a.path.is_empty());
    }

    #[test]
    fn fractions_and_json_shape() {
        let g = graph(&[(0, 1)], 2);
        let sched = SimSchedule {
            ops: vec![op(0, 0, 0.0, 2.0), op(1, 0, 2.0, 4.0)],
            transfers: vec![],
        };
        let a = attribute(&g, &sched, 4.0);
        assert!((a.fraction(BlameCategory::Compute) - 1.0).abs() < 1e-12);
        let j = a.to_json(&sched, 1);
        assert_eq!(j.get("top_ops").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("path").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("residual").unwrap().as_f64().unwrap().abs() <= 1e-9);
        let fr = j.get("fractions").unwrap();
        assert!(fr.get("compute").unwrap().as_f64().unwrap() > 0.99);
    }
}
