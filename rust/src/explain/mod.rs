//! Placement explainability — the third observability pillar.
//!
//! [`crate::telemetry`] answers *how long* each pipeline stage took;
//! this module answers *why the plan looks the way it does*:
//!
//! - [`decision`] — opt-in per-op **decision records**: for every op a
//!   placer commits, the candidate-device ESTs split into data-ready
//!   (comm) and queue (device-free) components, the memory deficits
//!   that disqualified devices, and the reason the winner won (min-EST,
//!   SCT favorite-child, coarsening pin, OOM fallback). Threaded
//!   through `placer/sched.rs`, `metf.rs`, `msct.rs`, and
//!   `hierarchy/refine.rs` behind a single relaxed atomic load.
//! - [`attribution`] — **critical-path attribution**: walk the
//!   simulator's [`crate::sim::SimSchedule`] backward from the makespan
//!   and attribute every second of it to compute / transfer /
//!   queue-wait / idle, per device and per link, with the top-k
//!   critical ops. The four category totals sum to the makespan within
//!   1e-9 (property-tested).
//! - [`record`] — a **run-history flight recorder**: an append-only
//!   JSONL store of [`record::RunRecord`]s (graph + topology features,
//!   placer spec, serve mode, simulated makespan, critical-path
//!   breakdown), size-bounded with rotation. Written by
//!   [`crate::engine::PlacementEngine`] and
//!   [`crate::serve::PlacementService`] when enabled; this is the
//!   substrate the learned-scorer/portfolio roadmap item trains on.
//!
//! Surfaced by `baechi explain` (per-op query, critical-path report,
//! placer diff), by new Prometheus families in
//! [`crate::telemetry::prometheus`], and as `crit`/`crit_category`
//! Chrome-trace span args so Perfetto highlights the critical path.
//!
//! **Off by default, same contract as tracing:** with no
//! [`decision::DecisionScope`] active and no recorder configured,
//! responses are bit-identical to a build without this module and the
//! placer hot path pays one relaxed atomic load
//! ([`decision::is_live`]). Enable per-process with `BAECHI_EXPLAIN`
//! (decision records) and `BAECHI_RUN_HISTORY=<path>` (flight
//! recorder), or per-call with [`decision::record_decisions`] /
//! [`crate::engine::PlacementEngineBuilder::run_history`].

pub mod attribution;
pub mod decision;
pub mod record;

pub use attribution::{attribute, Attribution, BlameCategory, DeviceBlame, LinkBlame, PathStep};
pub use decision::{
    decisions_recorded, is_live, record_decisions, Candidate, Decision, DecisionLog,
    DecisionReason, DecisionScope,
};
pub use record::{FlightRecorder, RecorderStats, RunRecord};

/// Whether the `BAECHI_EXPLAIN` environment variable asks for decision
/// recording. Unset, empty, `0`, `false`, `off`, and `no` mean off;
/// anything else means on. Same contract as `BAECHI_TRACE`.
pub fn env_explain_enabled() -> bool {
    match std::env::var("BAECHI_EXPLAIN") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "" | "0" | "false" | "off" | "no"
        ),
        Err(_) => false,
    }
}

/// Flight-recorder path requested by `BAECHI_RUN_HISTORY` (unset or
/// off-valued means no recorder). `BAECHI_RUN_HISTORY_MAX_BYTES`
/// overrides the rotation bound (default
/// [`record::DEFAULT_MAX_BYTES`]).
pub fn env_run_history() -> Option<(String, u64)> {
    let path = std::env::var("BAECHI_RUN_HISTORY").ok()?;
    let trimmed = path.trim();
    if matches!(
        trimmed.to_ascii_lowercase().as_str(),
        "" | "0" | "false" | "off" | "no"
    ) {
        return None;
    }
    let max_bytes = std::env::var("BAECHI_RUN_HISTORY_MAX_BYTES")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .unwrap_or(record::DEFAULT_MAX_BYTES);
    Some((trimmed.to_string(), max_bytes))
}

#[cfg(test)]
mod tests {
    #[test]
    fn env_gates_default_off() {
        // The test harness does not set the variables, so both gates
        // must read as disabled (the off-by-default contract).
        if std::env::var("BAECHI_EXPLAIN").is_err() {
            assert!(!super::env_explain_enabled());
        }
        if std::env::var("BAECHI_RUN_HISTORY").is_err() {
            assert!(super::env_run_history().is_none());
        }
    }
}
