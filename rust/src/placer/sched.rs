//! Shared scheduling state for m-ETF and m-SCT: earliest-schedulable-time
//! computation (paper Eq. 1), sequential communication queues (§3.1.4),
//! per-destination tensor caching (§4.2), and the memory ledger.
//!
//! Communication costs are pairwise: every transfer is priced by the
//! cluster topology's effective model for its device pair and reserves
//! every interconnect link on its path
//! ([`crate::topology::contention::LinkTimes`]). Under a uniform
//! topology this reduces bit-for-bit to the paper's single `CommModel`
//! plus one transfer engine per device.

use super::ledger::MemoryLedger;
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::profile::Cluster;
use crate::topology::contention::LinkTimes;
use crate::topology::Topology;
use std::borrow::Cow;

const INF: f64 = f64::INFINITY;

/// Mutable schedule being constructed by a placement algorithm.
pub struct SchedState<'a> {
    pub graph: &'a OpGraph,
    pub cluster: &'a Cluster,
    topo: Cow<'a, Topology>,
    pub ledger: MemoryLedger,
    pub start: Vec<f64>,
    pub finish: Vec<f64>,
    pub device_of: Vec<Option<DeviceId>>,
    /// Earliest time each device's compute queue is free.
    pub device_free: Vec<f64>,
    /// Earliest time each interconnect link is free (§3.1.4 generalized:
    /// one transfer at a time per link; uniform topologies make links
    /// exactly the per-device transfer engines).
    comm_free: LinkTimes,
    /// `arrival[node * n_dev + device]`: when the node's output tensor is
    /// available on that device (INF = not transferred). The home device
    /// is set at schedule time. Stored flat — one allocation instead of
    /// one per node, which dominates setup cost on 100K+-op graphs.
    arrival: Vec<f64>,
    /// Unscheduled predecessor count (readiness tracking).
    pub unscheduled_preds: Vec<usize>,
    pub scheduled_count: usize,
}

impl<'a> SchedState<'a> {
    pub fn new(graph: &'a OpGraph, cluster: &'a Cluster) -> SchedState<'a> {
        let cap = graph.capacity();
        let n = cluster.n();
        let topo = cluster.effective_topology();
        let capacities: Vec<u64> = cluster.devices.iter().map(|d| d.memory).collect();
        let mut unscheduled_preds = vec![usize::MAX; cap];
        for id in graph.node_ids() {
            unscheduled_preds[id.0] = graph.in_degree(id);
        }
        SchedState {
            graph,
            cluster,
            ledger: MemoryLedger::new(graph, &capacities),
            start: vec![0.0; cap],
            finish: vec![0.0; cap],
            device_of: vec![None; cap],
            device_free: vec![0.0; n],
            comm_free: LinkTimes::new(topo.n_links()),
            arrival: vec![INF; cap * n],
            unscheduled_preds,
            scheduled_count: 0,
            topo,
        }
    }

    /// The topology this schedule prices communication against.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Index into the flat `arrival` table.
    #[inline]
    fn arr_idx(&self, i: NodeId, p: DeviceId) -> usize {
        i.0 * self.device_free.len() + p.0
    }

    /// Earliest free instant of one interconnect link.
    pub fn comm_free_at(&self, link: usize) -> f64 {
        self.comm_free.free_at(link)
    }

    /// Ops with no unscheduled predecessors and not yet scheduled.
    pub fn initial_ready(&self) -> Vec<NodeId> {
        self.graph
            .node_ids()
            .filter(|&id| self.unscheduled_preds[id.0] == 0)
            .collect()
    }

    pub fn is_scheduled(&self, id: NodeId) -> bool {
        self.device_of[id.0].is_some()
    }

    pub fn done(&self) -> bool {
        self.scheduled_count == self.graph.len()
    }

    /// Makespan of the schedule so far.
    pub fn makespan(&self) -> f64 {
        self.graph
            .node_ids()
            .map(|id| self.finish[id.0])
            .fold(0.0, f64::max)
    }

    /// When would pred `i`'s tensor be available on device `p`
    /// (hypothetically — does not reserve transfer slots)?
    fn data_ready_from(&self, i: NodeId, p: DeviceId, bytes: u64) -> f64 {
        let src = self.device_of[i.0].expect("pred must be scheduled");
        if src == p {
            return self.finish[i.0];
        }
        let cached = self.arrival[self.arr_idx(i, p)];
        if cached.is_finite() {
            return cached;
        }
        let t = self.topo.time(src.0, p.0, bytes);
        if self.cluster.sequential_comm {
            let start = self
                .comm_free
                .earliest(self.finish[i.0], self.topo.path(src.0, p.0));
            start + t
        } else {
            self.finish[i.0] + t
        }
    }

    /// Earliest schedulable time of `j` on `p` (paper Eq. 1, with queue
    /// wait added per §3.1.4). `None` if memory/colocation forbids it.
    pub fn est(&self, j: NodeId, p: DeviceId) -> Option<f64> {
        if !self.ledger.fits(self.graph, j, p) {
            return None;
        }
        let mut ready = 0.0f64;
        for &(i, bytes) in self.graph.predecessors(j) {
            ready = ready.max(self.data_ready_from(i, p, bytes));
        }
        Some(ready.max(self.device_free[p.0]))
    }

    /// Decision-record view of every device's bid for `j`
    /// ([`crate::explain::Candidate`]): the EST split into its
    /// data-ready (comm) and device-free (queue) components, plus the
    /// memory deficit of devices that don't fit. Explain-only — callers
    /// gate on [`crate::explain::decision::is_live`], it is never on
    /// the hot path, and it reserves nothing (same hypothetical view as
    /// [`est`](Self::est)).
    pub fn explain_candidates(&self, j: NodeId) -> Vec<crate::explain::Candidate> {
        (0..self.device_free.len())
            .map(|d| {
                let p = DeviceId(d);
                let mut data_ready = 0.0f64;
                for &(i, bytes) in self.graph.predecessors(j) {
                    data_ready = data_ready.max(self.data_ready_from(i, p, bytes));
                }
                let (est, memory_deficit) = match self.ledger.required_on(self.graph, j, p) {
                    // Colocation pins `j` to another device; not a
                    // memory disqualification.
                    None => (None, 0),
                    Some(need) => {
                        let free = self.ledger.devices[d].free();
                        if need <= free {
                            (Some(data_ready.max(self.device_free[d])), 0)
                        } else {
                            (None, need - free)
                        }
                    }
                };
                crate::explain::Candidate {
                    device: d,
                    est,
                    data_ready,
                    device_free: self.device_free[d],
                    memory_deficit,
                }
            })
            .collect()
    }

    /// Urgent time of `j`: the earliest `j` could start on *any* device,
    /// charging full communication from every predecessor (paper App. B).
    /// Heterogeneous topologies charge each predecessor's cheapest
    /// outbound link.
    pub fn urgent_time(&self, j: NodeId) -> f64 {
        let mut u = 0.0f64;
        for &(i, bytes) in self.graph.predecessors(j) {
            let src = self.device_of[i.0].expect("pred must be scheduled");
            u = u.max(self.finish[i.0] + self.topo.min_time_from(src.0, bytes));
        }
        u
    }

    /// Commit `j` to `p`: reserve transfer slots for its inputs, set
    /// start/finish, charge memory, and update readiness. Returns the
    /// newly-ready successors.
    pub fn commit(&mut self, j: NodeId, p: DeviceId) -> Vec<NodeId> {
        debug_assert!(self.device_of[j.0].is_none(), "double schedule of {j}");
        // Reserve transfers, in order of predecessor finish time.
        let mut preds: Vec<(NodeId, u64)> = self.graph.predecessors(j).to_vec();
        preds.sort_by(|a, b| {
            self.finish[a.0 .0]
                .partial_cmp(&self.finish[b.0 .0])
                .unwrap()
                .then(a.0.cmp(&b.0))
        });
        let mut ready = 0.0f64;
        for (i, bytes) in preds {
            let src = self.device_of[i.0].expect("pred scheduled");
            let avail = if src == p {
                self.finish[i.0]
            } else if self.arrival[self.arr_idx(i, p)].is_finite() {
                self.arrival[self.arr_idx(i, p)] // cached — no new transfer
            } else {
                let t = self.topo.time(src.0, p.0, bytes);
                let arr = if self.cluster.sequential_comm {
                    let path = self.topo.path(src.0, p.0);
                    let start = self.comm_free.earliest(self.finish[i.0], path);
                    let end = start + t;
                    self.comm_free.reserve(path, end);
                    end
                } else {
                    self.finish[i.0] + t
                };
                let idx = self.arr_idx(i, p);
                self.arrival[idx] = arr;
                arr
            };
            ready = ready.max(avail);
        }
        let start = ready.max(self.device_free[p.0]);
        let compute = self.graph.node(j).compute / self.cluster.devices[p.0].speed;
        let finish = start + compute;
        self.start[j.0] = start;
        self.finish[j.0] = finish;
        self.device_free[p.0] = finish;
        self.device_of[j.0] = Some(p);
        let idx = self.arr_idx(j, p);
        self.arrival[idx] = finish;
        self.ledger.commit(self.graph, j, p);
        self.scheduled_count += 1;

        let mut newly_ready = Vec::new();
        for &(s, _) in self.graph.successors(j) {
            let r = &mut self.unscheduled_preds[s.0];
            *r -= 1;
            if *r == 0 {
                newly_ready.push(s);
            }
        }
        newly_ready
    }
}

/// Depth-bucketed FIFO ready queue.
///
/// Large-graph sweeps (the hierarchical refine pass, list schedulers
/// that only need *a* deterministic topological-ish order) don't need a
/// full priority heap: bucketing ready nodes by their DAG depth
/// ([`OpGraph::depths`](crate::graph::OpGraph)) gives O(1) push/pop with
/// a monotone cursor, because every successor is strictly deeper than
/// the node that readied it. Within a bucket, order is FIFO — push
/// order — which keeps sweeps deterministic.
#[derive(Debug, Default)]
pub struct ReadyBuckets {
    buckets: Vec<std::collections::VecDeque<NodeId>>,
    cursor: usize,
    len: usize,
}

impl ReadyBuckets {
    /// Queue sized for depths `0..=max_depth` (grows on demand).
    pub fn new(max_depth: usize) -> ReadyBuckets {
        ReadyBuckets {
            buckets: (0..=max_depth)
                .map(|_| std::collections::VecDeque::new())
                .collect(),
            cursor: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue `node` at `depth`.
    pub fn push(&mut self, node: NodeId, depth: usize) {
        if depth >= self.buckets.len() {
            self.buckets
                .resize_with(depth + 1, std::collections::VecDeque::new);
        }
        self.buckets[depth].push_back(node);
        self.cursor = self.cursor.min(depth);
        self.len += 1;
    }

    /// Dequeue the shallowest node (FIFO within a depth).
    pub fn pop(&mut self) -> Option<NodeId> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
        }
        self.len -= 1;
        self.buckets[self.cursor].pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpGraph, OpKind};
    use crate::profile::CommModel;

    fn two_device_cluster() -> Cluster {
        // 1 byte/s bandwidth, zero latency: bytes == seconds.
        Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap())
    }

    fn simple_graph() -> (OpGraph, NodeId, NodeId, NodeId) {
        // a(1s) → b(2s), a → c(1s); edges 5 bytes
        let mut g = OpGraph::new("t");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 2.0;
        g.node_mut(c).compute = 1.0;
        for id in [a, b, c] {
            g.node_mut(id).mem = MemorySpec {
                params: 10,
                ..Default::default()
            };
        }
        g.add_edge(a, b, 5);
        g.add_edge(a, c, 5);
        (g, a, b, c)
    }

    #[test]
    fn est_accounts_for_comm_and_device_free() {
        let (g, a, b, _c) = simple_graph();
        let cluster = two_device_cluster();
        let mut st = SchedState::new(&g, &cluster);
        assert_eq!(st.initial_ready(), vec![a]);
        st.commit(a, DeviceId(0));
        // On a's device: ready at finish(a)=1. On device 1: 1 + 5 = 6.
        assert_eq!(st.est(b, DeviceId(0)), Some(1.0));
        assert_eq!(st.est(b, DeviceId(1)), Some(6.0));
    }

    #[test]
    fn transfer_caching_avoids_second_transfer() {
        let (g, a, b, c) = simple_graph();
        let cluster = two_device_cluster();
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        st.commit(b, DeviceId(1)); // transfers a's tensor to dev1: arrives at 6
        assert_eq!(st.start[b.0], 6.0);
        // c on dev1 reuses the cached tensor: est = max(free(dev1)=8, 6) = 8
        assert_eq!(st.est(c, DeviceId(1)), Some(8.0));
        // comm queues were consumed once (uniform: link 0 = dev0's engine)
        assert_eq!(st.comm_free_at(0), 6.0);
    }

    #[test]
    fn sequential_comm_queues_serialize() {
        // a → b and a → c, b and c on different devices: the two
        // transfers out of a's device must serialize (§3.1.4).
        let (g, a, b, c) = simple_graph();
        let cluster = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap());
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        st.commit(b, DeviceId(1)); // transfer occupies [1, 6] on dev0+dev1
        st.commit(c, DeviceId(2)); // queued behind: [6, 11]
        assert_eq!(st.start[b.0], 6.0);
        assert_eq!(st.start[c.0], 11.0);
    }

    #[test]
    fn parallel_comm_overlaps() {
        let (g, a, b, c) = simple_graph();
        let cluster = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap())
            .with_sequential_comm(false);
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        st.commit(b, DeviceId(1));
        st.commit(c, DeviceId(2));
        assert_eq!(st.start[b.0], 6.0);
        assert_eq!(st.start[c.0], 6.0); // overlapped transfers
    }

    #[test]
    fn est_respects_memory() {
        let (mut g, a, _b, _c) = simple_graph();
        g.node_mut(a).mem.params = 5000; // too big for 1000-byte devices
        let cluster = two_device_cluster();
        let st = SchedState::new(&g, &cluster);
        assert_eq!(st.est(a, DeviceId(0)), None);
    }

    #[test]
    fn makespan_tracks_finish() {
        let (g, a, b, c) = simple_graph();
        let cluster = two_device_cluster();
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        st.commit(b, DeviceId(0));
        st.commit(c, DeviceId(0));
        assert!(st.done());
        assert_eq!(st.makespan(), 4.0); // 1 + 2 + 1 sequential
    }

    #[test]
    fn pairwise_costs_prefer_fast_links() {
        // Islands of 2 at 10 bytes/s intra, 1 byte/s inter: the same
        // 5-byte edge costs 0.5 s within an island, 5 s across.
        use crate::topology::Topology;
        let (g, a, b, _c) = simple_graph();
        let intra = CommModel::new(0.0, 10.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let topo = Topology::nvlink_islands(4, 2, intra, inter).unwrap();
        let cluster = Cluster::homogeneous(4, 1000, inter)
            .with_topology(topo)
            .unwrap();
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        // Device 1 shares a's island: 1 + 0.5; device 2 is across: 1 + 5.
        assert_eq!(st.est(b, DeviceId(1)), Some(1.5));
        assert_eq!(st.est(b, DeviceId(2)), Some(6.0));
    }

    #[test]
    fn shared_trunk_serializes_cross_machine_transfers() {
        // Two-tier: transfers 0→2 and 1→3 both cross the shared NIC
        // trunks and must queue, unlike the islands topology where the
        // endpoint host-links are disjoint.
        use crate::topology::Topology;
        let mut g = OpGraph::new("trunk");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 5);
        g.add_edge(b, d, 5);
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let cluster = Cluster::homogeneous(4, 1000, inter)
            .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
            .unwrap();
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(0));
        st.commit(b, DeviceId(1));
        st.commit(c, DeviceId(2)); // transfer [1, 6] on the trunk
        st.commit(d, DeviceId(3)); // queued: [6, 11]
        assert_eq!(st.start[c.0], 6.0);
        assert_eq!(st.start[d.0], 11.0);

        let islands = Cluster::homogeneous(4, 1000, inter)
            .with_topology(Topology::nvlink_islands(4, 2, intra, inter).unwrap())
            .unwrap();
        let mut st2 = SchedState::new(&g, &islands);
        st2.commit(a, DeviceId(0));
        st2.commit(b, DeviceId(1));
        st2.commit(c, DeviceId(2));
        st2.commit(d, DeviceId(3)); // disjoint host-links: no queueing
        assert_eq!(st2.start[c.0], 6.0);
        assert_eq!(st2.start[d.0], 6.0);
    }

    #[test]
    fn ready_buckets_pop_in_depth_order() {
        let mut q = ReadyBuckets::new(3);
        q.push(NodeId(10), 2);
        q.push(NodeId(1), 0);
        q.push(NodeId(2), 0);
        q.push(NodeId(5), 1);
        assert_eq!(q.len(), 4);
        // Depth order, FIFO within depth 0.
        assert_eq!(q.pop(), Some(NodeId(1)));
        assert_eq!(q.pop(), Some(NodeId(2)));
        // Interleaved push at a depth not shallower than the cursor.
        q.push(NodeId(6), 1);
        assert_eq!(q.pop(), Some(NodeId(5)));
        assert_eq!(q.pop(), Some(NodeId(6)));
        assert_eq!(q.pop(), Some(NodeId(10)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn ready_buckets_grow_past_initial_depth() {
        let mut q = ReadyBuckets::new(0);
        q.push(NodeId(3), 7); // deeper than the initial allocation
        q.push(NodeId(4), 0);
        assert_eq!(q.pop(), Some(NodeId(4)));
        assert_eq!(q.pop(), Some(NodeId(3)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn device_speed_scales_compute() {
        use crate::topology::Topology;
        let (g, a, _b, _c) = simple_graph();
        let comm = CommModel::new(0.0, 1.0).unwrap();
        let topo = Topology::uniform(2, comm)
            .with_speeds(vec![1.0, 2.0])
            .unwrap();
        let cluster = Cluster::homogeneous(2, 1000, comm)
            .with_topology(topo)
            .unwrap();
        let mut st = SchedState::new(&g, &cluster);
        st.commit(a, DeviceId(1)); // 1 s of work at 2× speed
        assert_eq!(st.finish[a.0], 0.5);
    }
}
