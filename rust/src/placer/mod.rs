//! The memory-constrained placement algorithms (paper §2): m-TOPO,
//! m-ETF and m-SCT, plus the shared [`Placement`] result type and the
//! [`Placer`] trait implemented by the baselines as well.
//!
//! Placers report failures through the crate-wide
//! [`BaechiError`](crate::BaechiError) enum — OOM carries the failing
//! operator together with the closest device and its byte deficit, so a
//! serving layer can react (shed load, grow the cluster, pick another
//! placer) without string matching.

pub mod ledger;
pub mod metf;
pub mod msct;
pub mod mtopo;
pub mod sched;

use crate::error::BaechiError;
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::profile::Cluster;
use std::collections::BTreeMap;

/// A completed placement of a graph on a cluster.
#[derive(Debug, Clone)]
pub struct Placement {
    pub algorithm: String,
    pub device_of: BTreeMap<NodeId, DeviceId>,
    /// Makespan predicted by the placement-time schedule, seconds.
    pub predicted_makespan: f64,
    /// Wall-clock time the algorithm took, seconds.
    pub placement_time: f64,
    /// Peak memory per device as tracked by the placement ledger.
    pub peak_memory: Vec<u64>,
}

impl Placement {
    /// Device of `id`, if the placement covers it.
    pub fn try_device(&self, id: NodeId) -> Option<DeviceId> {
        self.device_of.get(&id).copied()
    }

    /// Device of `id`. Panics with a descriptive message when the node
    /// is not covered — use [`Placement::try_device`] to handle that
    /// case gracefully.
    pub fn device(&self, id: NodeId) -> DeviceId {
        self.try_device(id).unwrap_or_else(|| {
            panic!(
                "placement '{}' ({} ops) has no device for node {id}",
                self.algorithm,
                self.device_of.len()
            )
        })
    }

    /// Ops per device.
    pub fn device_histogram(&self, n: usize) -> Vec<usize> {
        let mut h = vec![0; n];
        for d in self.device_of.values() {
            h[d.0] += 1;
        }
        h
    }

    /// Number of distinct devices actually used.
    pub fn devices_used(&self) -> usize {
        let set: std::collections::BTreeSet<_> = self.device_of.values().collect();
        set.len()
    }
}

/// A placement algorithm.
pub trait Placer {
    fn name(&self) -> String;
    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement>;
}

/// Build the OOM error for an op no device can host: scans the ledger
/// for the closest device and its byte deficit.
pub(crate) fn oom_error(
    graph: &OpGraph,
    node: NodeId,
    ledger: &ledger::MemoryLedger,
) -> BaechiError {
    let mut best: Option<(DeviceId, u64)> = None;
    for d in 0..ledger.devices.len() {
        let dev = DeviceId(d);
        if let Some(need) = ledger.required_on(graph, node, dev) {
            let deficit = need.saturating_sub(ledger.devices[d].free());
            if best.map_or(true, |(_, b)| deficit < b) {
                best = Some((dev, deficit));
            }
        }
    }
    BaechiError::Oom {
        op: graph.node(node).name.clone(),
        best_device: best.map(|(d, _)| d),
        deficit: best.map(|(_, x)| x).unwrap_or(0),
    }
}

/// Helper shared by placers: verify the result covers every live op.
pub(crate) fn finish_placement(
    algorithm: &str,
    graph: &OpGraph,
    st: sched::SchedState<'_>,
    t0: std::time::Instant,
) -> crate::Result<Placement> {
    let mut device_of = BTreeMap::new();
    for id in graph.node_ids() {
        match st.device_of[id.0] {
            Some(d) => {
                device_of.insert(id, d);
            }
            None => return Err(oom_error(graph, id, &st.ledger)),
        }
    }
    Ok(Placement {
        algorithm: algorithm.to_string(),
        predicted_makespan: st.makespan(),
        placement_time: t0.elapsed().as_secs_f64(),
        peak_memory: st.ledger.peaks(),
        device_of,
    })
}

/// Heap entry ordered by earliest schedulable time. Ties break on
/// favorite-device preference, then ids, for determinism. Used as
/// `Reverse<QueueEntry>` inside a max-heap to obtain a min-heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct QueueEntry {
    pub est: f64,
    pub prefer: bool, // favorite-parent device gets priority on ties
    pub node: NodeId,
    pub dev: DeviceId,
}

impl Eq for QueueEntry {}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // NaN `est` (corrupted profile) sorts strictly last — greater
        // than every finite value and equal to other NaNs — so the heap
        // keeps a consistent total order instead of silently treating
        // NaN as a tie with everything, which breaks transitivity.
        let est_ord = match self.est.partial_cmp(&other.est) {
            Some(o) => o,
            None => match (self.est.is_nan(), other.est.is_nan()) {
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                _ => std::cmp::Ordering::Equal,
            },
        };
        est_ord
            .then_with(|| other.prefer.cmp(&self.prefer)) // prefer=true first
            .then_with(|| self.node.cmp(&other.node))
            .then_with(|| self.dev.cmp(&other.dev))
    }
}

impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Ordering;

    #[test]
    fn queue_entry_ordering() {
        let a = QueueEntry {
            est: 1.0,
            prefer: false,
            node: NodeId(0),
            dev: DeviceId(0),
        };
        let b = QueueEntry {
            est: 2.0,
            prefer: true,
            node: NodeId(0),
            dev: DeviceId(0),
        };
        assert!(a < b, "earlier est wins regardless of preference");
        let c = QueueEntry { prefer: true, ..a };
        assert!(c < a, "preference breaks ties");
    }

    #[test]
    fn nan_est_schedules_last() {
        let finite = QueueEntry {
            est: 1e12,
            prefer: true,
            node: NodeId(7),
            dev: DeviceId(3),
        };
        let nan = QueueEntry {
            est: f64::NAN,
            prefer: true,
            node: NodeId(0),
            dev: DeviceId(0),
        };
        assert_eq!(nan.cmp(&finite), Ordering::Greater, "NaN after finite");
        assert_eq!(finite.cmp(&nan), Ordering::Less, "finite before NaN");
        // NaN vs NaN falls through to the deterministic tie-breaks.
        let nan2 = QueueEntry {
            node: NodeId(1),
            ..nan
        };
        assert_eq!(nan.cmp(&nan2), Ordering::Less);
        assert_eq!(nan.cmp(&nan), Ordering::Equal);
    }

    #[test]
    fn nan_never_preempts_in_min_heap() {
        // A min-heap (Reverse) over entries with one NaN must pop every
        // finite entry first regardless of insertion order.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mk = |est: f64, n: usize| QueueEntry {
            est,
            prefer: false,
            node: NodeId(n),
            dev: DeviceId(0),
        };
        let mut heap = BinaryHeap::new();
        for e in [mk(f64::NAN, 9), mk(3.0, 1), mk(1.0, 2), mk(2.0, 3)] {
            heap.push(Reverse(e));
        }
        let order: Vec<usize> = std::iter::from_fn(|| heap.pop().map(|Reverse(e)| e.node.0))
            .collect();
        assert_eq!(order, vec![2, 3, 1, 9], "NaN entry pops last");
    }

    #[test]
    fn try_device_on_missing_node() {
        let p = Placement {
            algorithm: "test".into(),
            device_of: [(NodeId(0), DeviceId(1))].into_iter().collect(),
            predicted_makespan: 0.0,
            placement_time: 0.0,
            peak_memory: vec![0, 0],
        };
        assert_eq!(p.try_device(NodeId(0)), Some(DeviceId(1)));
        assert_eq!(p.try_device(NodeId(42)), None);
        assert_eq!(p.device(NodeId(0)), DeviceId(1));
    }
}
