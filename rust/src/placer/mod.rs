//! The memory-constrained placement algorithms (paper §2): m-TOPO,
//! m-ETF and m-SCT, plus the shared [`Placement`] result type and the
//! [`Placer`] trait implemented by the baselines as well.

pub mod ledger;
pub mod metf;
pub mod msct;
pub mod mtopo;
pub mod sched;

use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::profile::Cluster;
use std::collections::BTreeMap;

/// A completed placement of a graph on a cluster.
#[derive(Debug, Clone)]
pub struct Placement {
    pub algorithm: String,
    pub device_of: BTreeMap<NodeId, DeviceId>,
    /// Makespan predicted by the placement-time schedule, seconds.
    pub predicted_makespan: f64,
    /// Wall-clock time the algorithm took, seconds.
    pub placement_time: f64,
    /// Peak memory per device as tracked by the placement ledger.
    pub peak_memory: Vec<u64>,
}

impl Placement {
    pub fn device(&self, id: NodeId) -> DeviceId {
        self.device_of[&id]
    }

    /// Ops per device.
    pub fn device_histogram(&self, n: usize) -> Vec<usize> {
        let mut h = vec![0; n];
        for d in self.device_of.values() {
            h[d.0] += 1;
        }
        h
    }

    /// Number of distinct devices actually used.
    pub fn devices_used(&self) -> usize {
        let set: std::collections::BTreeSet<_> = self.device_of.values().collect();
        set.len()
    }
}

/// Placement failure.
#[derive(Debug, thiserror::Error)]
pub enum PlaceError {
    #[error("out of memory: operator {op} does not fit on any device")]
    Oom { op: String },
    #[error("graph is not a DAG")]
    Cyclic,
}

/// A placement algorithm.
pub trait Placer {
    fn name(&self) -> String;
    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> anyhow::Result<Placement>;
}

/// Helper shared by placers: verify the result covers every live op.
pub(crate) fn finish_placement(
    algorithm: &str,
    graph: &OpGraph,
    st: sched::SchedState<'_>,
    t0: std::time::Instant,
) -> anyhow::Result<Placement> {
    let mut device_of = BTreeMap::new();
    for id in graph.node_ids() {
        match st.device_of[id.0] {
            Some(d) => {
                device_of.insert(id, d);
            }
            None => {
                return Err(PlaceError::Oom {
                    op: graph.node(id).name.clone(),
                }
                .into())
            }
        }
    }
    Ok(Placement {
        algorithm: algorithm.to_string(),
        predicted_makespan: st.makespan(),
        placement_time: t0.elapsed().as_secs_f64(),
        peak_memory: st.ledger.peaks(),
        device_of,
    })
}

/// Heap entry ordered by earliest schedulable time. Ties break on
/// favorite-device preference, then ids, for determinism. Used as
/// `Reverse<QueueEntry>` inside a max-heap to obtain a min-heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct QueueEntry {
    pub est: f64,
    pub prefer: bool, // favorite-parent device gets priority on ties
    pub node: NodeId,
    pub dev: DeviceId,
}

impl Eq for QueueEntry {}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.est
            .partial_cmp(&other.est)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.prefer.cmp(&self.prefer)) // prefer=true first
            .then_with(|| self.node.cmp(&other.node))
            .then_with(|| self.dev.cmp(&other.dev))
    }
}

impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_entry_ordering() {
        let a = QueueEntry {
            est: 1.0,
            prefer: false,
            node: NodeId(0),
            dev: DeviceId(0),
        };
        let b = QueueEntry {
            est: 2.0,
            prefer: true,
            node: NodeId(0),
            dev: DeviceId(0),
        };
        assert!(a < b, "earlier est wins regardless of preference");
        let c = QueueEntry { prefer: true, ..a };
        assert!(c < a, "preference breaks ties");
    }
}
