//! m-ETF: memory-constrained Earliest Task First (paper §2.3).
//!
//! Maintains a queue of `(operator, device)` pairs sorted by earliest
//! schedulable time (paper Eq. 1 plus the §3.1.4 communication-queue
//! wait). Iteratively pops the head; if the device's leftover memory is
//! insufficient the pair is removed (exactly the paper's rule), otherwise
//! the operator is committed and its children's pairs enter the queue.
//!
//! The heap is lazy: committed state only pushes earliest-schedulable
//! times upward, so a popped entry is re-validated and re-pushed when its
//! recomputed time regressed.

use super::sched::SchedState;
use super::{finish_placement, oom_error, Placement, Placer, QueueEntry};
use crate::error::BaechiError;
use crate::graph::{DeviceId, OpGraph};
use crate::profile::Cluster;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// The m-ETF placer.
#[derive(Debug, Default, Clone, Copy)]
pub struct MEtf;

const EPS: f64 = 1e-12;

impl Placer for MEtf {
    fn name(&self) -> String {
        "m-etf".to_string()
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        let t0 = std::time::Instant::now();
        if !graph.is_acyclic() {
            return Err(BaechiError::Cyclic);
        }
        let mut st = SchedState::new(graph, cluster);
        let mut heap: BinaryHeap<Reverse<QueueEntry>> = BinaryHeap::new();

        let push_all = |st: &SchedState<'_>,
                        heap: &mut BinaryHeap<Reverse<QueueEntry>>,
                        node: crate::graph::NodeId| {
            for d in 0..cluster.n() {
                let dev = DeviceId(d);
                // Push with the current estimate; memory-infeasible pairs
                // enter with a sentinel and are re-checked at pop time
                // (memory can free up as outputs are consumed).
                let est = st.est(node, dev).unwrap_or(f64::MAX);
                heap.push(Reverse(QueueEntry {
                    est,
                    prefer: false,
                    node,
                    dev,
                }));
            }
        };

        for node in st.initial_ready() {
            push_all(&st, &mut heap, node);
        }

        while let Some(Reverse(entry)) = heap.pop() {
            if st.is_scheduled(entry.node) {
                continue;
            }
            match st.est(entry.node, entry.dev) {
                None => {
                    // Paper: "if the head element (i, p) is not schedulable
                    // because device p's leftover memory is insufficient,
                    // the head is removed" — unless it was a sentinel that
                    // never had a real estimate; those only pop after all
                    // real entries, where removal is equally correct.
                    continue;
                }
                Some(now) => {
                    if now > entry.est + EPS {
                        // Stale: someone advanced this device/comm queue.
                        heap.push(Reverse(QueueEntry { est: now, ..entry }));
                        continue;
                    }
                    if crate::explain::is_live() {
                        crate::explain::decision::record(crate::explain::Decision {
                            node: entry.node,
                            name: graph.node(entry.node).name.clone(),
                            chosen: entry.dev.0,
                            reason: crate::explain::DecisionReason::MinEst,
                            candidates: st.explain_candidates(entry.node),
                        });
                    }
                    let newly_ready = st.commit(entry.node, entry.dev);
                    for r in newly_ready {
                        push_all(&st, &mut heap, r);
                    }
                }
            }
        }

        if !st.done() {
            // Some op exhausted all its pairs: report the first unplaced.
            let unplaced = graph
                .node_ids()
                .find(|&id| st.device_of[id.0].is_none())
                .unwrap();
            return Err(oom_error(graph, unplaced, &st.ledger));
        }
        finish_placement(&self.name(), graph, st, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, NodeId, OpKind};
    use crate::profile::CommModel;

    /// Two parallel chains: ETF should use both devices.
    #[test]
    fn exploits_parallelism() {
        let mut g = OpGraph::new("par");
        let src = g.add_node("src", OpKind::Input);
        g.node_mut(src).compute = 0.1;
        let mut mk_chain = |tag: &str| -> Vec<NodeId> {
            let mut prev = src;
            let mut ids = Vec::new();
            for i in 0..3 {
                let id = g.add_node(&format!("{tag}{i}"), OpKind::MatMul);
                g.node_mut(id).compute = 1.0;
                g.node_mut(id).mem = MemorySpec {
                    params: 10,
                    ..Default::default()
                };
                g.add_edge(prev, id, 1);
                prev = id;
                ids.push(id);
            }
            ids
        };
        let a = mk_chain("a");
        let b = mk_chain("b");
        let cluster = Cluster::homogeneous(2, 1_000, CommModel::new(0.0, 1e6).unwrap());
        let p = MEtf.place(&g, &cluster).unwrap();
        // both chains can't be faster than 3 s; parallel ≈ 3.1 s, serial 6.1 s
        assert!(p.predicted_makespan < 4.0, "{}", p.predicted_makespan);
        assert_eq!(p.devices_used(), 2);
        // chains must not be interleaved across devices (comm is cheap but
        // est keeps chains local once started)
        let _ = (a, b);
    }

    /// With huge communication cost, everything lands on one device.
    #[test]
    fn expensive_comm_keeps_single_device() {
        let mut g = OpGraph::new("seq");
        let mut prev: Option<NodeId> = None;
        for i in 0..4 {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 10,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 1_000_000_000); // 1 GB tensors
            }
            prev = Some(id);
        }
        let cluster = Cluster::homogeneous(4, 1_000, CommModel::new(0.0, 1e9).unwrap());
        let p = MEtf.place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 1);
        assert!((p.predicted_makespan - 4.0).abs() < 1e-9);
    }

    /// Memory pressure forces spreading even though comm is costly.
    #[test]
    fn memory_forces_spread() {
        let mut g = OpGraph::new("mem");
        let mut prev: Option<NodeId> = None;
        for i in 0..4 {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 600,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 100);
            }
            prev = Some(id);
        }
        // each device fits one 600-byte op only
        let cluster = Cluster::homogeneous(4, 1_000, CommModel::new(0.0, 1e9).unwrap());
        let p = MEtf.place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 4);
    }

    /// OOM when the graph simply cannot fit.
    #[test]
    fn oom_reported() {
        let mut g = OpGraph::new("big");
        for i in 0..3 {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).mem = MemorySpec {
                params: 800,
                ..Default::default()
            };
        }
        let cluster = Cluster::homogeneous(2, 1_000, CommModel::new(0.0, 1e9).unwrap());
        let err = MEtf.place(&g, &cluster).unwrap_err();
        assert!(err.to_string().contains("out of memory"), "{err}");
    }

    /// Colocation constraints hold in the result.
    #[test]
    fn colocation_respected() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(2, 100, CommModel::new(0.0, 1.0).unwrap());
        let p = MEtf.place(&g, &cluster).unwrap();
        for (_, members) in g.colocation_groups() {
            let d0 = p.device(members[0]);
            for &m in &members[1..] {
                assert_eq!(p.device(m), d0, "colocation group split");
            }
        }
    }

    /// ETF beats TOPO on a fork-join graph (the paper's qualitative
    /// Table 4 ordering).
    #[test]
    fn beats_mtopo_on_parallel_graph() {
        let g = crate::models::transformer::transformer(
            crate::models::transformer::TransformerConfig::paper(8),
        );
        let opt = crate::optimizer::optimize(&g, &crate::optimizer::OptConfig::full());
        let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
        let etf = MEtf.place(&opt.graph, &cluster).unwrap();
        let topo = super::super::mtopo::MTopo.place(&opt.graph, &cluster).unwrap();
        assert!(
            etf.predicted_makespan <= topo.predicted_makespan * 1.05,
            "etf {} vs topo {}",
            etf.predicted_makespan,
            topo.predicted_makespan
        );
    }
}
