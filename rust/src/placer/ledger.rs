//! Placement-time device memory ledger (paper §3.1.1 + §4.2).
//!
//! Tracks, per device, the memory the placer has committed:
//!
//! * **permanent** bytes (parameters + gradients) accumulate monotonically;
//! * **output** tensors are held from the producer's schedule until every
//!   successor has been scheduled (in a training graph the backward op is
//!   a successor, so outputs are naturally held across the forward pass —
//!   the paper's dynamic-allocation model);
//! * **temporary** bytes exist only during an op's execution window; since
//!   a device executes one op at a time, the check is
//!   `used + temp(op) ≤ capacity` at schedule time;
//! * **colocation groups** (§3.1.1): when the first member of a group is
//!   placed, the whole group's permanent memory is reserved on that device
//!   at once, and the group is pinned there. If it does not fit, placement
//!   of that member fails and the algorithm tries its next device choice.

use crate::graph::{DeviceId, NodeId, OpGraph};
use std::collections::BTreeMap;

/// Ledger for one device.
#[derive(Debug, Clone)]
pub struct DeviceLedger {
    pub capacity: u64,
    /// Params + grads (+ group reservations) committed so far.
    pub permanent: u64,
    /// Output tensors currently held: node → bytes.
    outputs: BTreeMap<NodeId, u64>,
    output_bytes: u64,
    /// Peak of permanent + outputs + transient temp.
    pub peak: u64,
}

impl DeviceLedger {
    pub fn new(capacity: u64) -> DeviceLedger {
        DeviceLedger {
            capacity,
            permanent: 0,
            outputs: BTreeMap::new(),
            output_bytes: 0,
            peak: 0,
        }
    }

    pub fn used(&self) -> u64 {
        self.permanent + self.output_bytes
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used())
    }

    fn bump_peak(&mut self, transient: u64) {
        self.peak = self.peak.max(self.used() + transient);
    }
}

/// Cluster-wide ledger with colocation-group pinning.
#[derive(Debug, Clone)]
pub struct MemoryLedger {
    pub devices: Vec<DeviceLedger>,
    /// Colocation group → pinned device.
    group_device: BTreeMap<String, DeviceId>,
    /// Per-group total permanent bytes (reserved on first placement).
    group_perm: BTreeMap<String, u64>,
    /// Remaining unscheduled successors per node (for output freeing).
    succ_remaining: Vec<usize>,
    /// Where each node's output is held.
    output_home: Vec<Option<DeviceId>>,
}

impl MemoryLedger {
    /// Build from the graph to place and per-device capacities.
    pub fn new(graph: &OpGraph, capacities: &[u64]) -> MemoryLedger {
        // Colocation-group reservation covers the *entire* group's
        // lasting memory (params, grads, and outputs): "memory is
        // reserved for a colocation group at device p when the first
        // operator is placed on p" (§4.2) — otherwise late group members
        // (e.g. ApplyGrad pinned to its Variable) can dead-end on a
        // device that filled up in the meantime.
        // The reservation also sets aside the largest member's transient
        // scratch: a pinned late member (ApplyGrad / fused backward) must
        // still be runnable after other groups fill the device.
        let mut group_perm: BTreeMap<String, u64> = BTreeMap::new();
        let mut group_temp: BTreeMap<String, u64> = BTreeMap::new();
        for n in graph.iter_nodes() {
            if let Some(g) = &n.colocation_group {
                *group_perm.entry(g.clone()).or_insert(0) +=
                    n.mem.params + n.mem.param_grad + n.mem.output;
                let t = group_temp.entry(g.clone()).or_insert(0);
                *t = (*t).max(n.mem.temporary_training());
            }
        }
        for (g, t) in group_temp {
            *group_perm.get_mut(&g).unwrap() += t;
        }
        let mut succ_remaining = vec![0usize; graph.capacity()];
        for id in graph.node_ids() {
            succ_remaining[id.0] = graph.out_degree(id);
        }
        MemoryLedger {
            devices: capacities.iter().map(|&c| DeviceLedger::new(c)).collect(),
            group_device: BTreeMap::new(),
            group_perm,
            succ_remaining,
            output_home: vec![None; graph.capacity()],
        }
    }

    /// Device a node is constrained to via its colocation group, if the
    /// group is already pinned.
    pub fn pinned_device(&self, graph: &OpGraph, node: NodeId) -> Option<DeviceId> {
        graph
            .node(node)
            .colocation_group
            .as_ref()
            .and_then(|g| self.group_device.get(g).copied())
    }

    /// Bytes `node` would charge if placed on `dev` right now: the whole
    /// group reservation for a first group member, the individual budget
    /// otherwise. `None` when colocation pins the node elsewhere.
    pub fn required_on(&self, graph: &OpGraph, node: NodeId, dev: DeviceId) -> Option<u64> {
        // Colocation pinning dominates.
        if let Some(p) = self.pinned_device(graph, node) {
            if p != dev {
                return None;
            }
        }
        let n = graph.node(node);
        Some(match &n.colocation_group {
            Some(g) if !self.group_device.contains_key(g) => {
                // First member: the whole group's lasting memory (plus
                // its worst transient) must fit.
                self.group_perm[g]
            }
            // Group reservation already covers perm + output + max temp.
            Some(_) => 0,
            None => n.mem.params + n.mem.param_grad + n.mem.output + n.mem.temporary_training(),
        })
    }

    /// Whether `node` can be scheduled on `dev` without exceeding memory.
    pub fn fits(&self, graph: &OpGraph, node: NodeId, dev: DeviceId) -> bool {
        match self.required_on(graph, node, dev) {
            Some(need) => need <= self.devices[dev.0].free(),
            None => false,
        }
    }

    /// Commit `node` to `dev`. Panics if `fits` would be false (callers
    /// check first). Frees predecessors' outputs whose consumers are now
    /// all scheduled.
    pub fn commit(&mut self, graph: &OpGraph, node: NodeId, dev: DeviceId) {
        debug_assert!(self.fits(graph, node, dev), "commit without fits");
        let n = graph.node(node);
        // Group reservation (covers params + grads + outputs of all
        // members); non-grouped ops charge individually.
        let in_group = n.colocation_group.is_some();
        match &n.colocation_group {
            Some(g) if !self.group_device.contains_key(g) => {
                self.group_device.insert(g.clone(), dev);
                self.devices[dev.0].permanent += self.group_perm[g];
            }
            Some(_) => {}
            None => {
                self.devices[dev.0].permanent += n.mem.params + n.mem.param_grad;
            }
        }
        // Output allocation (held until all successors scheduled);
        // grouped ops' outputs live inside the group reservation.
        if !in_group && n.mem.output > 0 && self.succ_remaining[node.0] > 0 {
            let led = &mut self.devices[dev.0];
            led.outputs.insert(node, n.mem.output);
            led.output_bytes += n.mem.output;
            self.output_home[node.0] = Some(dev);
        }
        // Transient peak accounting.
        self.devices[dev.0].bump_peak(n.mem.temporary_training());
        // Free predecessors whose successors are all scheduled.
        for &(p, _) in graph.predecessors(node) {
            let r = &mut self.succ_remaining[p.0];
            *r = r.saturating_sub(1);
            if *r == 0 {
                if let Some(home) = self.output_home[p.0].take() {
                    let led = &mut self.devices[home.0];
                    if let Some(bytes) = led.outputs.remove(&p) {
                        led.output_bytes -= bytes;
                    }
                }
            }
        }
    }

    /// Peak usage per device (for Fig. 7).
    pub fn peaks(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.peak).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpGraph, OpKind};

    fn node_with_mem(g: &mut OpGraph, name: &str, mem: MemorySpec) -> NodeId {
        let id = g.add_node(name, OpKind::MatMul);
        g.node_mut(id).mem = mem;
        id
    }

    #[test]
    fn permanent_accumulates_and_outputs_free() {
        let mut g = OpGraph::new("t");
        let a = node_with_mem(
            &mut g,
            "a",
            MemorySpec {
                params: 100,
                output: 50,
                ..Default::default()
            },
        );
        let b = node_with_mem(
            &mut g,
            "b",
            MemorySpec {
                params: 10,
                ..Default::default()
            },
        );
        g.add_edge(a, b, 50);
        let mut led = MemoryLedger::new(&g, &[1000]);
        assert!(led.fits(&g, a, DeviceId(0)));
        led.commit(&g, a, DeviceId(0));
        assert_eq!(led.devices[0].used(), 150); // params + held output
        led.commit(&g, b, DeviceId(0));
        // b scheduled → a's output freed
        assert_eq!(led.devices[0].used(), 110);
    }

    #[test]
    fn rejects_oversized_op() {
        let mut g = OpGraph::new("t");
        let a = node_with_mem(
            &mut g,
            "a",
            MemorySpec {
                params: 2000,
                ..Default::default()
            },
        );
        let led = MemoryLedger::new(&g, &[1000, 4000]);
        assert!(!led.fits(&g, a, DeviceId(0)));
        assert!(led.fits(&g, a, DeviceId(1)));
    }

    #[test]
    fn colocation_group_reserved_once_and_pins() {
        let mut g = OpGraph::new("t");
        let v = node_with_mem(
            &mut g,
            "var",
            MemorySpec {
                params: 400,
                ..Default::default()
            },
        );
        let ap = node_with_mem(
            &mut g,
            "apply",
            MemorySpec {
                params: 300,
                ..Default::default()
            },
        );
        g.node_mut(v).colocation_group = Some("w".into());
        g.node_mut(ap).colocation_group = Some("w".into());
        let mut led = MemoryLedger::new(&g, &[1000, 1000]);
        // First member needs the whole group's 700.
        assert!(led.fits(&g, v, DeviceId(0)));
        led.commit(&g, v, DeviceId(0));
        assert_eq!(led.devices[0].permanent, 700);
        // Second member pinned to device 0 and costs no extra permanent.
        assert!(!led.fits(&g, ap, DeviceId(1)), "pinned to dev0");
        assert!(led.fits(&g, ap, DeviceId(0)));
        led.commit(&g, ap, DeviceId(0));
        assert_eq!(led.devices[0].permanent, 700);
    }

    #[test]
    fn group_too_big_rejected_at_first_member() {
        let mut g = OpGraph::new("t");
        let v = node_with_mem(
            &mut g,
            "var",
            MemorySpec {
                params: 600,
                ..Default::default()
            },
        );
        let ap = node_with_mem(
            &mut g,
            "apply",
            MemorySpec {
                params: 600,
                ..Default::default()
            },
        );
        g.node_mut(v).colocation_group = Some("w".into());
        g.node_mut(ap).colocation_group = Some("w".into());
        let led = MemoryLedger::new(&g, &[1000]);
        assert!(!led.fits(&g, v, DeviceId(0)), "group of 1200 > 1000");
    }

    #[test]
    fn temp_is_transient() {
        let mut g = OpGraph::new("t");
        let a = node_with_mem(
            &mut g,
            "a",
            MemorySpec {
                temp: 900,
                ..Default::default()
            },
        );
        let b = node_with_mem(
            &mut g,
            "b",
            MemorySpec {
                temp: 900,
                ..Default::default()
            },
        );
        let mut led = MemoryLedger::new(&g, &[1000]);
        assert!(led.fits(&g, a, DeviceId(0)));
        led.commit(&g, a, DeviceId(0));
        // temp released: b's 900 still fits
        assert!(led.fits(&g, b, DeviceId(0)));
        led.commit(&g, b, DeviceId(0));
        assert_eq!(led.devices[0].peak, 900);
    }
}
