//! m-SCT: memory-constrained Small Communication Times (paper §2.4).
//!
//! m-SCT schedules like m-ETF but uses the LP-derived favorite-child
//! relation (module [`crate::lp::sct`]):
//!
//! * after an operator `i` with favorite child `j` finishes on device
//!   `p`, `p` is held **awake** — reserved for `j` — until the time `j`
//!   could have started on `p`;
//! * while awake, only **urgent** operators (ready to begin immediately,
//!   i.e. their data is available no later than the device frees up) may
//!   claim `p` (Hanen–Munier's finite-device rule, §2.4);
//! * a device that runs out of memory is excluded from future placements
//!   (pairs popped against it are dropped, as in m-ETF).

use super::sched::SchedState;
use super::{finish_placement, oom_error, Placement, Placer, QueueEntry};
use crate::error::BaechiError;
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::lp::{favorites, FavoriteMethod, Favorites};
use crate::profile::Cluster;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// The m-SCT placer.
#[derive(Debug, Clone, Copy)]
pub struct MSct {
    pub method: FavoriteMethod,
}

impl Default for MSct {
    fn default() -> MSct {
        MSct {
            // LP on optimizer-reduced graphs; heuristic beyond the limit
            // where the dense interior point becomes the bottleneck
            // (DESIGN.md §6; the limit is raised if the §Perf pass makes
            // the normal-equation factorization fast enough).
            method: FavoriteMethod::Auto { edge_limit: 600 },
        }
    }
}

impl MSct {
    pub fn with_lp() -> MSct {
        MSct {
            method: FavoriteMethod::Lp,
        }
    }

    pub fn with_heuristic() -> MSct {
        MSct {
            method: FavoriteMethod::Heuristic,
        }
    }
}

const EPS: f64 = 1e-12;

/// Awake reservation: device held for `child` until simulated `expiry`.
#[derive(Debug, Clone, Copy)]
struct Awake {
    child: NodeId,
    expiry: f64,
}

impl Placer for MSct {
    fn name(&self) -> String {
        match self.method {
            FavoriteMethod::Lp => "m-sct(lp)".to_string(),
            FavoriteMethod::Heuristic => "m-sct(heur)".to_string(),
            FavoriteMethod::Auto { .. } => "m-sct".to_string(),
        }
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        let t0 = std::time::Instant::now();
        if !graph.is_acyclic() {
            return Err(BaechiError::Cyclic);
        }
        let fav: Favorites = favorites(graph, &cluster.comm, self.method);
        let mut st = SchedState::new(graph, cluster);
        let mut heap: BinaryHeap<Reverse<QueueEntry>> = BinaryHeap::new();
        let mut awake: Vec<Option<Awake>> = vec![None; cluster.n()];

        let push_all = |st: &SchedState<'_>,
                        heap: &mut BinaryHeap<Reverse<QueueEntry>>,
                        fav: &Favorites,
                        node: NodeId| {
            // The favorite parent's device is preferred on est ties.
            let fav_parent_dev = fav.fav_parent[node.0].and_then(|p| st.device_of[p.0]);
            for d in 0..cluster.n() {
                let dev = DeviceId(d);
                let est = st.est(node, dev).unwrap_or(f64::MAX);
                heap.push(Reverse(QueueEntry {
                    est,
                    prefer: fav_parent_dev == Some(dev),
                    node,
                    dev,
                }));
            }
        };

        for node in st.initial_ready() {
            push_all(&st, &mut heap, &fav, node);
        }

        while let Some(Reverse(entry)) = heap.pop() {
            if st.is_scheduled(entry.node) {
                continue;
            }
            let now = match st.est(entry.node, entry.dev) {
                None => continue, // memory-excluded pair (paper rule)
                Some(t) => t,
            };
            if now > entry.est + EPS {
                heap.push(Reverse(QueueEntry { est: now, ..entry }));
                continue;
            }
            // Awake check: device reserved for a favorite child. The
            // window test uses the *queue key* (entry.est), so a pair
            // deferred to `expiry` passes on its next pop — guaranteeing
            // progress.
            if let Some(aw) = awake[entry.dev.0] {
                if st.is_scheduled(aw.child) {
                    awake[entry.dev.0] = None; // reservation satisfied elsewhere
                } else if aw.child != entry.node && entry.est + EPS < aw.expiry {
                    // Non-favorite op during the reservation window: only
                    // urgent ops (data ready by the time the device frees)
                    // may take the device.
                    let urgent = st.urgent_time(entry.node)
                        <= st.device_free[entry.dev.0] + EPS;
                    if !urgent {
                        // Retry once the reservation expires.
                        heap.push(Reverse(QueueEntry {
                            est: aw.expiry,
                            ..entry
                        }));
                        continue;
                    }
                }
            }
            let node = entry.node;
            let dev = entry.dev;
            if crate::explain::is_live() {
                crate::explain::decision::record(crate::explain::Decision {
                    node,
                    name: graph.node(node).name.clone(),
                    chosen: dev.0,
                    // `prefer` marks the favorite parent's device winning
                    // the est tie — the SCT relation at work.
                    reason: if entry.prefer {
                        crate::explain::DecisionReason::SctFavoriteChild
                    } else {
                        crate::explain::DecisionReason::MinEst
                    },
                    candidates: st.explain_candidates(node),
                });
            }
            let newly_ready = st.commit(node, dev);
            awake[dev.0] = None;
            // Reserve the device for this op's favorite child — but only
            // if the child is already ready (reserving for a child whose
            // other inputs are pending would idle the device on an
            // unbounded start time) *and* the idle wait does not exceed
            // the communication the reservation saves. Under the SCT
            // assumption (ρ ≤ 1) the wait is always ≤ c_max, so this
            // degenerates to the classical rule; with ρ ≫ 1 (paper §5.3)
            // it prevents devices from parking on long transfers.
            if let Some(child) = fav.fav_child[node.0] {
                if !st.is_scheduled(child) && st.unscheduled_preds[child.0] == 0 {
                    let expiry = st.est(child, dev).unwrap_or(st.finish[node.0]);
                    // The communication avoided by keeping the child
                    // local: the cheapest link out of this device (the
                    // full uniform model on homogeneous clusters).
                    let saved = graph
                        .edge_bytes(node, child)
                        .map(|b| st.topology().min_time_from(dev.0, b))
                        .unwrap_or(0.0);
                    if expiry - st.device_free[dev.0] <= saved {
                        awake[dev.0] = Some(Awake { child, expiry });
                    }
                }
            }
            for r in newly_ready {
                push_all(&st, &mut heap, &fav, r);
            }
        }

        if !st.done() {
            let unplaced = graph
                .node_ids()
                .find(|&id| st.device_of[id.0].is_none())
                .unwrap();
            return Err(oom_error(graph, unplaced, &st.ledger));
        }
        finish_placement(&self.name(), graph, st, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpKind};
    use crate::profile::CommModel;

    fn unit_cluster(n: usize, mem: u64) -> Cluster {
        // bytes == seconds at unit bandwidth
        Cluster::homogeneous(n, mem, CommModel::new(0.0, 1.0).unwrap())
    }

    /// m-SCT keeps the favorite child local even on a heterogeneous
    /// topology, and prefers the intra-island device for the other child
    /// when inter-island links are slow.
    #[test]
    fn islands_shift_cut_edges_onto_fast_links() {
        use crate::topology::Topology;
        let mut g = OpGraph::new("isl");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 2.0;
        g.node_mut(c).compute = 2.0;
        for id in [a, b, c] {
            g.node_mut(id).mem = MemorySpec {
                params: 1,
                ..Default::default()
            };
        }
        g.add_edge(a, b, 2);
        g.add_edge(a, c, 2);
        let intra = CommModel::new(0.0, 10.0).unwrap(); // 0.2 s per edge
        let inter = CommModel::new(0.0, 0.5).unwrap(); // 4 s per edge
        let cluster = Cluster::homogeneous(4, 100, inter)
            .with_topology(Topology::nvlink_islands(4, 2, intra, inter).unwrap())
            .unwrap();
        let p = MSct::with_lp().place(&g, &cluster).unwrap();
        // Everything stays inside one island: a cross-island hop costs
        // 4 s while the off-device child pays only 0.2 s intra-island.
        let topo = cluster.effective_topology();
        for (x, y) in [(a, b), (a, c)] {
            assert!(
                !topo.is_cross_island(p.device(x).0, p.device(y).0),
                "edge {x}->{y} crosses islands: {:?}",
                p.device_of
            );
        }
        assert!(p.predicted_makespan <= 3.2 + 1e-9, "{}", p.predicted_makespan);
        // Acceptance: the ≥4× intra/inter gap measurably changes the
        // m-SCT placement vs the uniform cluster, where the 4 s hop
        // keeps both children serialized on a's device (makespan 5).
        let uniform = Cluster::homogeneous(4, 100, inter);
        let pu = MSct::with_lp().place(&g, &uniform).unwrap();
        assert_ne!(pu.device_of, p.device_of, "island gap must re-place");
        assert_eq!(pu.devices_used(), 1, "uniform: transfers too expensive");
        assert!(p.devices_used() >= 2, "islands: fast links get used");
    }

    /// Favorite child stays on the parent's device even when another
    /// device is idle (avoiding the expensive transfer).
    #[test]
    fn favorite_child_follows_parent() {
        let mut g = OpGraph::new("fav");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul); // heavy favorite child
        let c = g.add_node("c", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(b).compute = 2.0;
        g.node_mut(c).compute = 2.0;
        for id in [a, b, c] {
            g.node_mut(id).mem = MemorySpec {
                params: 1,
                ..Default::default()
            };
        }
        g.add_edge(a, b, 2); // 2 s transfer if split
        g.add_edge(a, c, 2);
        let cluster = unit_cluster(2, 100);
        let p = MSct::with_lp().place(&g, &cluster).unwrap();
        // b or c is the favorite and must share a's device.
        let fav_on_a = p.device(b) == p.device(a) || p.device(c) == p.device(a);
        assert!(fav_on_a);
        // makespan: a(1) + fav(2) local = 3; other child: transfer 2 after
        // queue + 2 compute ≤ 5... best schedule ≈ 5.
        assert!(p.predicted_makespan <= 5.0 + 1e-9, "{}", p.predicted_makespan);
    }

    /// Paper Fig. 1: with ample memory SCT packs 2 devices tightly; with
    /// M = 4 units it must spread but still succeeds, with slightly
    /// higher makespan. Single-device memory cannot hold everything.
    #[test]
    fn fig1_memory_constrained_succeeds() {
        let g = crate::models::linreg::fig1_graph();
        let unit = crate::models::linreg::FIG1_MEM_UNIT;
        // Unlimited memory.
        let free = MSct::with_lp()
            .place(&g, &unit_cluster(3, 1_000 * unit))
            .unwrap();
        // Constrained: 4 memory units per device (total graph = 11).
        let tight = MSct::with_lp().place(&g, &unit_cluster(3, 4 * unit)).unwrap();
        assert!(tight.predicted_makespan >= free.predicted_makespan);
        // must not blow up: within 2× of unconstrained
        assert!(
            tight.predicted_makespan <= 2.0 * free.predicted_makespan,
            "tight {} vs free {}",
            tight.predicted_makespan,
            free.predicted_makespan
        );
        // memory cap respected
        for (i, &peak) in tight.peak_memory.iter().enumerate() {
            assert!(peak <= 4 * unit, "device {i} peak {peak}");
        }
    }

    /// Device exclusion: ops spread across devices when memory forces it.
    #[test]
    fn oom_device_excluded() {
        let mut g = OpGraph::new("t");
        let mut prev = None;
        for i in 0..4 {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: 3,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 1);
            }
            prev = Some(id);
        }
        let p = MSct::default().place(&g, &unit_cluster(2, 6)).unwrap();
        assert_eq!(p.devices_used(), 2);
        for &peak in &p.peak_memory {
            assert!(peak <= 6);
        }
    }

    /// m-SCT and m-ETF both place the fused transformer; makespans are
    /// in the same ballpark (paper §5.3: comparable, either may win).
    #[test]
    fn comparable_to_metf_on_transformer() {
        let g = crate::models::transformer::transformer(
            crate::models::transformer::TransformerConfig::paper(8),
        );
        let opt = crate::optimizer::optimize(&g, &crate::optimizer::OptConfig::full());
        let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
        let sct = MSct::default().place(&opt.graph, &cluster).unwrap();
        let etf = super::super::metf::MEtf.place(&opt.graph, &cluster).unwrap();
        let ratio = sct.predicted_makespan / etf.predicted_makespan;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "sct {} vs etf {}",
            sct.predicted_makespan,
            etf.predicted_makespan
        );
    }

    /// All three placers respect colocation groups.
    #[test]
    fn colocation_respected() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = unit_cluster(2, 100);
        let p = MSct::with_heuristic().place(&g, &cluster).unwrap();
        for (_, members) in g.colocation_groups() {
            let d0 = p.device(members[0]);
            for &m in &members[1..] {
                assert_eq!(p.device(m), d0);
            }
        }
    }
}
