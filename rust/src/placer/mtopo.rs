//! m-TOPO: the topological-sort strawman placer (paper §2.2).
//!
//! Computes the load-balanced per-device cap
//! `Cap = Σᵢ dᵢ / n + maxᵢ dᵢ`, then walks the graph in topological order
//! filling device 0, then device 1, … until each device's permanent
//! memory reaches the cap. Colocation groups are honored by pinning a
//! group to the device of its first-placed member.

use super::sched::SchedState;
use super::{finish_placement, oom_error, Placement, Placer};
use crate::error::BaechiError;
use crate::graph::{DeviceId, OpGraph};
use crate::profile::Cluster;

/// The m-TOPO placer.
#[derive(Debug, Default, Clone, Copy)]
pub struct MTopo;

impl Placer for MTopo {
    fn name(&self) -> String {
        "m-topo".to_string()
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        let t0 = std::time::Instant::now();
        let order = graph.topo_order().ok_or(BaechiError::Cyclic)?;
        // Memory requirement dᵢ: what the op permanently holds.
        let d = |id: crate::graph::NodeId| graph.node(id).mem.permanent_training();
        let total: u64 = order.iter().map(|&i| d(i)).sum();
        let max_d: u64 = order.iter().map(|&i| d(i)).max().unwrap_or(0);
        let n = cluster.n() as u64;
        let cap = total / n + max_d;

        // Fill devices in topo order; the SchedState replays the schedule
        // (each device runs its ops in topological order — m-TOPO's
        // runtime semantics) and provides the memory ledger, which also
        // enforces colocation pinning.
        let mut st = SchedState::new(graph, cluster);
        let mut dev = 0usize;
        let mut filled: u64 = 0;
        for &id in &order {
            // Colocation pinning can override the fill device.
            let pinned = st.ledger.pinned_device(graph, id);
            let target = match pinned {
                Some(p) => p,
                None => {
                    // Advance while this op would push the current device
                    // past the cap (and a later device exists).
                    while dev + 1 < cluster.n() && filled + d(id) > cap {
                        dev += 1;
                        filled = 0;
                    }
                    DeviceId(dev)
                }
            };
            // Memory feasibility: try the target, then subsequent devices.
            let mut chosen = None;
            if st.est(id, target).is_some() {
                chosen = Some(target);
            } else if pinned.is_none() {
                for probe in 0..cluster.n() {
                    let cand = DeviceId((target.0 + probe + 1) % cluster.n());
                    if st.est(id, cand).is_some() {
                        chosen = Some(cand);
                        break;
                    }
                }
            }
            let chosen = chosen.ok_or_else(|| oom_error(graph, id, &st.ledger))?;
            st.commit(id, chosen);
            if pinned.is_none() && chosen.0 == dev {
                filled += d(id);
            }
        }
        finish_placement(&self.name(), graph, st, t0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpKind};
    use crate::profile::CommModel;

    fn chain_graph(n: usize, mem_each: u64) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 1.0;
            g.node_mut(id).mem = MemorySpec {
                params: mem_each,
                ..Default::default()
            };
            if let Some(p) = prev {
                g.add_edge(p, id, 1);
            }
            prev = Some(id);
        }
        g
    }

    #[test]
    fn splits_by_cap() {
        // 8 ops × 100 bytes on 4 devices: cap = 200 + 100 = 300 → 3,3,2.
        let g = chain_graph(8, 100);
        let cluster = Cluster::homogeneous(4, 10_000, CommModel::new(0.0, 1e9).unwrap());
        let p = MTopo.place(&g, &cluster).unwrap();
        let hist = p.device_histogram(4);
        assert_eq!(hist.iter().sum::<usize>(), 8);
        assert!(hist[0] >= 2 && hist[0] <= 3, "hist {:?}", hist);
        assert!(p.devices_used() >= 2);
    }

    #[test]
    fn topo_order_preserved_per_device() {
        let g = chain_graph(6, 10);
        let cluster = Cluster::homogeneous(2, 10_000, CommModel::new(0.0, 1e9).unwrap());
        let p = MTopo.place(&g, &cluster).unwrap();
        // chain: placement must be a prefix on dev0 and suffix on dev1
        let mut seen_dev1 = false;
        for id in g.topo_order().unwrap() {
            let d = p.device(id);
            if d == DeviceId(1) {
                seen_dev1 = true;
            } else {
                assert!(!seen_dev1, "device 0 op after device 1 op");
            }
        }
    }

    #[test]
    fn oom_when_cluster_too_small() {
        let g = chain_graph(4, 1000);
        let cluster = Cluster::homogeneous(2, 1500, CommModel::new(0.0, 1e9).unwrap());
        assert!(MTopo.place(&g, &cluster).is_err());
    }

    #[test]
    fn single_huge_op_on_emptier_device() {
        // One op larger than cap must still place (cap includes max dᵢ).
        let mut g = chain_graph(3, 10);
        let big = g.add_node("big", OpKind::MatMul);
        g.node_mut(big).mem = MemorySpec {
            params: 500,
            ..Default::default()
        };
        let first = g.node_ids().next().unwrap();
        g.add_edge(first, big, 1);
        let cluster = Cluster::homogeneous(2, 2000, CommModel::new(0.0, 1e9).unwrap());
        let p = MTopo.place(&g, &cluster).unwrap();
        assert_eq!(p.device_of.len(), 4);
    }

    #[test]
    fn makespan_positive_and_covers_compute() {
        let g = chain_graph(5, 10);
        let cluster = Cluster::homogeneous(2, 10_000, CommModel::new(0.0, 1e9).unwrap());
        let p = MTopo.place(&g, &cluster).unwrap();
        assert!(p.predicted_makespan >= 5.0, "{}", p.predicted_makespan);
    }
}
