//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! All `xla` types come from [`xla`], the in-repo API-compatible stub of
//! the xla_extension bindings (the offline build cannot link the native
//! runtime — swap that module for the real crate to execute artifacts).
//! The compile path (python/jax/pallas) emits HLO **text** — not
//! serialized protos, which xla_extension 0.5.1 rejects for jax ≥ 0.5
//! (64-bit instruction ids); `HloModuleProto::from_text_file` reassigns
//! ids and round-trips cleanly.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + `manifest.json` once; this module loads them.

pub mod artifact;
pub mod xla;

use std::sync::Arc;

/// Shared PJRT CPU client. Creating a client is expensive; executables
/// hold an `Arc` so device workers can share one.
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Construct a CPU PJRT client.
    pub fn cpu() -> crate::Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub(crate) fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_constructs() {
        let rt = Runtime::cpu().expect("pjrt cpu client");
        assert_eq!(rt.platform().to_lowercase(), "cpu".to_string());
        assert!(rt.device_count() >= 1);
    }
}
