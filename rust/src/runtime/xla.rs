//! API-compatible stand-in for the `xla` (xla_extension) PJRT bindings.
//!
//! The offline build cannot link the native XLA runtime, so this module
//! mirrors the handful of types and methods the crate touches:
//!
//! * [`Literal`] is **fully functional** host-side (f32 data + dims) —
//!   it backs [`crate::exec::HostTensor`] round-trips and the literal
//!   helpers in [`crate::runtime::artifact`].
//! * [`PjRtClient::cpu`] constructs (so clients/registries can be built
//!   and manifests validated), but [`PjRtClient::compile`] reports
//!   [`XlaError`]: executing AOT HLO artifacts needs the real backend.
//!
//! To light up the PJRT path, delete this module and add the real `xla`
//! crate as a dependency — every call site uses the same names and
//! signatures.

/// Error raised by the (stubbed) XLA layer.
#[derive(Debug, Clone, PartialEq)]
pub struct XlaError(pub String);

impl XlaError {
    fn backend_unavailable(what: &str) -> XlaError {
        XlaError(format!(
            "{what} requires the native XLA runtime, which this offline build stubs \
             (see runtime::xla module docs)"
        ))
    }
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

impl From<XlaError> for crate::BaechiError {
    fn from(e: XlaError) -> crate::BaechiError {
        crate::BaechiError::Runtime(e.to_string())
    }
}

/// Element types a [`Literal`] can be read back as (f32 only — the wire
/// format of every artifact in this repo).
pub trait Element: Sized {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Array shape (row-major dims).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Literal shape.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple,
}

/// Host-side tensor literal (f32, row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape; errors when the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn shape(&self) -> Result<Shape, XlaError> {
        Ok(Shape::Array(ArrayShape {
            dims: self.dims.clone(),
        }))
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Flatten a tuple literal. Only produced by executions, which the
    /// stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::backend_unavailable("tuple literals"))
    }
}

/// Parsed HLO module (text retained verbatim; the real crate reassigns
/// instruction ids here).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(module: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _module: module.clone(),
        }
    }
}

/// Device buffer handle (only ever produced by real executions).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::backend_unavailable("device buffers"))
    }
}

/// Compiled executable handle (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::backend_unavailable("executing HLO"))
    }
}

/// PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { platform: "cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::backend_unavailable("compiling HLO"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_readback() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = lit.reshape(&[2, 2]).unwrap();
        match m.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            other => panic!("unexpected shape {other:?}"),
        }
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_reshape() {
        let lit = Literal::vec1(&[7.5]).reshape(&[]).unwrap();
        match lit.shape().unwrap() {
            Shape::Array(a) => assert!(a.dims().is_empty()),
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu");
        let proto = HloModuleProto {
            text: "HloModule m".into(),
        };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("native XLA runtime"), "{err}");
    }
}
