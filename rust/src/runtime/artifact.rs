//! AOT artifact registry: `artifacts/manifest.json` + `*.hlo.txt` →
//! compiled PJRT executables.
//!
//! The manifest is written by `python/compile/aot.py` and maps each
//! exported function to its HLO file, input arity/shapes, and output
//! arity. All entries are lowered with `return_tuple=True`, so execution
//! always unwraps a tuple.

use super::{xla, Runtime};
use crate::error::BaechiError;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    /// Input tensor shapes (row-major dims; empty dims = scalar).
    pub input_shapes: Vec<Vec<usize>>,
    /// Number of outputs in the result tuple.
    pub num_outputs: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| BaechiError::io(format!("reading manifest in {}: {e}", dir.display())))?;
        let root = Json::parse(&text)?;
        let mut entries = BTreeMap::new();
        let arr = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| BaechiError::invalid("manifest missing 'artifacts' array"))?;
        for item in arr {
            let name = item
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| BaechiError::invalid("artifact missing name"))?
                .to_string();
            let file = item
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| BaechiError::invalid(format!("artifact {name} missing file")))?;
            let input_shapes = item
                .get("input_shapes")
                .and_then(|v| v.as_arr())
                .map(|shapes| {
                    shapes
                        .iter()
                        .map(|s| {
                            s.as_arr()
                                .map(|dims| {
                                    dims.iter().filter_map(|d| d.as_u64()).map(|d| d as usize).collect()
                                })
                                .unwrap_or_default()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let num_outputs = item
                .get("num_outputs")
                .and_then(|v| v.as_u64())
                .unwrap_or(1) as usize;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file: dir.join(file),
                    input_shapes,
                    num_outputs,
                },
            );
        }
        Ok(Manifest { entries })
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }
}

/// A compiled, ready-to-run executable.
pub struct LoadedExec {
    pub name: String,
    pub num_outputs: usize,
    exec: xla::PjRtLoadedExecutable,
}

impl LoadedExec {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let bufs = self.exec.execute::<xla::Literal>(inputs)?;
        let result = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True — always a tuple.
        Ok(result.to_tuple()?)
    }

    /// Execute and return the single output (asserts arity 1).
    pub fn run1(&self, inputs: &[xla::Literal]) -> crate::Result<xla::Literal> {
        let mut outs = self.run(inputs)?;
        if outs.len() != 1 {
            return Err(BaechiError::runtime(format!(
                "{}: expected 1 output, got {}",
                self.name,
                outs.len()
            )));
        }
        Ok(outs.pop().unwrap())
    }
}

/// Registry of compiled executables, loaded lazily from a manifest.
pub struct ArtifactRegistry {
    runtime: Runtime,
    manifest: Manifest,
    cache: std::sync::Mutex<BTreeMap<String, Arc<LoadedExec>>>,
}

impl ArtifactRegistry {
    /// Open `dir` (default: `$BAECHI_ARTIFACTS` or `artifacts/`).
    pub fn open(runtime: Runtime, dir: &Path) -> crate::Result<ArtifactRegistry> {
        let manifest = Manifest::load(dir)?;
        Ok(ArtifactRegistry {
            runtime,
            manifest,
            cache: std::sync::Mutex::new(BTreeMap::new()),
        })
    }

    /// Resolve the artifacts directory from the environment.
    pub fn default_dir() -> PathBuf {
        std::env::var("BAECHI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (compile) an executable by name, caching the result.
    pub fn load(&self, name: &str) -> crate::Result<Arc<LoadedExec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| BaechiError::invalid(format!("unknown artifact '{name}'")))?;
        let path = entry
            .file
            .to_str()
            .ok_or_else(|| BaechiError::invalid("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exec = self.runtime.client().compile(&comp)?;
        let loaded = Arc::new(LoadedExec {
            name: name.to_string(),
            num_outputs: entry.num_outputs,
            exec,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }
}

/// Convenience: build an f32 literal from data + shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> crate::Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    if numel as usize != data.len() {
        return Err(BaechiError::invalid("shape/data mismatch"));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Convenience: extract f32 data from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> crate::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join(format!("baechi_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "matmul", "file": "matmul.hlo.txt",
                 "input_shapes": [[2,3],[3,4]], "num_outputs": 1},
                {"name": "train_step", "file": "train_step.hlo.txt",
                 "input_shapes": [[8,8]], "num_outputs": 3}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.names(), vec!["matmul", "train_step"]);
        let e = &m.entries["matmul"];
        assert_eq!(e.input_shapes, vec![vec![2, 3], vec![3, 4]]);
        assert_eq!(m.entries["train_step"].num_outputs, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("baechi_no_such_dir_xyz");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(literal_f32(&[1.0], &[2, 2]).is_err());
    }
}
