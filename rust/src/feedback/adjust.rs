//! Turning a [`ContentionReport`] into effective per-link models.
//!
//! The adjustment is a queueing-theory-flavored heuristic: a transfer
//! crossing a contended link pays, on average, the wait the simulator
//! observed there, so the link behaves *as if* its latency were higher
//! and its bandwidth lower. Re-pricing the topology this way lets a
//! placement-time scheduler — which only models its own reservations —
//! anticipate the load every other transfer puts on the same link.
//!
//! The same arithmetic serves both comm modes: in sequential mode a
//! link's `blocked` seconds are serialized pre-start waits; in parallel
//! mode they are bandwidth-sharing *slowdown* (extra in-flight seconds
//! of flows bottlenecked on the link). Either way `blocked / transfers`
//! is the mean extra delay a transfer crossing the link experienced,
//! and `busy / (busy + blocked)` the fraction of demanded link-seconds
//! actually served.

use super::policy::ReplacementPolicy;
use crate::error::BaechiError;
use crate::profile::CommModel;
use crate::sim::ContentionReport;
use crate::topology::{Link, Topology};

/// Per-link degradation derived from one simulated step: added latency
/// (the observed mean queueing wait) and a bandwidth scale (the served
/// share of link-seconds). Apply with [`TopologyAdjustment::apply`] to
/// obtain the effective topology the next placement round prices
/// against.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyAdjustment {
    added_latency: Vec<f64>,
    bandwidth_scale: Vec<f64>,
}

impl TopologyAdjustment {
    /// Derive the adjustment from a contention report. `damping` scales
    /// the injected latency (1.0 = charge the full observed mean wait;
    /// smaller values converge more cautiously) uniformly across link
    /// kinds; [`TopologyAdjustment::for_topology`] adapts it per kind.
    ///
    /// Links that never made a transfer wait are left untouched, so an
    /// uncontended report yields a no-op adjustment.
    pub fn from_report(report: &ContentionReport, damping: f64) -> TopologyAdjustment {
        // A hostile damping (negative, NaN, infinite) would flow into
        // link latencies unvalidated — apply() builds CommModels
        // directly — so degrade it to 0 (latency injection off,
        // bandwidth scaling still applies).
        let damping = if damping.is_finite() && damping > 0.0 {
            damping
        } else {
            0.0
        };
        Self::build(report, |_| damping)
    }

    /// Kind-adaptive variant: each link's injected latency is damped by
    /// [`ReplacementPolicy::damping_for`] its kind in `topo` (NVLink
    /// observations charged in full, NIC trunk waits most cautiously).
    /// Errors with [`BaechiError::InvalidRequest`] when the report does
    /// not cover `topo`'s links — e.g. a measured report recorded
    /// against a different cluster.
    pub fn for_topology(
        report: &ContentionReport,
        policy: &ReplacementPolicy,
        topo: &Topology,
    ) -> crate::Result<TopologyAdjustment> {
        if report.links.len() != topo.n_links() {
            return Err(BaechiError::invalid(format!(
                "topology adjustment: report covers {} links but the topology has {}",
                report.links.len(),
                topo.n_links()
            )));
        }
        let links = topo.links();
        Ok(Self::build(report, |l| policy.damping_for(links[l].kind)))
    }

    fn build(report: &ContentionReport, damping_of: impl Fn(usize) -> f64) -> TopologyAdjustment {
        let n = report.links.len();
        let mut added_latency = vec![0.0; n];
        let mut bandwidth_scale = vec![1.0; n];
        for u in &report.links {
            if u.transfers == 0 || u.blocked <= 0.0 {
                continue;
            }
            // Mean per-transfer wait attributed to this link. The
            // simulator splits each wait across its path's links, so
            // re-summing the injected latencies along a path recovers
            // roughly the observed queueing delay — the cost the placer
            // never priced.
            added_latency[u.link] = damping_of(u.link) * u.blocked / u.transfers as f64;
            // Served share of link-seconds: busy / (busy + queued).
            // Zero-cost links (infinite bandwidth) stay infinite — the
            // added latency alone carries their queue cost.
            let share = u.busy / (u.busy + u.blocked);
            bandwidth_scale[u.link] = share.clamp(0.05, 1.0);
        }
        TopologyAdjustment {
            added_latency,
            bandwidth_scale,
        }
    }

    /// True when no link is degraded (nothing queued).
    pub fn is_noop(&self) -> bool {
        self.added_latency.iter().all(|&a| a == 0.0)
            && self.bandwidth_scale.iter().all(|&s| s == 1.0)
    }

    /// Latency injected on `link`, seconds.
    pub fn added_latency(&self, link: usize) -> f64 {
        self.added_latency[link]
    }

    /// Bandwidth scale applied to `link`, in `(0, 1]`.
    pub fn bandwidth_scale(&self, link: usize) -> f64 {
        self.bandwidth_scale[link]
    }

    /// Number of links this adjustment covers.
    pub fn n_links(&self) -> usize {
        self.added_latency.len()
    }

    /// Rebuild `topo` with every link's model degraded by this
    /// adjustment. Islands and device speed factors are preserved;
    /// pairwise effective models and contention paths are re-resolved,
    /// so traffic may also re-route around a degraded link. Adjusting a
    /// uniform topology yields an explicit (non-uniform) link graph.
    pub fn apply(&self, topo: &Topology) -> crate::Result<Topology> {
        if topo.n_links() != self.n_links() {
            return Err(BaechiError::invalid(format!(
                "topology adjustment covers {} links but the topology has {}",
                self.n_links(),
                topo.n_links()
            )));
        }
        let links: Vec<Link> = topo
            .links()
            .iter()
            .enumerate()
            .map(|(i, l)| Link {
                comm: CommModel {
                    latency: l.comm.latency + self.added_latency[i],
                    bandwidth: l.comm.bandwidth * self.bandwidth_scale[i],
                },
                ..*l
            })
            .collect();
        let islands = topo.islands().to_vec();
        Topology::from_links(
            topo.n(),
            topo.n_switches(),
            links,
            Some(islands),
            topo.speeds().map(|s| s.to_vec()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DeviceId, NodeId, OpGraph, OpKind};
    use crate::profile::Cluster;
    use crate::sim::{simulate, SimConfig};
    use std::collections::BTreeMap;

    fn trunk_report() -> (ContentionReport, Topology) {
        // Two cross-machine transfers queueing on the shared trunks.
        let mut g = OpGraph::new("trunk");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 10);
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let topo = Topology::two_tier(2, 2, intra, inter).unwrap();
        let cluster = Cluster::homogeneous(4, 1000, inter)
            .with_topology(topo.clone())
            .unwrap();
        let placement: BTreeMap<NodeId, DeviceId> = g
            .node_ids()
            .enumerate()
            .map(|(i, id)| (id, DeviceId(i)))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        (r.contention, topo)
    }

    #[test]
    fn contended_links_get_latency_and_bandwidth_penalties() {
        let (report, topo) = trunk_report();
        let adj = TopologyAdjustment::from_report(&report, 1.0);
        assert!(!adj.is_noop());
        let trunk: Vec<usize> = topo
            .path(0, 2)
            .iter()
            .filter(|l| topo.path(1, 3).contains(l))
            .copied()
            .collect();
        for &l in &trunk {
            // The waiter's 10 s split over its 4-link path gives each
            // trunk link blocked = 2.5 s; mean over 2 transfers = 1.25.
            assert!((adj.added_latency(l) - 1.25).abs() < 1e-9);
            // Served share = 20 / (20 + 2.5) = 8/9.
            assert!((adj.bandwidth_scale(l) - 8.0 / 9.0).abs() < 1e-9);
        }
        // Intra-machine links never queued: untouched.
        let intra_link = topo.path(0, 1)[0];
        assert_eq!(adj.added_latency(intra_link), 0.0);
        assert_eq!(adj.bandwidth_scale(intra_link), 1.0);
    }

    #[test]
    fn damping_scales_the_injection() {
        let (report, _) = trunk_report();
        let full = TopologyAdjustment::from_report(&report, 1.0);
        let half = TopologyAdjustment::from_report(&report, 0.5);
        for l in 0..full.n_links() {
            assert!((half.added_latency(l) - full.added_latency(l) / 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn kind_adaptive_damping_follows_the_policy() {
        use crate::feedback::ReplacementPolicy;
        use crate::topology::LinkKind;
        let (report, topo) = trunk_report();
        // Every contended link in the trunk scenario is NIC-kind (the
        // intra PCIe links never queue): with the default policy the
        // injection is half the uniform charge, while bandwidth scaling
        // (damping-independent) is untouched.
        let uniform = TopologyAdjustment::from_report(&report, 1.0);
        let policy = ReplacementPolicy::default();
        let adaptive = TopologyAdjustment::for_topology(&report, &policy, &topo).unwrap();
        for (u, l) in report.links.iter().zip(topo.links()) {
            if u.blocked > 0.0 {
                assert_eq!(l.kind, LinkKind::Nic, "contended link {}", u.link);
            }
        }
        for l in 0..uniform.n_links() {
            assert!(
                (adaptive.added_latency(l) - 0.5 * uniform.added_latency(l)).abs() < 1e-12,
                "link {l}"
            );
            assert_eq!(adaptive.bandwidth_scale(l), uniform.bandwidth_scale(l));
        }
        // An all-1.0 kind table reproduces the uniform adjustment.
        let flat = ReplacementPolicy::default().with_uniform_damping();
        let same = TopologyAdjustment::for_topology(&report, &flat, &topo).unwrap();
        assert_eq!(same, uniform);
        // A report for a different link set is a typed error.
        let other = Topology::uniform(2, CommModel::new(0.0, 1.0).unwrap());
        assert!(matches!(
            TopologyAdjustment::for_topology(&report, &policy, &other),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn apply_degrades_contended_pairs_only() {
        let (report, topo) = trunk_report();
        let adj = TopologyAdjustment::from_report(&report, 1.0);
        let adjusted = adj.apply(&topo).unwrap();
        // Cross-machine pairs got slower…
        assert!(adjusted.time(0, 2, 1000) > topo.time(0, 2, 1000));
        // …while intra-machine pairs are unchanged.
        assert!((adjusted.time(0, 1, 1000) - topo.time(0, 1, 1000)).abs() < 1e-12);
        // Structure is preserved.
        assert_eq!(adjusted.n(), topo.n());
        assert_eq!(adjusted.n_links(), topo.n_links());
        assert_eq!(adjusted.island_of(3), topo.island_of(3));
    }

    #[test]
    fn uncontended_report_is_noop_and_mismatch_is_typed() {
        let topo = Topology::uniform(2, CommModel::new(0.0, 1.0).unwrap());
        let report = ContentionReport::default();
        let adj = TopologyAdjustment::from_report(&report, 1.0);
        assert!(adj.is_noop());
        // Zero links vs the 2-link topology: typed error, not a panic.
        assert!(matches!(
            adj.apply(&topo),
            Err(crate::BaechiError::InvalidRequest(_))
        ));
    }
}
