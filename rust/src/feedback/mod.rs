//! Contention feedback: closing the sim → engine → placer loop.
//!
//! Baechi's headline result is that algorithmic placement is fast
//! enough to *re-run* (654×–206,000× faster than RL planners), yet a
//! single placement pass is built on an optimistic communication model:
//! the greedy placers commit one transfer at a time and never see the
//! aggregate queueing their own decisions induce on shared links (a NIC
//! trunk between machines, a host-mediated PCIe spoke). The execution
//! simulator *does* observe that queueing — per-link busy time,
//! blocked-seconds, and queue depths in
//! [`ContentionReport`](crate::sim::ContentionReport) — in **both**
//! comm modes: serialized waiter queueing in sequential mode, and
//! max-min fair flow *slowdown* (extra in-flight seconds below the
//! uncontended rate, attributed to the bottleneck link) in parallel
//! mode. Either way `blocked` means "seconds lost to the interconnect
//! versus running alone", so the loop below is mode-agnostic.
//!
//! This module feeds the observation back:
//!
//! * [`TopologyAdjustment`] degrades each link's effective
//!   communication model by the queueing delay measured on it (observed
//!   average wait becomes added latency; the queued share of
//!   link-seconds scales bandwidth down), producing a topology the
//!   placer prices honestly;
//! * [`ReplacementPolicy`] decides *when* re-placement is worth it
//!   (trunk-utilization and blocked-fraction triggers, a round budget,
//!   and a minimum improvement to keep iterating) and *how hard* to
//!   correct per link kind (NVLink observations charged in full, PCIe
//!   and NIC progressively damped — see
//!   [`ReplacementPolicy::damping_for`]);
//! * [`PlacementEngine::place_iterative`](crate::engine::PlacementEngine::place_iterative)
//!   runs the loop: place → simulate → adjust → re-place, judging every
//!   candidate on the *real* topology and keeping the best round. Each
//!   intermediate placement is cached under the adjusted topology's
//!   fingerprint, so repeating the loop (the serving scenario) is
//!   nearly free.

pub mod adjust;
pub mod policy;

pub use adjust::TopologyAdjustment;
pub use policy::{relative_gain, ReplacementPolicy, ReplacementRound};
