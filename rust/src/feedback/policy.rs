//! When is re-placement worth it, and how is each round reported.

use crate::sim::ContentionReport;
use crate::topology::LinkKind;

/// Trigger thresholds and budget for the iterative re-placement loop
/// ([`crate::engine::PlacementEngine::place_iterative`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplacementPolicy {
    /// Re-placement rounds after the single-shot baseline (0 = the loop
    /// degenerates to a plain `place`, bit-for-bit).
    pub max_rounds: usize,
    /// Re-place when some link's busy time reaches this fraction of the
    /// step (a saturated NIC trunk is the motivating case).
    pub trunk_utilization: f64,
    /// …or when total blocked seconds (serialized waits in
    /// sequential-comm mode, flow slowdown in parallel-comm mode) reach
    /// this fraction of the step time.
    pub blocked_fraction: f64,
    /// Keep iterating only while a round improves the best simulated
    /// makespan by at least this relative margin.
    pub min_improvement: f64,
    /// Global scale on the latency injected per round by
    /// [`crate::feedback::TopologyAdjustment`]; composed with the
    /// per-link-kind multipliers below (see
    /// [`ReplacementPolicy::damping_for`]).
    pub damping: f64,
    /// Per-link-kind damping multipliers, indexed NVLink / PCIe / NIC.
    /// NVLink queueing observations are point-to-point and reliable, so
    /// they are charged in full; PCIe waits are host-mediated and partly
    /// transient (0.7); NIC trunk waits swing hardest between rounds, so
    /// they get the most cautious correction (0.5) to keep the loop from
    /// oscillating traffic back and forth across machines.
    pub kind_damping: [f64; 3],
}

impl Default for ReplacementPolicy {
    fn default() -> ReplacementPolicy {
        ReplacementPolicy {
            max_rounds: 3,
            trunk_utilization: 0.5,
            blocked_fraction: 0.05,
            min_improvement: 1e-3,
            damping: 1.0,
            kind_damping: [1.0, 0.7, 0.5],
        }
    }
}

/// Slot of a link kind in [`ReplacementPolicy::kind_damping`].
fn kind_slot(kind: LinkKind) -> usize {
    match kind {
        LinkKind::NvLink => 0,
        LinkKind::Pcie => 1,
        LinkKind::Nic => 2,
    }
}

impl ReplacementPolicy {
    /// Default thresholds with an explicit round budget.
    pub fn rounds(max_rounds: usize) -> ReplacementPolicy {
        ReplacementPolicy {
            max_rounds,
            ..ReplacementPolicy::default()
        }
    }

    /// Override the trunk-utilization trigger.
    pub fn with_threshold(mut self, trunk_utilization: f64) -> ReplacementPolicy {
        self.trunk_utilization = trunk_utilization;
        self
    }

    /// Override the global damping factor.
    pub fn with_damping(mut self, damping: f64) -> ReplacementPolicy {
        self.damping = damping;
        self
    }

    /// Override the damping multiplier for one link kind.
    pub fn with_kind_damping(mut self, kind: LinkKind, damping: f64) -> ReplacementPolicy {
        self.kind_damping[kind_slot(kind)] = damping;
        self
    }

    /// Disable kind adaptation: every link kind is damped by the global
    /// factor alone (the pre-adaptive behavior).
    pub fn with_uniform_damping(mut self) -> ReplacementPolicy {
        self.kind_damping = [1.0, 1.0, 1.0];
        self
    }

    /// Effective damping for a link of `kind`: the global factor times
    /// the kind multiplier, sanitized to `[0, ∞)` (hostile values damp
    /// to 0 — latency injection off — rather than poisoning the
    /// topology).
    pub fn damping_for(&self, kind: LinkKind) -> f64 {
        let d = self.damping * self.kind_damping[kind_slot(kind)];
        if d.is_finite() && d > 0.0 {
            d
        } else {
            0.0
        }
    }

    /// Does the observed contention warrant another placement round?
    pub fn should_replace(&self, report: &ContentionReport) -> bool {
        report.max_utilization() >= self.trunk_utilization
            || report.blocked_fraction() >= self.blocked_fraction
    }

    /// Links this policy considers saturated in `report`.
    pub fn saturated_links(&self, report: &ContentionReport) -> Vec<usize> {
        report.saturated_links(self.trunk_utilization)
    }
}

/// Relative makespan recovered going from `baseline` to `current`
/// (0 for a degenerate baseline; negative when `current` is worse).
/// The single definition behind every "recovered X%" figure.
pub fn relative_gain(baseline: f64, current: f64) -> f64 {
    if baseline > 0.0 {
        (baseline - current) / baseline
    } else {
        0.0
    }
}

/// One round of the iterative loop, as recorded in
/// [`crate::engine::IterativePlacement::rounds`]. Round 0 is the
/// single-shot baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplacementRound {
    pub round: usize,
    /// Simulated makespan of this round's placement on the *real*
    /// topology. When `oom` is true this is the truncated time at
    /// which the simulation aborted, not a real step time.
    pub makespan: f64,
    /// This round's simulation ran out of memory (its makespan is
    /// partial and the round can never be adopted).
    pub oom: bool,
    /// Links the policy considered saturated in this round's step.
    pub saturated_links: Vec<usize>,
    /// Blocked-seconds fraction observed in this round's step.
    pub blocked_fraction: f64,
    /// Highest per-link utilization observed in this round's step.
    pub max_utilization: f64,
    /// Whether this round beat the best makespan before it and was
    /// adopted as the returned placement (always false for round 0;
    /// the policy's `min_improvement` margin only decides whether the
    /// loop keeps iterating).
    pub improved: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ContentionReport;

    #[test]
    fn quiet_report_does_not_trigger() {
        let p = ReplacementPolicy::default();
        assert!(!p.should_replace(&ContentionReport::default()));
    }

    #[test]
    fn builders_set_fields() {
        let p = ReplacementPolicy::rounds(5)
            .with_threshold(0.8)
            .with_damping(0.25);
        assert_eq!(p.max_rounds, 5);
        assert_eq!(p.trunk_utilization, 0.8);
        assert_eq!(p.damping, 0.25);
        let default = ReplacementPolicy::default();
        assert_eq!(p.blocked_fraction, default.blocked_fraction);
    }

    #[test]
    fn kind_damping_defaults_and_overrides() {
        let p = ReplacementPolicy::default();
        // NVLink charged in full, PCIe and NIC progressively damped.
        assert_eq!(p.damping_for(LinkKind::NvLink), 1.0);
        assert!((p.damping_for(LinkKind::Pcie) - 0.7).abs() < 1e-12);
        assert!((p.damping_for(LinkKind::Nic) - 0.5).abs() < 1e-12);
        // The global factor composes with the kind multiplier.
        let half = p.with_damping(0.5);
        assert!((half.damping_for(LinkKind::Nic) - 0.25).abs() < 1e-12);
        // Per-kind override.
        let custom = ReplacementPolicy::default().with_kind_damping(LinkKind::Nic, 0.9);
        assert!((custom.damping_for(LinkKind::Nic) - 0.9).abs() < 1e-12);
        assert!((custom.damping_for(LinkKind::Pcie) - 0.7).abs() < 1e-12);
        // Uniform mode restores the pre-adaptive behavior.
        let uniform = ReplacementPolicy::default().with_uniform_damping();
        for k in [LinkKind::NvLink, LinkKind::Pcie, LinkKind::Nic] {
            assert_eq!(uniform.damping_for(k), 1.0);
        }
        // Hostile values sanitize to 0, never NaN/negative.
        let bad = ReplacementPolicy::default().with_damping(f64::NAN);
        assert_eq!(bad.damping_for(LinkKind::Pcie), 0.0);
        let neg = ReplacementPolicy::default().with_kind_damping(LinkKind::Pcie, -3.0);
        assert_eq!(neg.damping_for(LinkKind::Pcie), 0.0);
    }

    #[test]
    fn blocked_fraction_alone_triggers() {
        let r = ContentionReport {
            makespan: 10.0,
            blocked_seconds: 2.0, // 20 % of the step spent queued
            ..ContentionReport::default()
        };
        let p = ReplacementPolicy::default();
        assert!(p.should_replace(&r));
        let quiet = ContentionReport::default();
        assert!(!p.with_threshold(2.0).should_replace(&quiet));
    }
}
