//! When is re-placement worth it, and how is each round reported.

use crate::sim::ContentionReport;

/// Trigger thresholds and budget for the iterative re-placement loop
/// ([`crate::engine::PlacementEngine::place_iterative`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplacementPolicy {
    /// Re-placement rounds after the single-shot baseline (0 = the loop
    /// degenerates to a plain `place`, bit-for-bit).
    pub max_rounds: usize,
    /// Re-place when some link's busy time reaches this fraction of the
    /// step (a saturated NIC trunk is the motivating case).
    pub trunk_utilization: f64,
    /// …or when total waiter-blocked seconds reach this fraction of the
    /// step time.
    pub blocked_fraction: f64,
    /// Keep iterating only while a round improves the best simulated
    /// makespan by at least this relative margin.
    pub min_improvement: f64,
    /// Scale on the latency injected per round by
    /// [`crate::feedback::TopologyAdjustment::from_report`].
    pub damping: f64,
}

impl Default for ReplacementPolicy {
    fn default() -> ReplacementPolicy {
        ReplacementPolicy {
            max_rounds: 3,
            trunk_utilization: 0.5,
            blocked_fraction: 0.05,
            min_improvement: 1e-3,
            damping: 1.0,
        }
    }
}

impl ReplacementPolicy {
    /// Default thresholds with an explicit round budget.
    pub fn rounds(max_rounds: usize) -> ReplacementPolicy {
        ReplacementPolicy {
            max_rounds,
            ..ReplacementPolicy::default()
        }
    }

    /// Override the trunk-utilization trigger.
    pub fn with_threshold(mut self, trunk_utilization: f64) -> ReplacementPolicy {
        self.trunk_utilization = trunk_utilization;
        self
    }

    /// Override the damping factor.
    pub fn with_damping(mut self, damping: f64) -> ReplacementPolicy {
        self.damping = damping;
        self
    }

    /// Does the observed contention warrant another placement round?
    pub fn should_replace(&self, report: &ContentionReport) -> bool {
        report.max_utilization() >= self.trunk_utilization
            || report.blocked_fraction() >= self.blocked_fraction
    }

    /// Links this policy considers saturated in `report`.
    pub fn saturated_links(&self, report: &ContentionReport) -> Vec<usize> {
        report.saturated_links(self.trunk_utilization)
    }
}

/// Relative makespan recovered going from `baseline` to `current`
/// (0 for a degenerate baseline; negative when `current` is worse).
/// The single definition behind every "recovered X%" figure.
pub fn relative_gain(baseline: f64, current: f64) -> f64 {
    if baseline > 0.0 {
        (baseline - current) / baseline
    } else {
        0.0
    }
}

/// One round of the iterative loop, as recorded in
/// [`crate::engine::IterativePlacement::rounds`]. Round 0 is the
/// single-shot baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplacementRound {
    pub round: usize,
    /// Simulated makespan of this round's placement on the *real*
    /// topology. When `oom` is true this is the truncated time at
    /// which the simulation aborted, not a real step time.
    pub makespan: f64,
    /// This round's simulation ran out of memory (its makespan is
    /// partial and the round can never be adopted).
    pub oom: bool,
    /// Links the policy considered saturated in this round's step.
    pub saturated_links: Vec<usize>,
    /// Blocked-seconds fraction observed in this round's step.
    pub blocked_fraction: f64,
    /// Highest per-link utilization observed in this round's step.
    pub max_utilization: f64,
    /// Whether this round beat the best makespan before it and was
    /// adopted as the returned placement (always false for round 0;
    /// the policy's `min_improvement` margin only decides whether the
    /// loop keeps iterating).
    pub improved: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ContentionReport;

    #[test]
    fn quiet_report_does_not_trigger() {
        let p = ReplacementPolicy::default();
        assert!(!p.should_replace(&ContentionReport::default()));
    }

    #[test]
    fn builders_set_fields() {
        let p = ReplacementPolicy::rounds(5)
            .with_threshold(0.8)
            .with_damping(0.25);
        assert_eq!(p.max_rounds, 5);
        assert_eq!(p.trunk_utilization, 0.8);
        assert_eq!(p.damping, 0.25);
        let default = ReplacementPolicy::default();
        assert_eq!(p.blocked_fraction, default.blocked_fraction);
    }

    #[test]
    fn blocked_fraction_alone_triggers() {
        let r = ContentionReport {
            makespan: 10.0,
            blocked_seconds: 2.0, // 20 % of the step spent queued
            ..ContentionReport::default()
        };
        let p = ReplacementPolicy::default();
        assert!(p.should_replace(&r));
        let quiet = ContentionReport::default();
        assert!(!p.with_threshold(2.0).should_replace(&quiet));
    }
}
