//! Per-link transfer contention state (paper §3.1.4, generalized).
//!
//! The paper's testbed moves tensors through host memory, so each device
//! performs one transfer at a time; with a [`Topology`](super::Topology)
//! the unit of contention becomes the **link**: a transfer occupies every
//! link on its path, disjoint NVLink pairs proceed in parallel, and
//! transfers sharing a NIC trunk queue behind each other. Two views of
//! the same model:
//!
//! * [`LinkTimes`] — placement-time: the earliest instant each link is
//!   free, consumed by the m-ETF/m-SCT scheduler when it reserves
//!   hypothetical transfers;
//! * [`LinkQueues`] — simulation-time: which links are mid-transfer plus
//!   the pending transfers waiting on each link, consumed by the
//!   event-driven execution simulator **in sequential-comm mode only**
//!   (in parallel-comm mode links are not exclusive: concurrent
//!   transfers share bandwidth max-min fairly via
//!   [`crate::sim::flows::FlowNet`] instead of queueing here).

/// Placement-time contention: earliest free instant per link.
#[derive(Debug, Clone)]
pub struct LinkTimes {
    free_at: Vec<f64>,
}

impl LinkTimes {
    pub fn new(n_links: usize) -> LinkTimes {
        LinkTimes {
            free_at: vec![0.0; n_links],
        }
    }

    /// Earliest instant ≥ `after` at which every link of `path` is free.
    pub fn earliest(&self, after: f64, path: &[usize]) -> f64 {
        let mut t = after;
        for &l in path {
            t = t.max(self.free_at[l]);
        }
        t
    }

    /// Reserve every link of `path` until `until`.
    pub fn reserve(&mut self, path: &[usize], until: f64) {
        for &l in path {
            self.free_at[l] = until;
        }
    }

    pub fn free_at(&self, link: usize) -> f64 {
        self.free_at[link]
    }
}

/// Simulation-time contention: busy flags plus per-link waiter queues.
#[derive(Debug, Clone)]
pub struct LinkQueues {
    busy: Vec<bool>,
    /// Pending transfer indices registered under each link they cross.
    waiters: Vec<Vec<usize>>,
}

impl LinkQueues {
    pub fn new(n_links: usize) -> LinkQueues {
        LinkQueues {
            busy: vec![false; n_links],
            waiters: vec![Vec::new(); n_links],
        }
    }

    /// True when no link of `path` is mid-transfer.
    pub fn all_free(&self, path: &[usize]) -> bool {
        path.iter().all(|&l| !self.busy[l])
    }

    /// Mark every link of `path` mid-transfer. In debug builds it is an
    /// error to acquire a link that is already held — callers must gate
    /// on [`LinkQueues::all_free`] first.
    pub fn acquire(&mut self, path: &[usize]) {
        for &l in path {
            debug_assert!(
                !self.busy[l],
                "LinkQueues::acquire: link {l} is already mid-transfer"
            );
            self.busy[l] = true;
        }
    }

    /// Release every link of `path`. In debug builds it is an error to
    /// release a link that is not currently held — an acquire/release
    /// asymmetry would let two transfers overlap on one link.
    pub fn release(&mut self, path: &[usize]) {
        for &l in path {
            debug_assert!(
                self.busy[l],
                "LinkQueues::release: link {l} released while not held"
            );
            self.busy[l] = false;
        }
    }

    /// Register a pending transfer under every link of its path.
    pub fn enqueue(&mut self, path: &[usize], transfer: usize) {
        for &l in path {
            self.waiters[l].push(transfer);
        }
    }

    /// The queue of transfers registered under `link`. Callers prune
    /// entries that have already started (lazy twin removal, mirroring
    /// the simulator's per-device pending lists).
    pub fn waiters_mut(&mut self, link: usize) -> &mut Vec<usize> {
        &mut self.waiters[link]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_times_fold_in_path_order() {
        let mut lt = LinkTimes::new(3);
        lt.reserve(&[0, 2], 5.0);
        assert_eq!(lt.earliest(1.0, &[0, 1]), 5.0);
        assert_eq!(lt.earliest(1.0, &[1]), 1.0);
        assert_eq!(lt.earliest(9.0, &[0, 2]), 9.0);
        assert_eq!(lt.free_at(1), 0.0);
        assert_eq!(lt.free_at(2), 5.0);
    }

    #[test]
    fn link_queues_acquire_release() {
        let mut lq = LinkQueues::new(3);
        assert!(lq.all_free(&[0, 1, 2]));
        lq.acquire(&[0, 2]);
        assert!(!lq.all_free(&[0, 1]));
        assert!(lq.all_free(&[1]));
        lq.release(&[0, 2]);
        assert!(lq.all_free(&[0, 1, 2]));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "released while not held")]
    fn release_of_idle_link_asserts_in_debug() {
        let mut lq = LinkQueues::new(2);
        lq.acquire(&[0]);
        lq.release(&[0, 1]); // link 1 was never acquired
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "already mid-transfer")]
    fn double_acquire_asserts_in_debug() {
        let mut lq = LinkQueues::new(2);
        lq.acquire(&[0, 1]);
        lq.acquire(&[1]);
    }

    #[test]
    fn waiters_register_under_every_link() {
        let mut lq = LinkQueues::new(2);
        lq.enqueue(&[0, 1], 7);
        lq.enqueue(&[1], 9);
        assert_eq!(lq.waiters_mut(0).as_slice(), &[7]);
        assert_eq!(lq.waiters_mut(1).as_slice(), &[7, 9]);
    }
}
