//! Device-topology subsystem: heterogeneous devices and a per-link
//! interconnect model.
//!
//! The paper fits one linear communication model `t(bytes) = a + b·bytes`
//! (§4.1) and assumes a homogeneous cluster. Real small clusters — the
//! paper's own target — have NVLink islands, PCIe hops through host
//! memory, and NICs between machines, where a uniform model mispredicts
//! both communication cost and makespan. A [`Topology`] describes the
//! cluster as a graph of typed [`Link`]s (NVLink / PCIe / NIC, each with
//! its own [`CommModel`]) between devices and internal switch vertices,
//! plus optional per-device compute-speed factors.
//!
//! At construction every device pair is resolved to an **effective**
//! communication model by shortest path over the link graph
//! (store-and-forward: latencies add, inverse bandwidths add), cached in
//! a dense pair matrix, together with the list of links the transfer
//! occupies — the [`contention`] model: in sequential-communication mode
//! (§3.1.4) each *link* carries one transfer at a time, so transfers
//! sharing a NIC trunk queue behind each other while disjoint NVLink
//! pairs proceed in parallel.
//!
//! [`Topology::uniform`] reproduces the pre-topology behavior exactly:
//! the pair matrix stores the single fitted model bit-for-bit and every
//! transfer occupies exactly its two endpoint host-links — the paper's
//! per-device transfer engine. Placement and simulation under a uniform
//! topology are therefore bit-identical to the legacy single-`CommModel`
//! path (property-tested in `tests/prop_invariants.rs`).

pub mod contention;
pub mod json;

use crate::error::BaechiError;
use crate::profile::CommModel;

/// Payload size used to weight links during shortest-path resolution.
/// 1 MiB sits in the flat part of the latency/bandwidth trade-off for
/// every interconnect we model; the *resulting* pair model is still an
/// affine function of bytes, only the route is pinned at this size.
pub const REF_BYTES: u64 = 1 << 20;

/// Physical flavor of an interconnect link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Direct GPU↔GPU link (fast, point-to-point).
    NvLink,
    /// PCIe hop, typically through host memory.
    Pcie,
    /// Network interface between machines.
    Nic,
}

impl LinkKind {
    pub fn name(&self) -> &'static str {
        match self {
            LinkKind::NvLink => "nvlink",
            LinkKind::Pcie => "pcie",
            LinkKind::Nic => "nic",
        }
    }

    pub fn parse(s: &str) -> crate::Result<LinkKind> {
        match s {
            "nvlink" => Ok(LinkKind::NvLink),
            "pcie" => Ok(LinkKind::Pcie),
            "nic" => Ok(LinkKind::Nic),
            other => Err(BaechiError::invalid(format!(
                "unknown link kind '{other}' (nvlink|pcie|nic)"
            ))),
        }
    }

}

/// One bidirectional link of the interconnect graph. Endpoints `a`/`b`
/// index devices (`0..n`) or internal switch vertices (`n..n+switches`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    pub kind: LinkKind,
    /// Cost of crossing this link alone.
    pub comm: CommModel,
}

/// Immutable description of a cluster's interconnect: typed links, the
/// all-pairs effective communication models they induce, per-device
/// compute-speed factors, and an island partition for visualization and
/// reporting. Construct via [`Topology::uniform`],
/// [`Topology::nvlink_islands`], [`Topology::two_tier`],
/// [`Topology::from_links`], or [`json::from_json`].
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    n: usize,
    n_switches: usize,
    links: Vec<Link>,
    /// Per-device speed factors (None = inherit the cluster's).
    speeds: Option<Vec<f64>>,
    /// Island id per device (NVLink-connected components by default).
    island: Vec<usize>,
    /// `Some(model)`: single-model cluster; the pair matrix holds this
    /// exact model so the legacy uniform path is reproduced bit-for-bit.
    uniform: Option<CommModel>,
    /// Dense `n×n` effective models, row-major `src*n + dst`.
    pair: Vec<CommModel>,
    /// Link indices a `src→dst` transfer occupies, row-major.
    paths: Vec<Vec<usize>>,
}

impl Topology {
    /// Homogeneous single-model topology: every device pair costs exactly
    /// `comm`, and a transfer occupies its two endpoints' host-links —
    /// the paper's one-transfer-at-a-time-per-device engine (§3.1.4).
    /// This reproduces `Cluster::homogeneous` behavior bit-for-bit.
    pub fn uniform(n: usize, comm: CommModel) -> Topology {
        // Physically a star through host memory: device d — host switch.
        // Each spoke carries half the end-to-end model so the generic
        // two-hop composition agrees with `comm`; the pair matrix stores
        // `comm` itself so the reduction is exact, not merely close.
        let host = n;
        let links: Vec<Link> = (0..n)
            .map(|d| Link {
                a: d,
                b: host,
                kind: LinkKind::Pcie,
                comm: CommModel {
                    latency: comm.latency / 2.0,
                    bandwidth: comm.bandwidth * 2.0,
                },
            })
            .collect();
        let mut paths = vec![Vec::new(); n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    paths[i * n + j] = vec![i, j];
                }
            }
        }
        Topology {
            n,
            n_switches: 1,
            links,
            speeds: None,
            island: vec![0; n],
            uniform: Some(comm),
            pair: vec![comm; n * n],
            paths,
        }
    }

    /// Islands of `island` devices joined by all-pairs NVLink (`intra`),
    /// with every device also hanging off a shared host switch over PCIe
    /// so that cross-island traffic costs `inter` end-to-end and
    /// serializes on each endpoint's host-link. The last island may be
    /// smaller when `island` does not divide `n`.
    pub fn nvlink_islands(
        n: usize,
        island: usize,
        intra: CommModel,
        inter: CommModel,
    ) -> crate::Result<Topology> {
        if n == 0 || island == 0 {
            return Err(BaechiError::invalid(format!(
                "nvlink_islands: need n ≥ 1 and island ≥ 1 (got n={n}, island={island})"
            )));
        }
        let host = n;
        let mut links = Vec::new();
        let groups = (n + island - 1) / island;
        for g in 0..groups {
            let lo = g * island;
            let hi = ((g + 1) * island).min(n);
            for i in lo..hi {
                for j in (i + 1)..hi {
                    links.push(Link {
                        a: i,
                        b: j,
                        kind: LinkKind::NvLink,
                        comm: intra,
                    });
                }
            }
        }
        let half = CommModel {
            latency: inter.latency / 2.0,
            bandwidth: inter.bandwidth * 2.0,
        };
        for d in 0..n {
            links.push(Link {
                a: d,
                b: host,
                kind: LinkKind::Pcie,
                comm: half,
            });
        }
        let islands: Vec<usize> = (0..n).map(|d| d / island).collect();
        Topology::from_links(n, 1, links, Some(islands), None)
    }

    /// `nodes` machines of `per_node` devices: all-pairs `intra` links
    /// within a machine, and one NIC trunk per machine to a core switch
    /// so that cross-machine traffic costs `inter` end-to-end and **all
    /// transfers leaving or entering a machine queue on its NIC**.
    pub fn two_tier(
        nodes: usize,
        per_node: usize,
        intra: CommModel,
        inter: CommModel,
    ) -> crate::Result<Topology> {
        if nodes == 0 || per_node == 0 {
            return Err(BaechiError::invalid(format!(
                "two_tier: need nodes ≥ 1 and per_node ≥ 1 (got {nodes}, {per_node})"
            )));
        }
        let n = nodes.checked_mul(per_node).ok_or_else(|| {
            BaechiError::invalid(format!("two_tier: {nodes} × {per_node} devices overflows"))
        })?;
        let nic = |m: usize| n + m; // per-machine NIC switch
        let core = n + nodes;
        let mut links = Vec::new();
        for m in 0..nodes {
            let lo = m * per_node;
            let hi = lo + per_node;
            for i in lo..hi {
                for j in (i + 1)..hi {
                    links.push(Link {
                        a: i,
                        b: j,
                        kind: LinkKind::Pcie,
                        comm: intra,
                    });
                }
            }
            // A cross-machine path crosses four links — spoke, trunk,
            // trunk, spoke — so each carries a quarter of the end-to-end
            // cost: latencies split across the two spokes, and every
            // link runs at 4× the pair bandwidth so the four inverse
            // bandwidths sum back to `inter` exactly. The NIC switch is
            // never a free intra-machine shortcut (two spokes cost a
            // full `inter`), and the trunk is the shared resource a
            // machine's cross-machine traffic queues on: exclusive in
            // sequential-comm mode, a finite 4× pipe that flows split
            // max-min fairly in parallel-comm mode.
            for d in lo..hi {
                links.push(Link {
                    a: d,
                    b: nic(m),
                    kind: LinkKind::Nic,
                    comm: CommModel {
                        latency: inter.latency / 2.0,
                        bandwidth: inter.bandwidth * 4.0,
                    },
                });
            }
            links.push(Link {
                a: nic(m),
                b: core,
                kind: LinkKind::Nic,
                comm: CommModel {
                    latency: 0.0,
                    bandwidth: inter.bandwidth * 4.0,
                },
            });
        }
        let islands: Vec<usize> = (0..n).map(|d| d / per_node).collect();
        Topology::from_links(n, nodes + 1, links, Some(islands), None)
    }

    /// General constructor: resolve all device pairs by shortest path
    /// (weighted by the cost of a [`REF_BYTES`] transfer) over the link
    /// graph. `islands` defaults to NVLink-connected components; `speeds`
    /// defaults to inheriting the cluster's device speeds. Errors with
    /// [`BaechiError::InvalidRequest`] on malformed or disconnected
    /// specs.
    pub fn from_links(
        n: usize,
        n_switches: usize,
        links: Vec<Link>,
        islands: Option<Vec<usize>>,
        speeds: Option<Vec<f64>>,
    ) -> crate::Result<Topology> {
        if n == 0 {
            return Err(BaechiError::invalid("topology: need at least one device"));
        }
        let v = n + n_switches;
        for (idx, l) in links.iter().enumerate() {
            if l.a >= v || l.b >= v {
                return Err(BaechiError::invalid(format!(
                    "topology: link {idx} endpoint out of range (vertices 0..{v})"
                )));
            }
            if l.a == l.b {
                return Err(BaechiError::invalid(format!(
                    "topology: link {idx} is a self-loop on vertex {}",
                    l.a
                )));
            }
        }
        if let Some(s) = &speeds {
            if s.len() != n {
                return Err(BaechiError::invalid(format!(
                    "topology: {} speeds for {n} devices",
                    s.len()
                )));
            }
            if let Some(bad) = s.iter().find(|x| !x.is_finite() || **x <= 0.0) {
                return Err(BaechiError::invalid(format!(
                    "topology: device speed must be positive and finite, got {bad}"
                )));
            }
        }
        let island = match islands {
            Some(i) => {
                if i.len() != n {
                    return Err(BaechiError::invalid(format!(
                        "topology: {} island ids for {n} devices",
                        i.len()
                    )));
                }
                // There cannot be more islands than devices; a huge id
                // would also blow up every `0..n_islands()` loop.
                if let Some(bad) = i.iter().find(|&&id| id >= n) {
                    return Err(BaechiError::invalid(format!(
                        "topology: island id {bad} out of range for {n} devices"
                    )));
                }
                i
            }
            None => nvlink_components(n, v, &links),
        };

        // Adjacency over devices + switches.
        let mut adj: Vec<Vec<(usize, usize)>> = vec![Vec::new(); v];
        for (idx, l) in links.iter().enumerate() {
            adj[l.a].push((l.b, idx));
            adj[l.b].push((l.a, idx));
        }

        let mut pair = vec![
            CommModel {
                latency: 0.0,
                bandwidth: f64::INFINITY,
            };
            n * n
        ];
        let mut paths = vec![Vec::new(); n * n];
        for src in 0..n {
            // O(V²) Dijkstra — clusters have a handful of vertices, and
            // the scan-based argmin is deterministic under cost ties
            // (lowest vertex id wins; first-found path kept).
            let mut dist = vec![f64::INFINITY; v];
            let mut prev_link = vec![usize::MAX; v];
            let mut done = vec![false; v];
            dist[src] = 0.0;
            loop {
                let mut u = usize::MAX;
                let mut best = f64::INFINITY;
                for x in 0..v {
                    if !done[x] && dist[x] < best {
                        best = dist[x];
                        u = x;
                    }
                }
                if u == usize::MAX {
                    break;
                }
                done[u] = true;
                for &(w, li) in &adj[u] {
                    let nd = dist[u] + links[li].comm.time(REF_BYTES);
                    if nd < dist[w] {
                        dist[w] = nd;
                        prev_link[w] = li;
                    }
                }
            }
            for dst in 0..n {
                if dst == src {
                    continue;
                }
                if !dist[dst].is_finite() {
                    return Err(BaechiError::invalid(format!(
                        "topology: no path between device {src} and device {dst}"
                    )));
                }
                let mut path = Vec::new();
                let mut cur = dst;
                while cur != src {
                    let li = prev_link[cur];
                    path.push(li);
                    cur = if links[li].a == cur {
                        links[li].b
                    } else {
                        links[li].a
                    };
                    if path.len() > links.len() {
                        return Err(BaechiError::invalid(
                            "topology: shortest-path walk did not terminate",
                        ));
                    }
                }
                path.reverse();
                let latency: f64 = path.iter().map(|&li| links[li].comm.latency).sum();
                let inv_bw: f64 = path.iter().map(|&li| 1.0 / links[li].comm.bandwidth).sum();
                pair[src * n + dst] = CommModel {
                    latency,
                    bandwidth: if inv_bw > 0.0 { 1.0 / inv_bw } else { f64::INFINITY },
                };
                paths[src * n + dst] = path;
            }
        }

        Ok(Topology {
            n,
            n_switches,
            links,
            speeds,
            island,
            uniform: None,
            pair,
            paths,
        })
    }

    /// Override per-device compute-speed factors.
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> crate::Result<Topology> {
        if speeds.len() != self.n {
            return Err(BaechiError::invalid(format!(
                "topology: {} speeds for {} devices",
                speeds.len(),
                self.n
            )));
        }
        if let Some(bad) = speeds.iter().find(|x| !x.is_finite() || **x <= 0.0) {
            return Err(BaechiError::invalid(format!(
                "topology: device speed must be positive and finite, got {bad}"
            )));
        }
        self.speeds = Some(speeds);
        Ok(self)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn n_switches(&self) -> usize {
        self.n_switches
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Number of contention resources (one per link).
    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// True for single-model topologies built via [`Topology::uniform`].
    pub fn is_uniform(&self) -> bool {
        self.uniform.is_some()
    }

    /// The single model of a uniform topology.
    pub fn uniform_model(&self) -> Option<CommModel> {
        self.uniform
    }

    /// Declared per-device speed factors (None = inherit the cluster's).
    pub fn speeds(&self) -> Option<&[f64]> {
        self.speeds.as_deref()
    }

    pub fn speed(&self, device: usize) -> f64 {
        self.speeds.as_ref().map(|s| s[device]).unwrap_or(1.0)
    }

    pub fn island_of(&self, device: usize) -> usize {
        self.island[device]
    }

    /// The full island partition, one id per device (dense, numbered by
    /// first appearance in device order).
    pub fn islands(&self) -> &[usize] {
        &self.island
    }

    pub fn n_islands(&self) -> usize {
        self.island.iter().copied().max().map(|m| m + 1).unwrap_or(0)
    }

    pub fn is_cross_island(&self, a: usize, b: usize) -> bool {
        self.island[a] != self.island[b]
    }

    /// Effective model for an ordered device pair (`src != dst`).
    pub fn pair(&self, src: usize, dst: usize) -> &CommModel {
        &self.pair[src * self.n + dst]
    }

    /// Transfer time `src → dst`; 0 for same-device or empty payloads.
    pub fn time(&self, src: usize, dst: usize, bytes: u64) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.pair[src * self.n + dst].time(bytes)
    }

    /// Links a `src → dst` transfer occupies (empty when `src == dst`).
    pub fn path(&self, src: usize, dst: usize) -> &[usize] {
        &self.paths[src * self.n + dst]
    }

    /// Cheapest transfer of `bytes` leaving `src` (the paper's "full
    /// communication" charge in App. B, generalized: urgent times charge
    /// the best-case link). Uniform topologies return the single model's
    /// time exactly.
    pub fn min_time_from(&self, src: usize, bytes: u64) -> f64 {
        if let Some(m) = self.uniform {
            return m.time(bytes);
        }
        let mut best = f64::INFINITY;
        for dst in 0..self.n {
            if dst != src {
                best = best.min(self.time(src, dst, bytes));
            }
        }
        if best.is_finite() {
            best
        } else {
            0.0 // single-device topology: transfers never happen
        }
    }

    /// A single representative model (for the SCT favorite-child LP and
    /// fused-edge pricing, which are device-pair-agnostic): the uniform
    /// model when there is one, otherwise the mean latency and harmonic
    /// mean bandwidth over all ordered pairs.
    pub fn representative(&self) -> CommModel {
        if let Some(m) = self.uniform {
            return m;
        }
        let mut latency = 0.0;
        let mut inv_bw = 0.0;
        let mut k = 0usize;
        for src in 0..self.n {
            for dst in 0..self.n {
                if src != dst {
                    let p = &self.pair[src * self.n + dst];
                    latency += p.latency;
                    inv_bw += 1.0 / p.bandwidth;
                    k += 1;
                }
            }
        }
        if k == 0 {
            return CommModel {
                latency: 0.0,
                bandwidth: f64::INFINITY,
            };
        }
        CommModel {
            latency: latency / k as f64,
            bandwidth: if inv_bw > 0.0 {
                k as f64 / inv_bw
            } else {
                f64::INFINITY
            },
        }
    }

    /// One-line human summary for tables and logs.
    pub fn describe(&self) -> String {
        if self.is_uniform() {
            format!("uniform ({} devices)", self.n)
        } else {
            format!(
                "{} devices, {} islands, {} links",
                self.n,
                self.n_islands(),
                self.links.len()
            )
        }
    }
}

/// Island partition = connected components over NVLink links (devices
/// not on any NVLink each form their own island), renumbered densely in
/// device order.
fn nvlink_components(n: usize, v: usize, links: &[Link]) -> Vec<usize> {
    let mut comp = vec![usize::MAX; v];
    let mut next = 0usize;
    for start in 0..v {
        if comp[start] != usize::MAX {
            continue;
        }
        comp[start] = next;
        let mut stack = vec![start];
        while let Some(u) = stack.pop() {
            for l in links {
                if l.kind != LinkKind::NvLink {
                    continue;
                }
                let other = if l.a == u {
                    l.b
                } else if l.b == u {
                    l.a
                } else {
                    continue;
                };
                if comp[other] == usize::MAX {
                    comp[other] = comp[u];
                    stack.push(other);
                }
            }
        }
        next += 1;
    }
    // Renumber by first appearance among devices.
    let mut remap = std::collections::BTreeMap::new();
    let mut island = Vec::with_capacity(n);
    for d in 0..n {
        let len = remap.len();
        let id = *remap.entry(comp[d]).or_insert(len);
        island.push(id);
    }
    island
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comm(lat: f64, bw: f64) -> CommModel {
        CommModel::new(lat, bw).unwrap()
    }

    #[test]
    fn uniform_pairs_are_exactly_the_model() {
        let m = comm(50e-6, 6e9);
        let t = Topology::uniform(4, m);
        assert!(t.is_uniform());
        assert_eq!(t.n_links(), 4);
        assert_eq!(t.n_islands(), 1);
        for i in 0..4 {
            for j in 0..4 {
                if i == j {
                    assert_eq!(t.time(i, j, 123), 0.0);
                } else {
                    // Bit-exact: the pair matrix stores the model itself.
                    assert_eq!(t.pair(i, j).latency.to_bits(), m.latency.to_bits());
                    assert_eq!(t.pair(i, j).bandwidth.to_bits(), m.bandwidth.to_bits());
                    assert_eq!(t.path(i, j), &[i, j], "endpoint host-links");
                }
            }
        }
        assert_eq!(t.min_time_from(0, 1 << 20).to_bits(), m.time(1 << 20).to_bits());
        assert_eq!(t.representative(), m);
    }

    #[test]
    fn nvlink_islands_cost_structure() {
        let intra = comm(5e-6, 50e9);
        let inter = comm(50e-6, 6e9);
        let t = Topology::nvlink_islands(4, 2, intra, inter).unwrap();
        assert!(!t.is_uniform());
        assert_eq!(t.n_islands(), 2);
        assert_eq!(t.island_of(0), 0);
        assert_eq!(t.island_of(3), 1);
        assert!(t.is_cross_island(1, 2));
        // Intra-island: the direct NVLink, single hop, exact.
        assert_eq!(t.pair(0, 1), &intra);
        assert_eq!(t.path(0, 1).len(), 1);
        // Cross-island: two PCIe half-hops composing to ≈ inter.
        let p = t.pair(0, 2);
        assert!((p.latency - inter.latency).abs() < 1e-12);
        assert!((p.bandwidth - inter.bandwidth).abs() / inter.bandwidth < 1e-9);
        assert_eq!(t.path(0, 2).len(), 2);
        // Disjoint cross-island pairs use disjoint links.
        let p02: Vec<usize> = t.path(0, 2).to_vec();
        let p13: Vec<usize> = t.path(1, 3).to_vec();
        assert!(p02.iter().all(|l| !p13.contains(l)));
        // A big payload is much faster intra-island.
        assert!(t.time(0, 1, 100 << 20) < t.time(0, 2, 100 << 20) / 4.0);
    }

    #[test]
    fn two_tier_shares_the_nic_trunk() {
        let intra = comm(1e-6, 10e9);
        let inter = comm(100e-6, 1e9);
        let t = Topology::two_tier(2, 2, intra, inter).unwrap();
        assert_eq!(t.n(), 4);
        assert_eq!(t.n_islands(), 2);
        // Cross-machine transfers from the same machine share links.
        let p02: Vec<usize> = t.path(0, 2).to_vec();
        let p13: Vec<usize> = t.path(1, 3).to_vec();
        assert!(
            p02.iter().any(|l| p13.contains(l)),
            "both cross-machine paths must cross the shared NIC trunks"
        );
        // End-to-end cost ≈ inter.
        let p = t.pair(0, 2);
        assert!((p.latency - inter.latency).abs() < 1e-12);
        assert!((p.bandwidth - inter.bandwidth).abs() / inter.bandwidth < 1e-9);
        // Intra-machine is the direct link.
        assert_eq!(t.pair(0, 1), &intra);
    }

    #[test]
    fn disconnected_topology_is_typed_error() {
        let links = vec![Link {
            a: 0,
            b: 1,
            kind: LinkKind::Pcie,
            comm: comm(0.0, 1e9),
        }];
        let err = Topology::from_links(3, 0, links, None, None).unwrap_err();
        assert!(matches!(err, BaechiError::InvalidRequest(_)), "{err}");
        assert!(err.to_string().contains("no path"), "{err}");
    }

    #[test]
    fn malformed_links_are_typed_errors() {
        let self_loop = vec![Link {
            a: 0,
            b: 0,
            kind: LinkKind::Pcie,
            comm: comm(0.0, 1e9),
        }];
        assert!(matches!(
            Topology::from_links(2, 0, self_loop, None, None),
            Err(BaechiError::InvalidRequest(_))
        ));
        let out_of_range = vec![Link {
            a: 0,
            b: 9,
            kind: LinkKind::Pcie,
            comm: comm(0.0, 1e9),
        }];
        assert!(matches!(
            Topology::from_links(2, 0, out_of_range, None, None),
            Err(BaechiError::InvalidRequest(_))
        ));
        assert!(matches!(
            Topology::uniform(2, comm(0.0, 1.0)).with_speeds(vec![1.0]),
            Err(BaechiError::InvalidRequest(_))
        ));
        assert!(matches!(
            Topology::uniform(2, comm(0.0, 1.0)).with_speeds(vec![1.0, 0.0]),
            Err(BaechiError::InvalidRequest(_))
        ));
    }

    #[test]
    fn default_islands_follow_nvlink_components() {
        // 0—1 NVLink, 2 alone, 3 alone: islands [0, 0, 1, 2].
        let links = vec![
            Link {
                a: 0,
                b: 1,
                kind: LinkKind::NvLink,
                comm: comm(1e-6, 50e9),
            },
            Link {
                a: 0,
                b: 4,
                kind: LinkKind::Pcie,
                comm: comm(1e-5, 6e9),
            },
            Link {
                a: 1,
                b: 4,
                kind: LinkKind::Pcie,
                comm: comm(1e-5, 6e9),
            },
            Link {
                a: 2,
                b: 4,
                kind: LinkKind::Pcie,
                comm: comm(1e-5, 6e9),
            },
            Link {
                a: 3,
                b: 4,
                kind: LinkKind::Pcie,
                comm: comm(1e-5, 6e9),
            },
        ];
        let t = Topology::from_links(4, 1, links, None, None).unwrap();
        assert_eq!(t.island_of(0), t.island_of(1));
        assert_ne!(t.island_of(1), t.island_of(2));
        assert_ne!(t.island_of(2), t.island_of(3));
        assert_eq!(t.n_islands(), 3);
    }

    #[test]
    fn speeds_validate_and_apply() {
        let t = Topology::uniform(2, comm(0.0, 1.0))
            .with_speeds(vec![1.0, 2.0])
            .unwrap();
        assert_eq!(t.speed(0), 1.0);
        assert_eq!(t.speed(1), 2.0);
        assert_eq!(t.speeds(), Some(&[1.0, 2.0][..]));
    }
}
