//! JSON load/save for arbitrary cluster topologies.
//!
//! Schema (all costs in seconds / bytes-per-second):
//!
//! ```json
//! {
//!   "devices": 4,                      // or [{"speed": 1.0}, ...]
//!   "switches": 1,                     // internal vertices, default 0
//!   "islands": [0, 0, 1, 1],           // optional; default: NVLink components
//!   "uniform": {"latency": 5e-5, "bandwidth": 6e9},   // shorthand, OR:
//!   "links": [
//!     {"a": 0, "b": 1, "kind": "nvlink", "latency": 5e-6, "bandwidth": 5e10},
//!     {"a": 0, "b": 4, "kind": "pcie",  "latency": 2.5e-5, "bandwidth": 1.2e10}
//!   ]
//! }
//! ```
//!
//! Link endpoints index devices (`0..devices`) then switches
//! (`devices..devices+switches`). The `"uniform"` shorthand builds
//! [`Topology::uniform`] — the bit-exact single-model cluster — and
//! ignores `links`/`switches`. Malformed specs produce
//! [`BaechiError::InvalidRequest`], never panics.

use super::{Link, LinkKind, Topology};
use crate::error::BaechiError;
use crate::profile::CommModel;
use crate::util::json::Json;

/// Upper bounds on untrusted spec sizes: the pair matrix is dense
/// (`devices²`), so an absurd count must be a typed error, not an
/// allocator abort. 1024 devices ≈ 25 MB of pair models — far beyond
/// any small-cluster placement target.
const MAX_DEVICES: usize = 1024;
const MAX_SWITCHES: usize = 1024;
const MAX_LINKS: usize = 1 << 16;

fn invalid(msg: impl Into<String>) -> BaechiError {
    BaechiError::invalid(format!("topology spec: {}", msg.into()))
}

fn get_f64(obj: &Json, key: &str, ctx: &str) -> crate::Result<f64> {
    obj.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| invalid(format!("{ctx}: missing numeric field '{key}'")))
}

fn get_usize(obj: &Json, key: &str, ctx: &str) -> crate::Result<usize> {
    let v = get_f64(obj, key, ctx)?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(invalid(format!("{ctx}: '{key}' must be a non-negative integer")));
    }
    Ok(v as usize)
}

fn comm_from(obj: &Json, ctx: &str) -> crate::Result<CommModel> {
    let latency = get_f64(obj, "latency", ctx)?;
    // An absent (or null) bandwidth means an infinite-bandwidth wiring
    // link (e.g. a two-tier NIC trunk whose cost sits on the device
    // hops) — `f64::INFINITY` cannot itself appear in JSON.
    match obj.get("bandwidth") {
        None | Some(Json::Null) => {
            if !latency.is_finite() || latency < 0.0 {
                return Err(invalid(format!(
                    "{ctx}: latency must be non-negative and finite, got {latency}"
                )));
            }
            Ok(CommModel {
                latency,
                bandwidth: f64::INFINITY,
            })
        }
        Some(_) => {
            let bandwidth = get_f64(obj, "bandwidth", ctx)?;
            CommModel::new(latency, bandwidth).map_err(|e| invalid(format!("{ctx}: {e}")))
        }
    }
}

/// Parse a topology from JSON text.
pub fn from_json_str(text: &str) -> crate::Result<Topology> {
    let doc = Json::parse(text).map_err(|e| invalid(e.to_string()))?;
    from_json(&doc)
}

/// Parse a topology from a JSON document.
pub fn from_json(doc: &Json) -> crate::Result<Topology> {
    let devices = doc
        .get("devices")
        .ok_or_else(|| invalid("missing 'devices'"))?;
    let (n, speeds): (usize, Option<Vec<f64>>) = match devices {
        Json::Num(_) => (get_usize(doc, "devices", "topology")?, None),
        Json::Arr(arr) => {
            let mut speeds = Vec::with_capacity(arr.len());
            for (i, d) in arr.iter().enumerate() {
                if d.as_obj().is_none() {
                    return Err(invalid(format!(
                        "device {i} must be an object like {{\"speed\": 1.0}}"
                    )));
                }
                let s = match d.get("speed") {
                    Some(v) => v
                        .as_f64()
                        .ok_or_else(|| invalid(format!("device {i}: 'speed' must be a number")))?,
                    None => 1.0,
                };
                speeds.push(s);
            }
            (arr.len(), Some(speeds))
        }
        _ => return Err(invalid("'devices' must be a count or an array")),
    };
    if n == 0 {
        return Err(invalid("need at least one device"));
    }
    if n > MAX_DEVICES {
        return Err(invalid(format!("{n} devices exceeds the {MAX_DEVICES} limit")));
    }

    let islands = match doc.get("islands") {
        None => None,
        Some(Json::Arr(arr)) => {
            let mut v = Vec::with_capacity(arr.len());
            for (i, x) in arr.iter().enumerate() {
                let id = x
                    .as_f64()
                    .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                    .ok_or_else(|| invalid(format!("islands[{i}] must be a non-negative integer")))?;
                v.push(id as usize);
            }
            Some(v)
        }
        Some(_) => return Err(invalid("'islands' must be an array of integers")),
    };

    // Uniform shorthand: the bit-exact single-model topology.
    if let Some(u) = doc.get("uniform") {
        let comm = comm_from(u, "uniform")?;
        let mut t = Topology::uniform(n, comm);
        if let Some(s) = speeds {
            t = t.with_speeds(s)?;
        }
        if let Some(i) = islands {
            if i.len() != n {
                return Err(invalid(format!("{} island ids for {n} devices", i.len())));
            }
            if let Some(bad) = i.iter().find(|&&id| id >= n) {
                return Err(invalid(format!(
                    "island id {bad} out of range for {n} devices"
                )));
            }
            t.island = i;
        }
        return Ok(t);
    }

    let n_switches = match doc.get("switches") {
        None => 0,
        Some(_) => get_usize(doc, "switches", "topology")?,
    };
    if n_switches > MAX_SWITCHES {
        return Err(invalid(format!(
            "{n_switches} switches exceeds the {MAX_SWITCHES} limit"
        )));
    }
    let raw_links = doc
        .get("links")
        .and_then(Json::as_arr)
        .ok_or_else(|| invalid("missing 'links' array (or a 'uniform' shorthand)"))?;
    if raw_links.len() > MAX_LINKS {
        return Err(invalid(format!(
            "{} links exceeds the {MAX_LINKS} limit",
            raw_links.len()
        )));
    }
    let mut links = Vec::with_capacity(raw_links.len());
    for (i, l) in raw_links.iter().enumerate() {
        let ctx = format!("link {i}");
        let kind = l
            .get("kind")
            .and_then(Json::as_str)
            .map(LinkKind::parse)
            .transpose()
            .map_err(|e| invalid(format!("{ctx}: {e}")))?
            .unwrap_or(LinkKind::Pcie);
        links.push(Link {
            a: get_usize(l, "a", &ctx)?,
            b: get_usize(l, "b", &ctx)?,
            kind,
            comm: comm_from(l, &ctx)?,
        });
    }
    Topology::from_links(n, n_switches, links, islands, speeds)
}

/// Serialize a topology back to the schema above (round-trips through
/// [`from_json`] to an equal topology).
pub fn to_json(t: &Topology) -> Json {
    let mut doc = Json::obj();
    match t.speeds() {
        Some(speeds) => {
            let devs: Vec<Json> = speeds
                .iter()
                .map(|&s| {
                    let mut d = Json::obj();
                    d.set("speed", s);
                    d
                })
                .collect();
            doc.set("devices", Json::Arr(devs));
        }
        None => {
            doc.set("devices", t.n());
        }
    }
    doc.set(
        "islands",
        Json::Arr((0..t.n()).map(|d| Json::from(t.island_of(d))).collect()),
    );
    if let Some(m) = t.uniform_model() {
        let mut u = Json::obj();
        u.set("latency", m.latency);
        if m.bandwidth.is_finite() {
            u.set("bandwidth", m.bandwidth);
        }
        doc.set("uniform", u);
        return doc;
    }
    doc.set("switches", t.n_switches());
    let links: Vec<Json> = t
        .links()
        .iter()
        .map(|l| {
            let mut j = Json::obj();
            j.set("a", l.a)
                .set("b", l.b)
                .set("kind", l.kind.name())
                .set("latency", l.comm.latency);
            if l.comm.bandwidth.is_finite() {
                j.set("bandwidth", l.comm.bandwidth);
            }
            j
        })
        .collect();
    doc.set("links", Json::Arr(links));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_shorthand_is_bit_exact() {
        let t = from_json_str(
            r#"{"devices": 4, "uniform": {"latency": 5e-5, "bandwidth": 6e9}}"#,
        )
        .unwrap();
        assert!(t.is_uniform());
        let m = t.uniform_model().unwrap();
        assert_eq!(m.latency.to_bits(), 5e-5f64.to_bits());
        assert_eq!(m.bandwidth.to_bits(), 6e9f64.to_bits());
        assert_eq!(t.pair(0, 3).latency.to_bits(), m.latency.to_bits());
    }

    #[test]
    fn explicit_links_round_trip() {
        let spec = r#"{
            "devices": [{"speed": 1.0}, {"speed": 1.0}, {"speed": 0.5}, {"speed": 0.5}],
            "switches": 1,
            "links": [
                {"a": 0, "b": 1, "kind": "nvlink", "latency": 5e-6, "bandwidth": 5e10},
                {"a": 2, "b": 3, "kind": "nvlink", "latency": 5e-6, "bandwidth": 5e10},
                {"a": 0, "b": 4, "kind": "pcie", "latency": 2.5e-5, "bandwidth": 1.2e10},
                {"a": 1, "b": 4, "kind": "pcie", "latency": 2.5e-5, "bandwidth": 1.2e10},
                {"a": 2, "b": 4, "kind": "pcie", "latency": 2.5e-5, "bandwidth": 1.2e10},
                {"a": 3, "b": 4, "kind": "pcie", "latency": 2.5e-5, "bandwidth": 1.2e10}
            ]
        }"#;
        let t = from_json_str(spec).unwrap();
        assert_eq!(t.n(), 4);
        assert_eq!(t.n_islands(), 2, "NVLink components define islands");
        assert_eq!(t.speed(2), 0.5);
        // Round trip preserves everything placement-relevant.
        let t2 = from_json(&to_json(&t)).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn malformed_specs_are_invalid_request() {
        for bad in [
            "{",                                              // syntax
            r#"{"links": []}"#,                               // no devices
            r#"{"devices": 0, "links": []}"#,                 // zero devices
            r#"{"devices": 2}"#,                              // no links/uniform
            r#"{"devices": 2, "links": [{"a": 0, "b": 1, "latency": 0.0, "bandwidth": -1.0}]}"#,
            r#"{"devices": 2, "links": [{"a": 0, "b": 5, "latency": 0.0, "bandwidth": 1e9}]}"#,
            r#"{"devices": 2, "islands": [0], "links": [{"a": 0, "b": 1, "latency": 0.0, "bandwidth": 1e9}]}"#,
            // Absurd sizes are typed errors, never allocator aborts.
            r#"{"devices": 200000, "uniform": {"latency": 5e-5, "bandwidth": 6e9}}"#,
            r#"{"devices": 2, "switches": 99999999, "links": [{"a": 0, "b": 1, "latency": 0.0, "bandwidth": 1e9}]}"#,
            // Island ids are bounded by the device count.
            r#"{"devices": 2, "islands": [0, 1000000000000], "links": [{"a": 0, "b": 1, "latency": 0.0, "bandwidth": 1e9}]}"#,
            // A devices *array* must hold objects, not a count.
            r#"{"devices": [4], "uniform": {"latency": 5e-5, "bandwidth": 6e9}}"#,
        ] {
            match from_json_str(bad) {
                Err(BaechiError::InvalidRequest(_)) => {}
                other => panic!("spec {bad:?}: expected InvalidRequest, got {other:?}"),
            }
        }
    }

    #[test]
    fn two_tier_round_trips_despite_infinite_trunk_bandwidth() {
        use crate::topology::Topology;
        let t = Topology::two_tier(
            2,
            2,
            CommModel::new(1e-6, 10e9).unwrap(),
            CommModel::new(100e-6, 1e9).unwrap(),
        )
        .unwrap();
        // The zero-cost trunk (infinite bandwidth) must survive a full
        // serialize → text → parse cycle.
        let text = to_json(&t).pretty();
        let t2 = from_json_str(&text).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn default_kind_is_pcie() {
        let t = from_json_str(
            r#"{"devices": 2, "links": [{"a": 0, "b": 1, "latency": 0.0, "bandwidth": 1e9}]}"#,
        )
        .unwrap();
        assert_eq!(t.links()[0].kind, LinkKind::Pcie);
    }
}
