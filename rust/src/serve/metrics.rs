//! Service-level metrics: counters and latency percentiles.
//!
//! The hot path touches only relaxed atomics plus one short-lived mutex
//! per completed request (the bounded latency reservoir); snapshots never
//! block serving.

use super::incremental::ServeMode;
use crate::engine::CacheStats;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Samples kept per latency reservoir; older samples are overwritten
/// ring-buffer style, so percentiles describe the recent window.
const LATENCY_WINDOW: usize = 4096;

#[derive(Debug, Default)]
struct Reservoir {
    samples: Vec<f64>,
    next: usize,
    count: u64,
    sum: f64,
}

impl Reservoir {
    fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }
}

/// Internal live counters shared between service workers.
pub(crate) struct MetricsInner {
    start: Instant,
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub deadline_misses: AtomicU64,
    pub cache_hits: AtomicU64,
    pub incremental: AtomicU64,
    pub full: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    lat_all: Mutex<Reservoir>,
    lat_incremental: Mutex<Reservoir>,
    lat_full: Mutex<Reservoir>,
}

impl MetricsInner {
    pub fn new() -> MetricsInner {
        MetricsInner {
            start: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            incremental: AtomicU64::new(0),
            full: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            lat_all: Mutex::new(Reservoir::default()),
            lat_incremental: Mutex::new(Reservoir::default()),
            lat_full: Mutex::new(Reservoir::default()),
        }
    }

    pub fn record_latency(&self, mode: ServeMode, latency_s: f64) {
        self.lat_all.lock().unwrap().record(latency_s);
        match mode {
            ServeMode::Incremental { .. } => {
                self.lat_incremental.lock().unwrap().record(latency_s)
            }
            ServeMode::Full => self.lat_full.lock().unwrap().record(latency_s),
            ServeMode::CacheHit => {}
        }
    }

    pub fn snapshot(&self, engine_cache: CacheStats) -> ServiceMetrics {
        let all = self.lat_all.lock().unwrap();
        let uptime_s = self.start.elapsed().as_secs_f64();
        let completed = self.completed.load(Ordering::Relaxed);
        ServiceMetrics {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            incremental: self.incremental.load(Ordering::Relaxed),
            full: self.full.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            uptime_s,
            qps: completed as f64 / uptime_s.max(1e-9),
            mean_latency_s: all.mean(),
            p50_latency_s: all.percentile(50.0),
            p99_latency_s: all.percentile(99.0),
            incremental_mean_latency_s: self.lat_incremental.lock().unwrap().mean(),
            full_mean_latency_s: self.lat_full.lock().unwrap().mean(),
            engine_cache,
        }
    }
}

/// Point-in-time service metrics snapshot
/// ([`crate::serve::PlacementService::metrics`]).
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests answered successfully (any mode).
    pub completed: u64,
    /// Requests answered with an error (includes deadline misses).
    pub errors: u64,
    /// Requests dropped because their deadline passed before serving.
    pub deadline_misses: u64,
    /// Responses served from the engine's placement cache.
    pub cache_hits: u64,
    /// Responses produced by incremental (delta) placement.
    pub incremental: u64,
    /// Responses produced by a full pipeline run.
    pub full: u64,
    /// Micro-batches drained from the queue.
    pub batches: u64,
    /// Requests that arrived inside those batches (`/ batches` = mean
    /// batch size).
    pub batched_requests: u64,
    /// Seconds since the service started.
    pub uptime_s: f64,
    /// Completed requests per second of uptime.
    pub qps: f64,
    /// Mean submit-to-completion latency, seconds (lifetime).
    pub mean_latency_s: f64,
    /// Median latency over the recent window, seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile latency over the recent window, seconds.
    pub p99_latency_s: f64,
    /// Mean latency of incremental-mode responses, seconds.
    pub incremental_mean_latency_s: f64,
    /// Mean latency of full-mode responses, seconds.
    pub full_mean_latency_s: f64,
    /// The shared engine's cache counters at snapshot time.
    pub engine_cache: CacheStats,
}

impl ServiceMetrics {
    /// Fraction of completed responses served straight from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_hits as f64 / (self.completed.max(1)) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("errors", self.errors)
            .set("deadline_misses", self.deadline_misses)
            .set("cache_hits", self.cache_hits)
            .set("cache_hit_rate", self.cache_hit_rate())
            .set("incremental", self.incremental)
            .set("full", self.full)
            .set("batches", self.batches)
            .set("batched_requests", self.batched_requests)
            .set("uptime_s", self.uptime_s)
            .set("qps", self.qps)
            .set("mean_latency_s", self.mean_latency_s)
            .set("p50_latency_s", self.p50_latency_s)
            .set("p99_latency_s", self.p99_latency_s)
            .set("incremental_mean_latency_s", self.incremental_mean_latency_s)
            .set("full_mean_latency_s", self.full_mean_latency_s)
            .set("engine_cache_hits", self.engine_cache.hits)
            .set("engine_cache_misses", self.engine_cache.misses)
            .set("engine_cache_evictions", self.engine_cache.evictions);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_percentiles_and_mean() {
        let mut r = Reservoir::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert!((r.mean() - 50.5).abs() < 1e-9);
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(r.percentile(100.0), 100.0);
    }

    #[test]
    fn reservoir_window_overwrites_oldest() {
        let mut r = Reservoir::default();
        for _ in 0..LATENCY_WINDOW {
            r.record(1.0);
        }
        for _ in 0..LATENCY_WINDOW {
            r.record(9.0);
        }
        assert_eq!(r.percentile(50.0), 9.0, "old window fully displaced");
        assert_eq!(r.count, 2 * LATENCY_WINDOW as u64);
    }

    #[test]
    fn snapshot_reports_modes_and_hit_rate() {
        let m = MetricsInner::new();
        m.completed.store(10, Ordering::Relaxed);
        m.cache_hits.store(4, Ordering::Relaxed);
        m.record_latency(ServeMode::Full, 0.2);
        m.record_latency(ServeMode::Incremental { dirty_ops: 1 }, 0.01);
        m.record_latency(ServeMode::CacheHit, 0.001);
        let s = m.snapshot(CacheStats::default());
        assert_eq!(s.completed, 10);
        assert!((s.cache_hit_rate() - 0.4).abs() < 1e-9);
        assert!((s.full_mean_latency_s - 0.2).abs() < 1e-9);
        assert!((s.incremental_mean_latency_s - 0.01).abs() < 1e-9);
        assert!(s.mean_latency_s > 0.0);
        let j = s.to_json();
        assert!(j.get("qps").is_some());
        assert!(j.get("p99_latency_s").is_some());
    }
}
