//! Service-level metrics: counters and latency percentiles.
//!
//! The hot path touches only relaxed atomics plus one short-lived mutex
//! per completed request (the bounded latency reservoir); snapshots never
//! block serving.

use super::incremental::ServeMode;
use crate::engine::CacheStats;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Samples kept per latency reservoir; older samples are overwritten
/// ring-buffer style, so percentiles describe the recent window.
const LATENCY_WINDOW: usize = 4096;

/// Seconds of history behind [`ServiceMetrics::recent_qps`].
pub const RECENT_QPS_WINDOW_S: f64 = 30.0;

/// Bounded ring of `(latency, recorded_at)` samples; `recorded_at` is
/// seconds since service start, which makes the reservoir double as the
/// completion-time record behind `recent_qps`.
#[derive(Debug, Default)]
struct Reservoir {
    samples: Vec<(f64, f64)>,
    next: usize,
    count: u64,
    sum: f64,
}

impl Reservoir {
    fn record(&mut self, v: f64, at_s: f64) {
        self.count += 1;
        self.sum += v;
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push((v, at_s));
        } else {
            self.samples[self.next] = (v, at_s);
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.samples.iter().map(|&(l, _)| l).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Samples recorded at or after `since_s`. Bounded by the window
    /// size, so this under-counts (never over-counts) when more than
    /// [`LATENCY_WINDOW`] requests completed inside the interval.
    fn recorded_since(&self, since_s: f64) -> usize {
        self.samples.iter().filter(|&&(_, at)| at >= since_s).count()
    }
}

/// Internal live counters shared between service workers.
pub(crate) struct MetricsInner {
    start: Instant,
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub deadline_misses: AtomicU64,
    pub cache_hits: AtomicU64,
    pub incremental: AtomicU64,
    pub full: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    lat_all: Mutex<Reservoir>,
    lat_incremental: Mutex<Reservoir>,
    lat_full: Mutex<Reservoir>,
}

impl MetricsInner {
    pub fn new() -> MetricsInner {
        MetricsInner {
            start: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            incremental: AtomicU64::new(0),
            full: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            lat_all: Mutex::new(Reservoir::default()),
            lat_incremental: Mutex::new(Reservoir::default()),
            lat_full: Mutex::new(Reservoir::default()),
        }
    }

    pub fn record_latency(&self, mode: ServeMode, latency_s: f64) {
        let at_s = self.start.elapsed().as_secs_f64();
        self.lat_all.lock().unwrap().record(latency_s, at_s);
        match mode {
            ServeMode::Incremental { .. } => {
                self.lat_incremental.lock().unwrap().record(latency_s, at_s)
            }
            ServeMode::Full => self.lat_full.lock().unwrap().record(latency_s, at_s),
            ServeMode::CacheHit => {}
        }
    }

    pub fn snapshot(&self, engine_cache: CacheStats, explain: ExplainStats) -> ServiceMetrics {
        let all = self.lat_all.lock().unwrap();
        let uptime_s = self.start.elapsed().as_secs_f64();
        let completed = self.completed.load(Ordering::Relaxed);
        // Recent throughput from reservoir timestamps: unlike lifetime
        // qps this doesn't decay toward zero on a long-idle service.
        // The window can hold at most LATENCY_WINDOW samples, so a
        // burst past that rate yields a lower bound.
        let window_s = RECENT_QPS_WINDOW_S.min(uptime_s).max(1e-9);
        let recent = all.recorded_since(uptime_s - window_s);
        ServiceMetrics {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            incremental: self.incremental.load(Ordering::Relaxed),
            full: self.full.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            uptime_s,
            qps: completed as f64 / uptime_s.max(1e-9),
            recent_qps: recent as f64 / window_s,
            mean_latency_s: all.mean(),
            p50_latency_s: all.percentile(50.0),
            p99_latency_s: all.percentile(99.0),
            incremental_mean_latency_s: self.lat_incremental.lock().unwrap().mean(),
            full_mean_latency_s: self.lat_full.lock().unwrap().mean(),
            engine_cache,
            explain,
        }
    }
}

/// Explainability counters folded into the service snapshot: the
/// run-history flight recorder's totals
/// ([`crate::explain::record::RecorderStats`], zero when recording is
/// disabled) plus the process-wide decision-record count
/// ([`crate::explain::decisions_recorded`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExplainStats {
    /// Run records appended to the flight recorder.
    pub run_records: u64,
    /// Cumulative bytes of run history written (across rotations).
    pub run_record_bytes: u64,
    /// Times the run-history file was rotated.
    pub run_record_rotations: u64,
    /// Placement decisions captured by explain scopes, process-wide.
    pub decisions: u64,
    /// Critical-path category totals of the most recently recorded run
    /// (`None` until a simulated run lands in the flight recorder).
    pub critical_path: Option<crate::explain::record::AttributionTotals>,
}

/// Point-in-time service metrics snapshot
/// ([`crate::serve::PlacementService::metrics`]).
#[derive(Debug, Clone)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests answered successfully (any mode).
    pub completed: u64,
    /// Requests answered with an error (includes deadline misses).
    pub errors: u64,
    /// Requests dropped because their deadline passed before serving.
    pub deadline_misses: u64,
    /// Responses served from the engine's placement cache.
    pub cache_hits: u64,
    /// Responses produced by incremental (delta) placement.
    pub incremental: u64,
    /// Responses produced by a full pipeline run.
    pub full: u64,
    /// Micro-batches drained from the queue.
    pub batches: u64,
    /// Requests that arrived inside those batches (`/ batches` = mean
    /// batch size).
    pub batched_requests: u64,
    /// Seconds since the service started.
    pub uptime_s: f64,
    /// Completed requests per second of uptime (lifetime average —
    /// decays toward zero while the service idles).
    pub qps: f64,
    /// Completed requests per second over the trailing
    /// [`RECENT_QPS_WINDOW_S`]-second window (a lower bound when the
    /// burst outruns the latency reservoir's capacity).
    pub recent_qps: f64,
    /// Mean submit-to-completion latency, seconds (lifetime).
    pub mean_latency_s: f64,
    /// Median latency over the recent window, seconds.
    pub p50_latency_s: f64,
    /// 99th-percentile latency over the recent window, seconds.
    pub p99_latency_s: f64,
    /// Mean latency of incremental-mode responses, seconds.
    pub incremental_mean_latency_s: f64,
    /// Mean latency of full-mode responses, seconds.
    pub full_mean_latency_s: f64,
    /// The shared engine's cache counters at snapshot time.
    pub engine_cache: CacheStats,
    /// Explainability counters (run history + decision records).
    pub explain: ExplainStats,
}

impl ServiceMetrics {
    /// Fraction of completed responses served straight from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_hits as f64 / (self.completed.max(1)) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("errors", self.errors)
            .set("deadline_misses", self.deadline_misses)
            .set("cache_hits", self.cache_hits)
            .set("cache_hit_rate", self.cache_hit_rate())
            .set("incremental", self.incremental)
            .set("full", self.full)
            .set("batches", self.batches)
            .set("batched_requests", self.batched_requests)
            .set("uptime_s", self.uptime_s)
            .set("qps", self.qps)
            .set("recent_qps", self.recent_qps)
            .set("mean_latency_s", self.mean_latency_s)
            .set("p50_latency_s", self.p50_latency_s)
            .set("p99_latency_s", self.p99_latency_s)
            .set("incremental_mean_latency_s", self.incremental_mean_latency_s)
            .set("full_mean_latency_s", self.full_mean_latency_s)
            .set("engine_cache_hits", self.engine_cache.hits)
            .set("engine_cache_misses", self.engine_cache.misses)
            .set("engine_cache_evictions", self.engine_cache.evictions)
            .set("run_records", self.explain.run_records)
            .set("run_record_bytes", self.explain.run_record_bytes)
            .set("run_record_rotations", self.explain.run_record_rotations)
            .set("explain_decisions", self.explain.decisions);
        if let Some(a) = self.explain.critical_path {
            let mut o = Json::obj();
            o.set("compute", a.compute)
                .set("transfer", a.transfer)
                .set("queue_wait", a.queue_wait)
                .set("idle", a.idle);
            j.set("critical_path", o);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_percentiles_and_mean() {
        let mut r = Reservoir::default();
        for i in 1..=100 {
            r.record(i as f64, 0.0);
        }
        assert!((r.mean() - 50.5).abs() < 1e-9);
        assert!((r.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((r.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(r.percentile(100.0), 100.0);
    }

    #[test]
    fn reservoir_window_overwrites_oldest() {
        let mut r = Reservoir::default();
        for _ in 0..LATENCY_WINDOW {
            r.record(1.0, 0.0);
        }
        for _ in 0..LATENCY_WINDOW {
            r.record(9.0, 1.0);
        }
        assert_eq!(r.percentile(50.0), 9.0, "old window fully displaced");
        assert_eq!(r.count, 2 * LATENCY_WINDOW as u64);
    }

    #[test]
    fn reservoir_counts_recent_samples_by_timestamp() {
        let mut r = Reservoir::default();
        for i in 0..10 {
            r.record(0.01, i as f64);
        }
        assert_eq!(r.recorded_since(0.0), 10);
        assert_eq!(r.recorded_since(5.0), 5);
        assert_eq!(r.recorded_since(9.5), 0);
    }

    #[test]
    fn snapshot_reports_modes_and_hit_rate() {
        let m = MetricsInner::new();
        m.completed.store(10, Ordering::Relaxed);
        m.cache_hits.store(4, Ordering::Relaxed);
        m.record_latency(ServeMode::Full, 0.2);
        m.record_latency(ServeMode::Incremental { dirty_ops: 1 }, 0.01);
        m.record_latency(ServeMode::CacheHit, 0.001);
        let s = m.snapshot(CacheStats::default(), ExplainStats::default());
        assert_eq!(s.completed, 10);
        assert!((s.cache_hit_rate() - 0.4).abs() < 1e-9);
        assert!((s.full_mean_latency_s - 0.2).abs() < 1e-9);
        assert!((s.incremental_mean_latency_s - 0.01).abs() < 1e-9);
        assert!(s.mean_latency_s > 0.0);
        let j = s.to_json();
        assert!(j.get("qps").is_some());
        assert!(j.get("recent_qps").is_some());
        assert!(j.get("p99_latency_s").is_some());
    }

    #[test]
    fn recent_qps_counts_window_samples_and_ignores_decay() {
        let m = MetricsInner::new();
        // 3 fresh completions: all inside the 30 s window, and the
        // service has been up well under 30 s, so recent_qps divides
        // by the (short) uptime — it must come out positive and at
        // least as large as the lifetime figure.
        for _ in 0..3 {
            m.record_latency(ServeMode::Full, 0.001);
        }
        m.completed.store(3, Ordering::Relaxed);
        let s = m.snapshot(CacheStats::default(), ExplainStats::default());
        assert!(s.recent_qps > 0.0);
        assert!(s.recent_qps >= s.qps * 0.99, "{} vs {}", s.recent_qps, s.qps);
    }

    #[test]
    fn cache_hit_latency_lands_in_all_but_no_mode_reservoir() {
        let m = MetricsInner::new();
        m.record_latency(ServeMode::CacheHit, 0.002);
        assert_eq!(m.lat_all.lock().unwrap().count, 1);
        assert_eq!(m.lat_incremental.lock().unwrap().count, 0);
        assert_eq!(m.lat_full.lock().unwrap().count, 0);
        let s = m.snapshot(CacheStats::default(), ExplainStats::default());
        assert!((s.mean_latency_s - 0.002).abs() < 1e-12);
        assert_eq!(s.incremental_mean_latency_s, 0.0);
        assert_eq!(s.full_mean_latency_s, 0.0);
    }

    #[test]
    fn concurrent_hammer_keeps_counters_consistent_and_snapshot_alive() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;

        let m = Arc::new(MetricsInner::new());
        let stop = Arc::new(AtomicBool::new(false));
        const WRITERS: usize = 4;
        const ITERS: usize = 1500;

        std::thread::scope(|s| {
            let writers: Vec<_> = (0..WRITERS)
                .map(|w| {
                    let m = Arc::clone(&m);
                    s.spawn(move || {
                        for i in 0..ITERS {
                            // Protocol: `completed` is bumped BEFORE
                            // the per-mode counter, both with Release,
                            // so any reader that observes a mode
                            // increment (via Acquire) also observes
                            // its completion — `completed ≥ hits +
                            // incremental + full` holds at every
                            // instant.
                            m.submitted.fetch_add(1, Ordering::Release);
                            m.completed.fetch_add(1, Ordering::Release);
                            let mode = match (w + i) % 3 {
                                0 => {
                                    m.cache_hits.fetch_add(1, Ordering::Release);
                                    ServeMode::CacheHit
                                }
                                1 => {
                                    m.incremental.fetch_add(1, Ordering::Release);
                                    ServeMode::Incremental { dirty_ops: 1 }
                                }
                                _ => {
                                    m.full.fetch_add(1, Ordering::Release);
                                    ServeMode::Full
                                }
                            };
                            m.record_latency(mode, 1e-4 * (i % 7) as f64);
                        }
                    })
                })
                .collect();
            let reader = {
                let m = Arc::clone(&m);
                let stop = Arc::clone(&stop);
                s.spawn(move || {
                    let mut snapshots = 0u64;
                    // Do-while: at least one check runs even if the
                    // writers win every race to the finish line.
                    loop {
                        // Load the mode counters first (Acquire), then
                        // completed: see the writer protocol above.
                        let hits = m.cache_hits.load(Ordering::Acquire);
                        let inc = m.incremental.load(Ordering::Acquire);
                        let full = m.full.load(Ordering::Acquire);
                        let completed = m.completed.load(Ordering::Acquire);
                        assert!(
                            completed >= hits + inc + full,
                            "completed {completed} < modes {hits}+{inc}+{full}"
                        );
                        let snap = m.snapshot(CacheStats::default(), ExplainStats::default());
                        assert!(snap.completed <= (WRITERS * ITERS) as u64);
                        assert!(snap.mean_latency_s >= 0.0);
                        assert!(snap.p99_latency_s >= 0.0);
                        assert!(snap.qps >= 0.0 && snap.recent_qps >= 0.0);
                        snapshots += 1;
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    snapshots
                })
            };
            for w in writers {
                w.join().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
            let snapshots = reader.join().unwrap();
            assert!(snapshots > 0, "reader must have observed the writers");
        });

        // Quiesced: the counters add up exactly.
        let total = (WRITERS * ITERS) as u64;
        assert_eq!(m.completed.load(Ordering::Relaxed), total);
        assert_eq!(m.submitted.load(Ordering::Relaxed), total);
        assert_eq!(
            m.cache_hits.load(Ordering::Relaxed)
                + m.incremental.load(Ordering::Relaxed)
                + m.full.load(Ordering::Relaxed),
            total
        );
        assert_eq!(m.lat_all.lock().unwrap().count, total);
        let final_snap = m.snapshot(CacheStats::default(), ExplainStats::default());
        assert_eq!(final_snap.completed, total);
    }
}
