//! Placement as a service.
//!
//! The paper's headline result is placement *speed* — algorithmic
//! placement is 654×–206,000× faster than learning-based planners — which
//! only pays off at scale if the engine serves a sustained request stream
//! rather than one-shot CLI invocations. This layer is that service:
//!
//! * [`PlacementService`] wraps a shared [`crate::engine::PlacementEngine`]
//!   behind a bounded MPSC queue and a worker pool, with per-request
//!   deadlines and adaptive micro-batching of compatible requests (same
//!   cluster/topology fingerprint) through the engine's `place_batch`.
//! * **Incremental placement** ([`incremental`]): a request whose graph
//!   differs from the previously served version by a small delta (diffed
//!   via Merkle-style cone fingerprints) re-places only the dirty cone
//!   against the cached plan's frozen device assignments, falling back to
//!   full placement when the delta is too large or the patched plan
//!   regresses past the configured makespan tolerance.
//! * [`ServiceMetrics`] snapshots qps, p50/p99 latency, cache hit rate,
//!   and incremental-vs-full counts; `baechi serve-bench` drives the
//!   whole stack over mutated benchmark-graph streams.

pub mod incremental;
pub mod metrics;
pub mod service;

pub use incremental::{DeltaBase, IncrementalConfig, ServeMode};
pub use metrics::{ExplainStats, ServiceMetrics};
pub use service::{PlacementService, ServeOutcome, ServiceConfig, Ticket};
