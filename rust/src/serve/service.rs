//! The placement service: a bounded-queue worker pool over a shared
//! [`PlacementEngine`].
//!
//! Request lifecycle:
//!
//! 1. [`PlacementService::submit`] enqueues (blocking on a full queue —
//!    backpressure) or [`PlacementService::try_submit`] fails fast with
//!    [`BaechiError::Saturated`]. Each submission returns a [`Ticket`].
//! 2. A worker drains a micro-batch (up to `max_batch`, waiting at most
//!    `batch_window` for stragglers), then per request: expired deadline →
//!    typed error; engine cache → [`ServeMode::CacheHit`]; small delta vs
//!    the last served version of the same model → incremental placement;
//!    otherwise requests are grouped by topology fingerprint and fanned
//!    through the engine's `place_batch` ([`ServeMode::Full`]).
//! 3. [`Ticket::wait`] returns the [`ServeOutcome`] (response + mode +
//!    measured latency).
//!
//! When the engine's tracer is live, intake stamps a fresh trace id on
//! each request (unless the caller stamped one), workers book the
//! queue wait as a `queued` span under that trace, and every engine
//! stage span carries it — so an exported timeline shows one request
//! end to end. [`PlacementService::metrics_text`] renders the whole
//! metrics surface in Prometheus text format.

use super::incremental::{try_incremental, DeltaBase, IncrementalConfig, ServeMode};
use super::metrics::{MetricsInner, ServiceMetrics};
use crate::engine::{fingerprint, PlacementEngine, PlacementRequest, PlacementResponse};
use crate::error::BaechiError;
use crate::telemetry::tracer::TraceId;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads draining the queue (≥ 1).
    pub workers: usize,
    /// Bounded request-queue depth; a full queue blocks `submit` and
    /// fails `try_submit` with [`BaechiError::Saturated`].
    pub queue_capacity: usize,
    /// Max requests drained into one micro-batch (≥ 1).
    pub max_batch: usize,
    /// How long a worker waits for stragglers to fill a batch after the
    /// first request arrives. Zero (the default) means "batch whatever is
    /// already queued" — lowest latency, still adaptive under load
    /// because a busy queue is never empty.
    pub batch_window: Duration,
    /// Deadline applied to every submission unless overridden.
    pub default_deadline: Option<Duration>,
    /// Incremental-placement knobs.
    pub incremental: IncrementalConfig,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .clamp(1, 4),
            queue_capacity: 1024,
            max_batch: 16,
            batch_window: Duration::ZERO,
            default_deadline: None,
            incremental: IncrementalConfig::default(),
        }
    }
}

/// A served response plus how it was produced.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub response: Arc<PlacementResponse>,
    pub mode: ServeMode,
    /// Submit-to-completion latency, seconds.
    pub latency_s: f64,
}

struct Job {
    req: PlacementRequest,
    submitted: Instant,
    deadline: Option<Instant>,
    reply: std::sync::mpsc::Sender<crate::Result<ServeOutcome>>,
}

/// Handle to one in-flight request.
pub struct Ticket {
    rx: std::sync::mpsc::Receiver<crate::Result<ServeOutcome>>,
}

impl Ticket {
    /// Block until the request is served.
    pub fn wait(self) -> crate::Result<ServeOutcome> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(BaechiError::runtime(
                "placement service dropped the request (shutting down)",
            ))
        })
    }

    /// Block at most `timeout`; [`BaechiError::DeadlineExceeded`] if the
    /// response hasn't arrived by then (the request keeps running).
    pub fn wait_timeout(self, timeout: Duration) -> crate::Result<ServeOutcome> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Err(BaechiError::DeadlineExceeded {
                waited: timeout.as_secs_f64(),
            }),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Err(BaechiError::runtime(
                "placement service dropped the request (shutting down)",
            )),
        }
    }
}

struct Shared {
    engine: Arc<PlacementEngine>,
    cfg: ServiceConfig,
    metrics: MetricsInner,
    /// Workers take turns holding the receiver while gathering a batch.
    rx: Mutex<Receiver<Job>>,
    /// Last served graph version per model identity, for delta patching.
    bases: Mutex<BTreeMap<String, Arc<DeltaBase>>>,
}

/// A long-running placement service over a shared engine. Threads submit
/// concurrently; dropping (or [`PlacementService::shutdown`]) drains the
/// queue and joins the workers.
pub struct PlacementService {
    shared: Arc<Shared>,
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl PlacementService {
    pub fn new(engine: Arc<PlacementEngine>, cfg: ServiceConfig) -> crate::Result<PlacementService> {
        if cfg.workers == 0 {
            return Err(BaechiError::invalid("PlacementService: workers must be >= 1"));
        }
        if cfg.queue_capacity == 0 {
            return Err(BaechiError::invalid(
                "PlacementService: queue_capacity must be >= 1",
            ));
        }
        if cfg.max_batch == 0 {
            return Err(BaechiError::invalid("PlacementService: max_batch must be >= 1"));
        }
        let (tx, rx) = sync_channel(cfg.queue_capacity);
        let shared = Arc::new(Shared {
            engine,
            cfg: cfg.clone(),
            metrics: MetricsInner::new(),
            rx: Mutex::new(rx),
            bases: Mutex::new(BTreeMap::new()),
        });
        let workers = (0..cfg.workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("baechi-serve-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn placement-service worker")
            })
            .collect();
        Ok(PlacementService {
            shared,
            tx: Some(tx),
            workers,
        })
    }

    /// The engine this service fronts.
    pub fn engine(&self) -> &Arc<PlacementEngine> {
        &self.shared.engine
    }

    /// Snapshot of service + engine-cache + explainability metrics.
    pub fn metrics(&self) -> ServiceMetrics {
        let rec = self.shared.engine.recorder_stats().unwrap_or_default();
        let explain = super::metrics::ExplainStats {
            run_records: rec.records,
            run_record_bytes: rec.bytes,
            run_record_rotations: rec.rotations,
            decisions: crate::explain::decisions_recorded(),
            critical_path: self.shared.engine.last_attribution(),
        };
        self.shared
            .metrics
            .snapshot(self.shared.engine.cache_stats(), explain)
    }

    /// Prometheus text-format (0.0.4) exposition over the service
    /// metrics, the engine's cache counters, and the tracer's span
    /// counters — the body served by [`crate::telemetry::MetricsServer`].
    pub fn metrics_text(&self) -> String {
        crate::telemetry::prometheus::render_metrics(
            &self.metrics(),
            &self.shared.engine.tracer().stats(),
        )
    }

    /// Enqueue a request under the configured default deadline, blocking
    /// while the queue is full (backpressure).
    pub fn submit(&self, req: PlacementRequest) -> crate::Result<Ticket> {
        self.submit_with_deadline(req, self.shared.cfg.default_deadline)
    }

    /// Enqueue with an explicit deadline measured from now (`None` =
    /// no deadline). Blocks while the queue is full.
    pub fn submit_with_deadline(
        &self,
        req: PlacementRequest,
        deadline: Option<Duration>,
    ) -> crate::Result<Ticket> {
        let (job, ticket) = self.job(req, deadline);
        self.sender()?
            .send(job)
            .map_err(|_| BaechiError::runtime("placement service is shut down"))?;
        self.shared.metrics.submitted.fetch_add(1, Relaxed);
        Ok(ticket)
    }

    /// Non-blocking enqueue: [`BaechiError::Saturated`] when the queue is
    /// full, so callers can shed load instead of stalling.
    pub fn try_submit(&self, req: PlacementRequest) -> crate::Result<Ticket> {
        let (job, ticket) = self.job(req, self.shared.cfg.default_deadline);
        match self.sender()?.try_send(job) {
            Ok(()) => {
                self.shared.metrics.submitted.fetch_add(1, Relaxed);
                Ok(ticket)
            }
            Err(TrySendError::Full(_)) => Err(BaechiError::Saturated {
                capacity: self.shared.cfg.queue_capacity,
            }),
            Err(TrySendError::Disconnected(_)) => {
                Err(BaechiError::runtime("placement service is shut down"))
            }
        }
    }

    /// Submit and wait: the one-call serving API.
    pub fn place(&self, req: PlacementRequest) -> crate::Result<ServeOutcome> {
        self.submit(req)?.wait()
    }

    /// Stop accepting requests, drain the queue, and join the workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx = None; // closing the channel ends the worker loops
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    fn job(&self, mut req: PlacementRequest, deadline: Option<Duration>) -> (Job, Ticket) {
        // Trace intake: when telemetry is watching, stamp a fresh trace
        // id so the queue wait and every engine stage of this request
        // book under one id. A caller-stamped id is left alone.
        if req.trace.is_none() {
            req.trace = self
                .shared
                .engine
                .tracer()
                .active_trace_id()
                .map(|t| t.0);
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let now = Instant::now();
        (
            Job {
                req,
                submitted: now,
                deadline: deadline.map(|d| now + d),
                reply: tx,
            },
            Ticket { rx },
        )
    }

    fn sender(&self) -> crate::Result<&SyncSender<Job>> {
        self.tx
            .as_ref()
            .ok_or_else(|| BaechiError::runtime("placement service is shut down"))
    }
}

impl Drop for PlacementService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(shared: &Shared) {
    while let Some(batch) = gather(shared) {
        serve_batch(shared, batch);
    }
}

/// Drain one micro-batch. Blocks for the first job; then greedily takes
/// whatever is queued, waiting up to `batch_window` for more while the
/// batch is short. Holds the intake lock for the whole gather — with the
/// default zero window that is only as long as the queue has jobs ready,
/// so workers still serve in parallel.
fn gather(shared: &Shared) -> Option<Vec<Job>> {
    let cfg = &shared.cfg;
    let rx = shared.rx.lock().unwrap();
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let window_end = Instant::now() + cfg.batch_window;
    while batch.len() < cfg.max_batch {
        match rx.try_recv() {
            Ok(job) => batch.push(job),
            Err(TryRecvError::Disconnected) => break,
            Err(TryRecvError::Empty) => {
                let now = Instant::now();
                if now >= window_end {
                    break;
                }
                match rx.recv_timeout(window_end - now) {
                    Ok(job) => batch.push(job),
                    Err(_) => break,
                }
            }
        }
    }
    Some(batch)
}

fn serve_batch(shared: &Shared, batch: Vec<Job>) {
    let m = &shared.metrics;
    m.batches.fetch_add(1, Relaxed);
    m.batched_requests.fetch_add(batch.len() as u64, Relaxed);
    // Full placements grouped by topology-override fingerprint: only
    // requests placed against the same target share a `place_batch` call.
    let mut fulls: BTreeMap<u64, Vec<Job>> = BTreeMap::new();
    for job in batch {
        record_queue_wait(shared, &job);
        if let Some(d) = job.deadline {
            if Instant::now() >= d {
                m.deadline_misses.fetch_add(1, Relaxed);
                let waited = job.submitted.elapsed().as_secs_f64();
                finish(
                    shared,
                    job,
                    Err(BaechiError::DeadlineExceeded { waited }),
                    ServeMode::Full,
                );
                continue;
            }
        }
        // 1) Engine cache.
        match shared.engine.lookup(&job.req) {
            Ok(Some(hit)) => {
                m.cache_hits.fetch_add(1, Relaxed);
                // `lookup` bypasses `engine.place`, so the run history
                // is written here (the full path records engine-side).
                shared.engine.record_served(&job.req, &hit, "cache_hit");
                finish(shared, job, Ok(hit), ServeMode::CacheHit);
                continue;
            }
            Ok(None) => {}
            Err(e) => {
                finish(shared, job, Err(e), ServeMode::Full);
                continue;
            }
        }
        // 2) Incremental: patch against the last served version.
        if shared.cfg.incremental.enabled {
            let key = base_key(&job.req);
            let base = shared.bases.lock().unwrap().get(&key).cloned();
            if let Some(base) = base {
                if let Some(plan) =
                    try_incremental(&shared.engine, &job.req, &base, &shared.cfg.incremental)
                {
                    m.incremental.fetch_add(1, Relaxed);
                    if plan.dirty_ops > 0 {
                        let next = DeltaBase {
                            graph: job.req.graph.clone(),
                            cones: plan.cones,
                            response: Arc::clone(&plan.response),
                        };
                        shared.bases.lock().unwrap().insert(key, Arc::new(next));
                    }
                    let mode = ServeMode::Incremental {
                        dirty_ops: plan.dirty_ops,
                    };
                    shared
                        .engine
                        .record_served(&job.req, &plan.response, "incremental");
                    finish(shared, job, Ok(plan.response), mode);
                    continue;
                }
            }
        }
        // 3) Full pipeline.
        fulls.entry(compat_key(&job.req)).or_default().push(job);
    }
    for jobs in fulls.into_values() {
        let results = if jobs.len() > 1 {
            let reqs: Vec<PlacementRequest> = jobs.iter().map(|j| j.req.clone()).collect();
            shared.engine.place_batch(&reqs)
        } else {
            vec![shared.engine.place(&jobs[0].req)]
        };
        for (job, result) in jobs.into_iter().zip(results) {
            if let Ok(resp) = &result {
                m.full.fetch_add(1, Relaxed);
                remember_base(shared, &job.req, Arc::clone(resp));
            }
            finish(shared, job, result, ServeMode::Full);
        }
    }
}

/// Book the time this job spent in the intake queue as a `queued` span
/// on its trace (a no-op unless intake stamped one — i.e. unless the
/// tracer was live at submission).
fn record_queue_wait(shared: &Shared, job: &Job) {
    let Some(trace) = job.req.trace.filter(|&t| t != 0) else {
        return;
    };
    let tracer = shared.engine.tracer();
    if !tracer.is_live() {
        return;
    }
    let waited = job.submitted.elapsed().as_secs_f64();
    let end_s = tracer.now_s();
    tracer.record_at(
        TraceId(trace),
        None,
        "queued",
        &job.req.placer,
        end_s - waited,
        end_s,
        0,
        0,
    );
}

fn finish(
    shared: &Shared,
    job: Job,
    result: crate::Result<Arc<PlacementResponse>>,
    mode: ServeMode,
) {
    let m = &shared.metrics;
    let latency_s = job.submitted.elapsed().as_secs_f64();
    let outcome = match result {
        Ok(response) => {
            m.completed.fetch_add(1, Relaxed);
            m.record_latency(mode, latency_s);
            Ok(ServeOutcome {
                response,
                mode,
                latency_s,
            })
        }
        Err(e) => {
            m.errors.fetch_add(1, Relaxed);
            Err(e)
        }
    };
    // A dropped Ticket just means the caller stopped waiting.
    let _ = job.reply.send(outcome);
}

/// Record a full response as the delta base for its model identity, so
/// the next near-duplicate request can be patched instead of re-placed.
/// Only plain simulated requests are eligible (the same precondition
/// `try_incremental` checks on the consuming side).
fn remember_base(shared: &Shared, req: &PlacementRequest, resp: Arc<PlacementResponse>) {
    if !shared.cfg.incremental.enabled || req.topology.is_some() || !req.simulate {
        return;
    }
    if let Ok(base) = DeltaBase::new(req.graph.clone(), resp) {
        shared
            .bases
            .lock()
            .unwrap()
            .insert(base_key(req), Arc::new(base));
    }
}

/// Base-index key: the model identity a delta stream is keyed by. The
/// incremental guards (fingerprint diff + simulator verdict) keep
/// correctness even if distinct streams collide here.
fn base_key(req: &PlacementRequest) -> String {
    let opt_fp = req
        .opt
        .map(|o| fingerprint::opt_fingerprint(&o))
        .unwrap_or(0);
    format!(
        "{}|{}|{}|{opt_fp:x}",
        req.graph.name,
        req.placer,
        req.benchmark.map(|b| b.name()).unwrap_or_default(),
    )
}

/// Micro-batch compatibility: requests against the same topology target.
fn compat_key(req: &PlacementRequest) -> u64 {
    req.topology
        .as_ref()
        .map(fingerprint::topology_fingerprint)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::delta::{mutate, MutationSpec};
    use crate::graph::{NodeId, OpGraph, OpKind};
    use crate::placer::{Placement, Placer};
    use crate::profile::{Cluster, CommModel};
    use crate::util::rng::Pcg;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev: Option<NodeId> = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 2.0;
            g.node_mut(id).output_bytes = 100;
            g.node_mut(id).mem.output = 100;
            g.node_mut(id).mem.temp = 100;
            if let Some(p) = prev {
                g.add_edge(p, id, 100);
            }
            prev = Some(id);
        }
        g
    }

    fn engine() -> Arc<PlacementEngine> {
        Arc::new(
            PlacementEngine::builder()
                .cluster(Cluster::homogeneous(
                    2,
                    1 << 20,
                    CommModel::new(1e-6, 1e9).unwrap(),
                ))
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn serve_full_then_cache_hit() {
        let service = PlacementService::new(engine(), ServiceConfig::default()).unwrap();
        let g = chain(6);
        let a = service
            .place(PlacementRequest::new(g.clone(), "m-etf"))
            .unwrap();
        assert_eq!(a.mode, ServeMode::Full);
        let b = service.place(PlacementRequest::new(g, "m-etf")).unwrap();
        assert_eq!(b.mode, ServeMode::CacheHit);
        assert!(Arc::ptr_eq(&a.response, &b.response));
        let m = service.metrics();
        assert_eq!(m.completed, 2);
        assert_eq!(m.full, 1);
        assert_eq!(m.cache_hits, 1);
        assert!(m.cache_hit_rate() > 0.0);
        assert!(m.qps > 0.0);
    }

    #[test]
    fn serve_incremental_on_small_delta() {
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        let service = PlacementService::new(engine(), cfg).unwrap();
        let g = chain(12);
        service
            .place(PlacementRequest::new(g.clone(), "m-etf"))
            .unwrap();
        let mut m = g.clone();
        let last = m.node_ids().last().unwrap();
        m.node_mut(last).compute += 0.5;
        let out = service
            .place(PlacementRequest::new(m.clone(), "m-etf"))
            .unwrap();
        assert_eq!(out.mode, ServeMode::Incremental { dirty_ops: 1 });
        assert_eq!(out.response.placement.device_of.len(), m.len());
        assert_eq!(service.metrics().incremental, 1);
    }

    #[test]
    fn serve_mutation_stream_mixes_modes() {
        let mut cfg = ServiceConfig::default();
        cfg.workers = 2;
        let service = PlacementService::new(engine(), cfg).unwrap();
        let mut g = chain(10);
        let mut rng = Pcg::seed(7);
        let mut served = 0u64;
        for i in 0..20 {
            if i % 3 == 1 {
                mutate(&mut g, &mut rng, &MutationSpec::small());
            }
            service
                .place(PlacementRequest::new(g.clone(), "m-etf"))
                .unwrap();
            served += 1;
        }
        let m = service.metrics();
        assert_eq!(m.completed, served);
        assert_eq!(m.errors, 0);
        assert!(m.cache_hits > 0, "repeats must hit: {m:?}");
        assert_eq!(m.cache_hits + m.incremental + m.full, served);
    }

    #[test]
    fn zero_deadline_is_a_typed_miss() {
        let service = PlacementService::new(engine(), ServiceConfig::default()).unwrap();
        let ticket = service
            .submit_with_deadline(
                PlacementRequest::new(chain(4), "m-etf"),
                Some(Duration::ZERO),
            )
            .unwrap();
        match ticket.wait() {
            Err(BaechiError::DeadlineExceeded { waited }) => assert!(waited >= 0.0),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let m = service.metrics();
        assert_eq!(m.deadline_misses, 1);
        assert_eq!(m.errors, 1);
    }

    /// Placer that sleeps, to wedge the single worker deterministically.
    struct SleepyPlacer;
    impl Placer for SleepyPlacer {
        fn name(&self) -> String {
            "sleepy".into()
        }
        fn place(&self, graph: &OpGraph, _cluster: &Cluster) -> crate::Result<Placement> {
            std::thread::sleep(Duration::from_millis(300));
            Ok(Placement {
                algorithm: "sleepy".into(),
                device_of: graph
                    .node_ids()
                    .map(|id| (id, crate::graph::DeviceId(0)))
                    .collect(),
                predicted_makespan: 0.0,
                placement_time: 0.0,
                peak_memory: Vec::new(),
            })
        }
    }

    #[test]
    fn try_submit_reports_saturation() {
        let engine = Arc::new(
            PlacementEngine::builder()
                .cluster(Cluster::homogeneous(
                    2,
                    1 << 20,
                    CommModel::new(1e-6, 1e9).unwrap(),
                ))
                .register_placer(
                    "sleepy",
                    crate::engine::PlacerRegistration::new(|_| Ok(Box::new(SleepyPlacer))),
                )
                .build()
                .unwrap(),
        );
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.queue_capacity = 1;
        cfg.incremental.enabled = false;
        let service = PlacementService::new(engine, cfg).unwrap();
        let mut tickets = Vec::new();
        let mut saturated = false;
        // Distinct graphs so nothing is served from the cache; the sleepy
        // placer wedges the worker, so by the third submission at most one
        // job is in flight and one queued.
        for i in 0..8 {
            let mut g = chain(4);
            g.node_mut(NodeId(0)).compute += i as f64;
            match service.try_submit(PlacementRequest::new(g, "sleepy").without_simulation()) {
                Ok(t) => tickets.push(t),
                Err(BaechiError::Saturated { capacity }) => {
                    assert_eq!(capacity, 1);
                    saturated = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(saturated, "queue of 1 must saturate under a wedged worker");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn shutdown_drains_and_rejects_new_work() {
        let service = PlacementService::new(engine(), ServiceConfig::default()).unwrap();
        let t = service
            .submit(PlacementRequest::new(chain(4), "m-etf"))
            .unwrap();
        service.shutdown();
        t.wait().unwrap();
    }

    #[test]
    fn config_validation() {
        let mut cfg = ServiceConfig::default();
        cfg.workers = 0;
        assert!(PlacementService::new(engine(), cfg).is_err());
        let mut cfg = ServiceConfig::default();
        cfg.max_batch = 0;
        assert!(PlacementService::new(engine(), cfg).is_err());
    }
}
