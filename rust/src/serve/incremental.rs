//! Incremental (delta) placement.
//!
//! The serving workload is dominated by *versions* of graphs already
//! placed: a user tweaks a layer, dims change, an op is spliced in. A full
//! pipeline run (optimize → place → expand → simulate) re-derives the
//! ~unchanged 99% from scratch. Instead, [`try_incremental`] diffs the
//! request against the last served version by per-op cone fingerprints
//! ([`crate::engine::fingerprint::cone_fingerprints`]), keeps every clean
//! op on its cached device, and greedily re-schedules only the dirty cone
//! under the full memory ledger.
//!
//! **Contract** (property-tested in `prop_invariants`): an incremental
//! plan always covers every op, always respects per-device memory
//! capacity (it is re-validated in the execution simulator), and its
//! simulated makespan never exceeds the base plan's by more than the
//! configured tolerance — otherwise `try_incremental` returns `None` and
//! the service falls back to full placement.

use crate::engine::fingerprint::{cone_fingerprints, graph_fingerprint};
use crate::engine::{PlacementEngine, PlacementRequest, PlacementResponse};
use crate::graph::delta::{diff_by_cones, GraphDelta};
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::optimizer::OptStats;
use crate::placer::ledger::MemoryLedger;
use crate::placer::Placement;
use crate::sim;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

/// Knobs for the incremental path.
#[derive(Debug, Clone, Copy)]
pub struct IncrementalConfig {
    pub enabled: bool,
    /// Fall back to full placement when more than this fraction of ops is
    /// dirty (the patch would redo most of the work anyway).
    pub max_dirty_fraction: f64,
    /// Reject a patched plan whose simulated makespan exceeds the base
    /// plan's by more than this relative tolerance.
    pub makespan_tolerance: f64,
}

impl Default for IncrementalConfig {
    fn default() -> IncrementalConfig {
        IncrementalConfig {
            enabled: true,
            max_dirty_fraction: 0.25,
            makespan_tolerance: 0.25,
        }
    }
}

/// How the service produced a response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// Straight from the engine's placement cache.
    CacheHit,
    /// Full pipeline run (optimize → place → expand → simulate).
    Full,
    /// Patched against a cached base plan; only `dirty_ops` ops were
    /// re-placed.
    Incremental { dirty_ops: usize },
}

/// A fully-placed graph version that later small-delta requests can be
/// patched against.
pub struct DeltaBase {
    pub graph: OpGraph,
    pub cones: Vec<u64>,
    pub response: Arc<PlacementResponse>,
}

impl DeltaBase {
    pub fn new(graph: OpGraph, response: Arc<PlacementResponse>) -> crate::Result<DeltaBase> {
        let cones = cone_fingerprints(&graph)?;
        Ok(DeltaBase {
            graph,
            cones,
            response,
        })
    }
}

/// A successful incremental placement.
pub(crate) struct IncrementalPlan {
    pub response: Arc<PlacementResponse>,
    pub dirty_ops: usize,
    /// Cone fingerprints of the request graph, reusable as the next base.
    pub cones: Vec<u64>,
}

/// Try to serve `req` by patching `base`. `None` means "take the full
/// path": delta too large, a frozen assignment no longer fits, no device
/// fits a dirty op, the patched plan OOMs in the simulator, or its
/// makespan regresses past tolerance. Only plain simulated requests are
/// eligible (no per-request topology override).
pub(crate) fn try_incremental(
    engine: &PlacementEngine,
    req: &PlacementRequest,
    base: &DeltaBase,
    cfg: &IncrementalConfig,
) -> Option<IncrementalPlan> {
    if !cfg.enabled || req.topology.is_some() || !req.simulate {
        return None;
    }
    let base_sim = base.response.sim.as_ref()?;
    if !base_sim.ok() {
        return None;
    }
    let cones = cone_fingerprints(&req.graph).ok()?;
    // The identical graph under the same placer: the base answer *is* the
    // answer. (The engine cache usually catches this first; this arm keeps
    // the path correct when the cache entry was evicted.)
    if graph_fingerprint(&req.graph) == graph_fingerprint(&base.graph) {
        return Some(IncrementalPlan {
            response: base.response.clone(),
            dirty_ops: 0,
            cones,
        });
    }
    let delta = diff_by_cones(&base.graph, &req.graph, &base.cones, &cones);
    if delta.dirty_fraction > cfg.max_dirty_fraction {
        return None;
    }
    // (An empty dirty set with differing fingerprints means ops were
    // *removed*; the patch below re-schedules the clean survivors on
    // their frozen devices and re-validates memory + makespan.)
    let t0 = Instant::now();
    let (device_of, predicted, peaks) = patch_placement(engine, req, base, &delta)?;
    let simulated = sim::simulate(
        &req.graph,
        engine.cluster(),
        &device_of,
        engine.sim_config(),
    );
    if !simulated.ok() {
        return None;
    }
    if simulated.makespan > base_sim.makespan * (1.0 + cfg.makespan_tolerance) + 1e-12 {
        return None;
    }
    let devices_used = device_of.values().collect::<BTreeSet<_>>().len();
    let dirty_ops = delta.dirty.len();
    let response = Arc::new(PlacementResponse {
        placer: format!("{}+delta", base.response.placer),
        placement: Placement {
            algorithm: format!("{}+delta", base.response.placement.algorithm),
            device_of,
            predicted_makespan: predicted,
            placement_time: t0.elapsed().as_secs_f64(),
            peak_memory: peaks,
        },
        stats: OptStats {
            original_ops: req.graph.len(),
            placed_ops: dirty_ops,
            ..OptStats::default()
        },
        sim: Some(simulated),
        devices_used,
    });
    Some(IncrementalPlan {
        response,
        dirty_ops,
        cones,
    })
}

/// One topo-order sweep over the request graph: clean ops keep their
/// cached device (frozen loads), dirty ops greedily take the device with
/// the earliest start time among those with memory room. Returns `None`
/// when any op has no feasible device.
fn patch_placement(
    engine: &PlacementEngine,
    req: &PlacementRequest,
    base: &DeltaBase,
    delta: &GraphDelta,
) -> Option<(BTreeMap<NodeId, DeviceId>, f64, Vec<u64>)> {
    let g = &req.graph;
    let cluster = engine.cluster();
    let topo = cluster.effective_topology();
    let caps: Vec<u64> = cluster.devices.iter().map(|d| d.memory).collect();
    let n_dev = cluster.n();
    let order = g.topo_order()?;

    let mut frozen: Vec<Option<DeviceId>> = vec![None; g.capacity()];
    for &(new_id, old_id) in &delta.clean {
        frozen[new_id.0] = base.response.placement.try_device(old_id);
    }
    // A colocation group with a frozen member pins its dirty members too.
    let mut group_dev: BTreeMap<&str, DeviceId> = BTreeMap::new();
    for id in g.node_ids() {
        if let (Some(grp), Some(d)) = (g.node(id).colocation_group.as_deref(), frozen[id.0]) {
            group_dev.entry(grp).or_insert(d);
        }
    }

    let mut ledger = MemoryLedger::new(g, &caps);
    let mut dev_ready = vec![0.0f64; n_dev];
    let mut finish = vec![0.0f64; g.capacity()];
    let mut device_of: BTreeMap<NodeId, DeviceId> = BTreeMap::new();

    let est = |id: NodeId,
               d: DeviceId,
               dev_ready: &[f64],
               finish: &[f64],
               device_of: &BTreeMap<NodeId, DeviceId>| {
        let mut t = dev_ready[d.0];
        for &(p, bytes) in g.predecessors(id) {
            let pd = device_of[&p];
            let arrive = finish[p.0]
                + if pd == d {
                    0.0
                } else {
                    topo.pair(pd.0, d.0).time(bytes)
                };
            if arrive > t {
                t = arrive;
            }
        }
        t
    };

    for &id in &order {
        let node = g.node(id);
        let choice = match frozen[id.0] {
            Some(d) => {
                // Frozen loads: the patch may only *keep* cached devices.
                // If memory no longer works out, the whole patch is off.
                if !ledger.fits(g, id, d) {
                    return None;
                }
                d
            }
            None => {
                let forced = node
                    .colocation_group
                    .as_deref()
                    .and_then(|grp| group_dev.get(grp).copied())
                    .or_else(|| ledger.pinned_device(g, id));
                let mut best: Option<(f64, DeviceId)> = None;
                let candidates: Vec<DeviceId> = match forced {
                    Some(d) => vec![d],
                    None => (0..n_dev).map(DeviceId).collect(),
                };
                for d in candidates {
                    if !ledger.fits(g, id, d) {
                        continue;
                    }
                    let t = est(id, d, &dev_ready, &finish, &device_of);
                    if best.map_or(true, |(bt, _)| t < bt) {
                        best = Some((t, d));
                    }
                }
                best?.1
            }
        };
        ledger.commit(g, id, choice);
        let start = est(id, choice, &dev_ready, &finish, &device_of);
        let done = start + node.compute / cluster.devices[choice.0].speed.max(1e-12);
        finish[id.0] = done;
        dev_ready[choice.0] = done;
        device_of.insert(id, choice);
    }
    let predicted = order.iter().map(|&id| finish[id.0]).fold(0.0, f64::max);
    Some((device_of, predicted, ledger.peaks()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::profile::{Cluster, CommModel};

    fn chain(n: usize, bytes: u64) -> OpGraph {
        let mut g = OpGraph::new("chain");
        let mut prev: Option<NodeId> = None;
        for i in 0..n {
            let id = g.add_node(&format!("op{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 2.0;
            g.node_mut(id).output_bytes = bytes;
            g.node_mut(id).mem.output = bytes;
            g.node_mut(id).mem.temp = bytes;
            if let Some(p) = prev {
                g.add_edge(p, id, bytes);
            }
            prev = Some(id);
        }
        g
    }

    fn engine(n: usize, mem: u64) -> PlacementEngine {
        PlacementEngine::builder()
            .cluster(Cluster::homogeneous(n, mem, CommModel::new(1e-6, 1e9).unwrap()))
            .build()
            .unwrap()
    }

    fn base_for(e: &PlacementEngine, g: &OpGraph) -> DeltaBase {
        let resp = e.place(&PlacementRequest::new(g.clone(), "m-etf")).unwrap();
        DeltaBase::new(g.clone(), resp).unwrap()
    }

    #[test]
    fn small_tail_delta_patches() {
        let e = engine(2, 1 << 20);
        let g = chain(12, 100);
        let base = base_for(&e, &g);
        let mut m = g.clone();
        let last = m.node_ids().last().unwrap();
        m.node_mut(last).compute += 0.5;
        let req = PlacementRequest::new(m.clone(), "m-etf");
        let plan =
            try_incremental(&e, &req, &base, &IncrementalConfig::default()).expect("patchable");
        assert_eq!(plan.dirty_ops, 1);
        assert_eq!(plan.response.placement.device_of.len(), m.len());
        assert!(plan.response.sim.as_ref().unwrap().ok());
        assert!(plan.response.placer.ends_with("+delta"));
        // Clean ops kept their cached devices.
        for id in g.node_ids() {
            if id == last {
                continue;
            }
            assert_eq!(
                plan.response.placement.try_device(id),
                base.response.placement.try_device(id),
                "clean op moved"
            );
        }
    }

    #[test]
    fn identical_graph_reuses_base_outright() {
        let e = engine(2, 1 << 20);
        let g = chain(8, 100);
        let base = base_for(&e, &g);
        let req = PlacementRequest::new(g.clone(), "m-etf");
        let plan = try_incremental(&e, &req, &base, &IncrementalConfig::default()).unwrap();
        assert_eq!(plan.dirty_ops, 0);
        assert!(Arc::ptr_eq(&plan.response, &base.response));
    }

    #[test]
    fn large_delta_falls_back() {
        let e = engine(2, 1 << 20);
        let g = chain(8, 100);
        let base = base_for(&e, &g);
        let mut m = g.clone();
        let first = m.node_ids().next().unwrap();
        m.node_mut(first).compute += 1.0; // head mutation dirties the whole chain
        let req = PlacementRequest::new(m, "m-etf");
        assert!(try_incremental(&e, &req, &base, &IncrementalConfig::default()).is_none());
    }

    #[test]
    fn topology_override_and_no_sim_are_ineligible() {
        let e = engine(2, 1 << 20);
        let g = chain(8, 100);
        let base = base_for(&e, &g);
        let cfg = IncrementalConfig::default();
        let no_sim = PlacementRequest::new(g.clone(), "m-etf").without_simulation();
        assert!(try_incremental(&e, &no_sim, &base, &cfg).is_none());
        let mut disabled = cfg;
        disabled.enabled = false;
        let plain = PlacementRequest::new(g, "m-etf");
        assert!(try_incremental(&e, &plain, &base, &disabled).is_none());
    }
}
