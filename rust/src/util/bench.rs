//! Minimal benchmark harness (replaces `criterion`, unavailable offline).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("table3");
//! b.run("m-sct/inception", || place(&graph));
//! b.finish();
//! ```
//!
//! Each measurement does a warmup phase, then timed iterations until a
//! minimum wall-clock budget (or max iteration count) is reached, and
//! reports mean/p50/p90 with outlier-robust statistics.

use super::stats::Summary;
use super::table::{fmt_secs, Table};
use std::time::{Duration, Instant};

/// One measured benchmark entry.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub iters: usize,
}

/// Benchmark group collecting measurements and printing a table at the end.
pub struct Bench {
    group: String,
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    min_iters: usize,
    measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench {
            group: group.to_string(),
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(1000),
            max_iters: 1000,
            min_iters: 5,
            measurements: Vec::new(),
        }
    }

    /// Configure the per-benchmark time budget.
    pub fn budget(mut self, warmup: Duration, measure: Duration) -> Bench {
        self.warmup = warmup;
        self.budget = measure;
        self
    }

    /// Configure iteration bounds.
    pub fn iters(mut self, min: usize, max: usize) -> Bench {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run and record a benchmark. The closure's return value is passed
    /// through `black_box` to keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters: samples.len(),
        });
        self.measurements.last().unwrap()
    }

    /// Record an externally-measured sample set (for one-shot expensive runs).
    pub fn record(&mut self, name: &str, samples: &[f64]) -> &Measurement {
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: Summary::of(samples),
            iters: samples.len(),
        });
        self.measurements.last().unwrap()
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Print the results table.
    pub fn finish(&self) {
        let mut t = Table::new(
            &format!("bench group: {}", self.group),
            &["benchmark", "iters", "mean", "p50", "p90", "stddev"],
        );
        for m in &self.measurements {
            t.row(&[
                m.name.clone(),
                m.iters.to_string(),
                fmt_secs(m.summary.mean),
                fmt_secs(m.summary.p50),
                fmt_secs(m.summary.p90),
                fmt_secs(m.summary.std_dev),
            ]);
        }
        t.print();
    }
}

/// Opaque value sink to prevent the optimizer from eliding benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let m = b.run("sum", || (0..1000u64).sum::<u64>());
        assert!(m.iters >= 5);
        assert!(m.summary.mean > 0.0);
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::new("test");
        let m = b.record("oneshot", &[1.0, 2.0, 3.0]);
        assert_eq!(m.iters, 3);
        assert!((m.summary.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn finish_renders() {
        let mut b = Bench::new("g").budget(
            Duration::from_millis(1),
            Duration::from_millis(5),
        );
        b.run("noop", || 1u32);
        b.finish(); // should not panic
        assert_eq!(b.measurements().len(), 1);
    }
}
