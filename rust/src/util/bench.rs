//! Minimal benchmark harness (replaces `criterion`, unavailable offline).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("table3");
//! b.run("m-sct/inception", || place(&graph));
//! b.finish();
//! ```
//!
//! Each measurement does a warmup phase, then timed iterations until a
//! minimum wall-clock budget (or max iteration count) is reached, and
//! reports mean/p50/p90 with outlier-robust statistics.

use super::json::Json;
use super::stats::Summary;
use super::table::{fmt_secs, Table};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Where bench `name` should write machine-readable results, if
/// anywhere: an explicit `--json <path>` argument (after `cargo bench
/// -- …`) wins; otherwise the `BAECHI_BENCH_JSON` environment variable
/// names a directory that receives `BENCH_<name>.json`. `None` = no
/// JSON requested (the default; benches stay print-only).
///
/// `cargo bench -- --json <path>` hands the flag to *every* bench
/// binary, so a plain file path would be overwritten by each bench in
/// turn. The rule: a path ending in `.json` (and not already a
/// directory) is a file — only meaningful with a single `--bench`
/// target; anything else is treated as a directory (created on write)
/// receiving per-bench `BENCH_<name>.json` files.
pub fn bench_json_path(name: &str) -> Option<PathBuf> {
    resolve_json_path(
        name,
        std::env::args(),
        std::env::var_os("BAECHI_BENCH_JSON").map(PathBuf::from),
    )
}

/// Pure resolution behind [`bench_json_path`] (testable without
/// touching the process environment, which data-races under the
/// parallel test harness).
fn resolve_json_path(
    name: &str,
    mut argv: impl Iterator<Item = String>,
    env_dir: Option<PathBuf>,
) -> Option<PathBuf> {
    let per_bench = |dir: PathBuf| dir.join(format!("BENCH_{name}.json"));
    while let Some(a) = argv.next() {
        if a == "--json" {
            match argv.next() {
                Some(p) => {
                    let p = PathBuf::from(p);
                    let is_file = !p.is_dir() && p.extension().map_or(false, |e| e == "json");
                    return Some(if is_file { p } else { per_bench(p) });
                }
                None => {
                    eprintln!("warning: --json needs a path; ignoring");
                    break;
                }
            }
        }
    }
    env_dir.map(per_bench)
}

/// Write the schema-versioned bench document (see README "Bench JSON
/// output") if JSON output was requested. Write failures warn instead
/// of panicking — a bench run should never die on a bad output path.
/// Returns the path written.
pub fn maybe_write_json(name: &str, rows: Vec<Json>, summary: Option<Json>) -> Option<PathBuf> {
    let path = bench_json_path(name)?;
    write_doc(path, name, rows, summary)
}

fn write_doc(path: PathBuf, name: &str, rows: Vec<Json>, summary: Option<Json>) -> Option<PathBuf> {
    // A CI run typically points BAECHI_BENCH_JSON at a directory that
    // does not exist yet; create it rather than silently archiving
    // nothing (write failures below still warn).
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let mut doc = Json::obj();
    doc.set("bench", name)
        .set("schema", 1u64)
        .set("rows", Json::Arr(rows));
    if let Some(s) = summary {
        doc.set("summary", s);
    }
    match std::fs::write(&path, doc.pretty()) {
        Ok(()) => {
            eprintln!("wrote {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("warning: could not write {}: {e}", path.display());
            None
        }
    }
}

/// One measured benchmark entry.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub iters: usize,
}

/// Benchmark group collecting measurements and printing a table at the end.
pub struct Bench {
    group: String,
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    min_iters: usize,
    measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench {
            group: group.to_string(),
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(1000),
            max_iters: 1000,
            min_iters: 5,
            measurements: Vec::new(),
        }
    }

    /// Configure the per-benchmark time budget.
    pub fn budget(mut self, warmup: Duration, measure: Duration) -> Bench {
        self.warmup = warmup;
        self.budget = measure;
        self
    }

    /// Configure iteration bounds.
    pub fn iters(mut self, min: usize, max: usize) -> Bench {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Run and record a benchmark. The closure's return value is passed
    /// through `black_box` to keep the optimizer honest.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters: samples.len(),
        });
        self.measurements.last().unwrap()
    }

    /// Record an externally-measured sample set (for one-shot expensive runs).
    pub fn record(&mut self, name: &str, samples: &[f64]) -> &Measurement {
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: Summary::of(samples),
            iters: samples.len(),
        });
        self.measurements.last().unwrap()
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Print the results table, and emit the measurements as bench JSON
    /// when requested (see [`maybe_write_json`]).
    pub fn finish(&self) {
        let mut t = Table::new(
            &format!("bench group: {}", self.group),
            &["benchmark", "iters", "mean", "p50", "p90", "stddev"],
        );
        for m in &self.measurements {
            t.row(&[
                m.name.clone(),
                m.iters.to_string(),
                fmt_secs(m.summary.mean),
                fmt_secs(m.summary.p50),
                fmt_secs(m.summary.p90),
                fmt_secs(m.summary.std_dev),
            ]);
        }
        t.print();
        maybe_write_json(
            &self.group,
            self.measurements
                .iter()
                .map(|m| {
                    let mut j = Json::obj();
                    j.set("name", m.name.as_str())
                        .set("iters", m.iters)
                        .set("mean_s", m.summary.mean)
                        .set("p50_s", m.summary.p50)
                        .set("p90_s", m.summary.p90)
                        .set("stddev_s", m.summary.std_dev);
                    j
                })
                .collect(),
            None,
        );
    }
}

/// Opaque value sink to prevent the optimizer from eliding benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let m = b.run("sum", || (0..1000u64).sum::<u64>());
        assert!(m.iters >= 5);
        assert!(m.summary.mean > 0.0);
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::new("test");
        let m = b.record("oneshot", &[1.0, 2.0, 3.0]);
        assert_eq!(m.iters, 3);
        assert!((m.summary.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_path_resolution_is_argv_first_then_env() {
        // Pure resolution — never mutates the process env (set_var would
        // data-race the parallel test harness's getenv calls).
        let argv = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        let none: Option<PathBuf> = None;
        // Nothing requested.
        assert_eq!(resolve_json_path("g", argv(&["bench"]).into_iter(), none.clone()), None);
        // Explicit file path wins over the env dir.
        let got = resolve_json_path(
            "g",
            argv(&["bench", "--json", "/tmp/out.json"]).into_iter(),
            Some(PathBuf::from("/elsewhere")),
        );
        assert_eq!(got, Some(PathBuf::from("/tmp/out.json")));
        // A directory path (argv or env) gets the per-bench file name.
        let dir = std::env::temp_dir();
        let expect = dir.join("BENCH_g.json");
        let via_argv = argv(&["bench", "--json", &dir.display().to_string()]);
        assert_eq!(
            resolve_json_path("g", via_argv.into_iter(), none.clone()),
            Some(expect.clone())
        );
        assert_eq!(
            resolve_json_path("g", argv(&["bench"]).into_iter(), Some(dir)),
            Some(expect)
        );
        // A not-yet-existing path without a .json extension is a
        // directory-to-be, not a file every bench would overwrite.
        let fresh = argv(&["bench", "--json", "/tmp/bench-out"]);
        assert_eq!(
            resolve_json_path("g", fresh.into_iter(), none.clone()),
            Some(PathBuf::from("/tmp/bench-out/BENCH_g.json"))
        );
        // Trailing --json without a value is ignored (with a warning).
        assert_eq!(resolve_json_path("g", argv(&["bench", "--json"]).into_iter(), none), None);
    }

    #[test]
    fn write_doc_emits_schema_versioned_document() {
        let name = format!("baechi_bench_json_{}", std::process::id());
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        let mut row = Json::obj();
        row.set("name", "case").set("mean_s", 0.5);
        let path = write_doc(dir.join("BENCH_envjson.json"), "envjson", vec![row], None).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("envjson"));
        assert_eq!(doc.get("schema").unwrap().as_u64(), Some(1));
        assert_eq!(doc.get("rows").unwrap().as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn finish_renders() {
        let mut b = Bench::new("g").budget(
            Duration::from_millis(1),
            Duration::from_millis(5),
        );
        b.run("noop", || 1u32);
        b.finish(); // should not panic
        assert_eq!(b.measurements().len(), 1);
    }
}
