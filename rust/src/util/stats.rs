//! Summary statistics over measurement samples.

/// Summary of a sample set: mean, std-dev, min/max, and percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = idx - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b, r2)`.
///
/// Degenerate sample sets — fewer than 2 points, mismatched lengths, or
/// zero variance in `x` (every sample at the same abscissa, where the
/// slope is unidentifiable) — are a typed
/// [`BaechiError::InvalidRequest`](crate::BaechiError::InvalidRequest)
/// instead of NaN coefficients: a calibration run fed a broken
/// measurement sweep must fail loudly, not fit garbage.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> crate::Result<(f64, f64, f64)> {
    if xs.len() != ys.len() {
        return Err(crate::BaechiError::invalid(format!(
            "linear fit: {} x samples vs {} y samples",
            xs.len(),
            ys.len()
        )));
    }
    if xs.len() < 2 {
        return Err(crate::BaechiError::invalid(format!(
            "linear fit: need at least 2 samples, got {}",
            xs.len()
        )));
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx <= 0.0 || sxx.is_nan() {
        return Err(crate::BaechiError::invalid(
            "linear fit: zero variance in x (all samples at one abscissa)",
        ));
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    Ok((a, b, r2))
}

/// Geometric mean of positive values.
pub fn geo_mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&sorted, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit(&xs, &ys).unwrap();
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_degenerate_inputs_are_typed_errors() {
        use crate::BaechiError;
        // Too few samples.
        for (xs, ys) in [(&[][..], &[][..]), (&[1.0][..], &[2.0][..])] {
            assert!(matches!(
                linear_fit(xs, ys),
                Err(BaechiError::InvalidRequest(_))
            ));
        }
        // Mismatched lengths.
        assert!(matches!(
            linear_fit(&[1.0, 2.0], &[1.0]),
            Err(BaechiError::InvalidRequest(_))
        ));
        // Zero variance in x: the slope is unidentifiable; this used to
        // silently return b = 0 (and NaN with hostile inputs upstream).
        match linear_fit(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]) {
            Err(BaechiError::InvalidRequest(msg)) => {
                assert!(msg.contains("variance"), "{msg}")
            }
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
    }

    #[test]
    fn geo_mean_of_ratios() {
        let g = geo_mean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }
}
