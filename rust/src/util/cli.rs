//! Tiny command-line argument parser (replaces `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generated usage text. The main binary and all examples/benches use
//! this.

use crate::error::BaechiError;
use std::collections::BTreeMap;

/// Declarative option spec used for usage text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    program: String,
}

impl Args {
    /// Build a parser with the given option specs and parse `argv[1..]`.
    pub fn parse(specs: &[OptSpec]) -> crate::Result<Args> {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse_from(specs, &argv)
    }

    /// Parse from an explicit argv (first element is the program name).
    pub fn parse_from(specs: &[OptSpec], argv: &[String]) -> crate::Result<Args> {
        let mut args = Args {
            specs: specs.to_vec(),
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if key == "help" {
                    eprintln!("{}", args.usage());
                    std::process::exit(0);
                }
                let spec = specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| {
                        BaechiError::invalid(format!("unknown option --{key}\n{}", args.usage()))
                    })?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| BaechiError::invalid(format!("--{key} needs a value")))?
                        }
                    };
                    args.opts.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        return Err(BaechiError::invalid(format!("--{key} takes no value")));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Usage text derived from the specs.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [options] [args]\noptions:\n", self.program);
        for spec in &self.specs {
            let arg = if spec.takes_value { " <value>" } else { "" };
            let default = spec
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!(
                "  --{}{arg}\n      {}{default}\n",
                spec.name, spec.help
            ));
        }
        s.push_str("  --help\n      show this message\n");
        s
    }

    /// String option with spec default fallback.
    pub fn get(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned().or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default.map(str::to_string))
        })
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| BaechiError::invalid(format!("--{name} expects an integer, got '{v}'"))),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|_| BaechiError::invalid(format!("--{name} expects a number, got '{v}'"))),
            None => Ok(default),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "model",
                help: "model name",
                takes_value: true,
                default: Some("inception"),
            },
            OptSpec {
                name: "devices",
                help: "device count",
                takes_value: true,
                default: Some("4"),
            },
            OptSpec {
                name: "verbose",
                help: "chatty output",
                takes_value: false,
                default: None,
            },
        ]
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        std::iter::once("prog")
            .chain(parts.iter().copied())
            .map(str::to_string)
            .collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::parse_from(&specs(), &argv(&["--model", "gnmt", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get("model").unwrap(), "gnmt");
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse_from(&specs(), &argv(&["--devices=8"])).unwrap();
        assert_eq!(a.get_usize("devices", 0).unwrap(), 8);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(&specs(), &argv(&[])).unwrap();
        assert_eq!(a.get("model").unwrap(), "inception");
        assert_eq!(a.get_usize("devices", 0).unwrap(), 4);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Args::parse_from(&specs(), &argv(&["--bogus"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse_from(&specs(), &argv(&["--model"])).is_err());
    }
}
