//! Seeded property-testing harness (replaces `proptest`, unavailable
//! offline).
//!
//! A property is a closure taking a [`Pcg`] it can draw arbitrary inputs
//! from; the harness runs it for `cases` distinct seeds and reports the
//! first failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop_check("fusion_acyclic", 200, |rng| {
//!     let g = random_dag(rng, 50);
//!     let fused = fuse(&g);
//!     assert!(fused.is_acyclic());
//! });
//! ```

use super::rng::Pcg;

/// Run `cases` property checks with deterministic per-case seeds derived
/// from `name`. Panics (with the failing seed) on the first failure.
pub fn prop_check(name: &str, cases: u64, prop: impl Fn(&mut Pcg) + std::panic::RefUnwindSafe) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9e3779b97f4a7c15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg::seed(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay seed: {seed:#x}):\n  {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn prop_replay(seed: u64, prop: impl Fn(&mut Pcg)) {
    let mut rng = Pcg::seed(seed);
    prop(&mut rng);
}

/// FNV-1a hash for seed derivation.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::sync::atomic::AtomicU64::new(0);
        prop_check("always_true", 50, |rng| {
            let _ = rng.next_u32();
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            prop_check("sometimes_false", 100, |rng| {
                // fail roughly half the time
                assert!(rng.f64() < 0.5, "drew a large value");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "got: {msg}");
    }

    #[test]
    fn seeds_are_deterministic() {
        // Both runs must see identical draws for case 0.
        let mut first = None;
        for _ in 0..2 {
            let cell = std::sync::Mutex::new(Vec::new());
            prop_check("det", 1, |rng| {
                cell.lock().unwrap().push(rng.next_u64());
            });
            let v = cell.into_inner().unwrap();
            match &first {
                None => first = Some(v),
                Some(f) => assert_eq!(f, &v),
            }
        }
    }
}
