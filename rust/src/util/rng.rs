//! PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! Deterministic, seedable, and fast — used by the synthetic graph
//! generators, the profile perturbation of Fig. 8, the RL baseline placer,
//! and the property-test harness. Replaces the `rand` crate (offline
//! registry does not carry it).

/// Permuted congruential generator (PCG-XSH-RR 64/32, O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a single seed (stream 0xda3e39cb94b95bdb).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next uniform `u32`.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's method. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection-free multiply-shift with widening; bias is negligible
        // for our use but we debias properly anyway.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with given log-space mean and sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Choose a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seed(1);
        let mut b = Pcg::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seed(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seed(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg::seed(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
