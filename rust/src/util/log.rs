//! Leveled stderr logger controlled by `BAECHI_LOG` (error|warn|info|debug).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log verbosity level.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize from the `BAECHI_LOG` environment variable (idempotent).
pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("BAECHI_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "debug" => Level::Debug,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

/// Set the level programmatically.
pub fn set_level(level: Level) {
    INIT.get_or_init(|| ());
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether the given level is enabled.
pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a log line.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[baechi {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
