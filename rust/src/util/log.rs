//! Leveled stderr logger controlled by `BAECHI_LOG`
//! (error|warn|info|debug, or numeric 0–3).
//!
//! Lines emitted while a telemetry span is open on the current thread
//! carry that span's trace id as `t=<hex>` (see
//! [`crate::telemetry::tracer`]), so service logs can be joined with
//! exported traces.

use std::cell::Cell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log verbosity level.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

/// Parse one `BAECHI_LOG` value. Accepts the level names and their
/// numeric forms (`0`=error … `3`=debug); `None` for anything else.
pub fn parse_level(v: &str) -> Option<Level> {
    match v.trim().to_ascii_lowercase().as_str() {
        "error" | "0" => Some(Level::Error),
        "warn" | "warning" | "1" => Some(Level::Warn),
        "info" | "2" => Some(Level::Info),
        "debug" | "3" => Some(Level::Debug),
        _ => None,
    }
}

/// Resolve an environment value to a level, flagging unrecognized
/// input (which falls back to `Info` rather than silently changing
/// verbosity in either direction).
pub fn level_from_env(v: &str) -> (Level, bool) {
    match parse_level(v) {
        Some(lvl) => (lvl, false),
        None => (Level::Info, true),
    }
}

/// Initialize from the `BAECHI_LOG` environment variable (idempotent).
/// An unrecognized value maps to `Info` and warns once on stderr.
pub fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("BAECHI_LOG") {
            let (lvl, unknown) = level_from_env(&v);
            LEVEL.store(lvl as u8, Ordering::Relaxed);
            if unknown {
                eprintln!(
                    "[baechi WARN ] BAECHI_LOG={v:?} not recognized \
                     (expected error|warn|info|debug or 0-3); using info"
                );
            }
        }
    });
}

/// Set the level programmatically.
pub fn set_level(level: Level) {
    INIT.get_or_init(|| ());
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether the given level is enabled.
pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

thread_local! {
    /// Trace id of the innermost open span on this thread; 0 = none.
    static TRACE_CTX: Cell<u64> = const { Cell::new(0) };
}

/// Install `trace` as this thread's log context, returning the
/// previous value so the caller (a span guard) can restore nesting on
/// drop. Pass 0 to clear.
pub fn set_trace_context(trace: u64) -> u64 {
    TRACE_CTX.with(|c| c.replace(trace))
}

/// The current thread's trace context (0 = none).
pub fn trace_context() -> u64 {
    TRACE_CTX.with(|c| c.get())
}

/// Emit a log line.
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        let ctx = trace_context();
        if ctx != 0 {
            eprintln!("[baechi {tag} t={ctx:08x}] {args}");
        } else {
            eprintln!("[baechi {tag}] {args}");
        }
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_level_accepts_names_and_numbers() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("0"), Some(Level::Error));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("warning"), Some(Level::Warn));
        assert_eq!(parse_level("1"), Some(Level::Warn));
        assert_eq!(parse_level(" info "), Some(Level::Info));
        assert_eq!(parse_level("2"), Some(Level::Info));
        assert_eq!(parse_level("Debug"), Some(Level::Debug));
        assert_eq!(parse_level("3"), Some(Level::Debug));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("4"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn unknown_env_value_flags_and_falls_back_to_info() {
        assert_eq!(level_from_env("trace"), (Level::Info, true));
        assert_eq!(level_from_env("-1"), (Level::Info, true));
        assert_eq!(level_from_env("debug"), (Level::Debug, false));
        assert_eq!(level_from_env("0"), (Level::Error, false));
    }

    #[test]
    fn trace_context_nests_and_restores() {
        assert_eq!(trace_context(), 0);
        let prev = set_trace_context(0xabc);
        assert_eq!(prev, 0);
        assert_eq!(trace_context(), 0xabc);
        let prev2 = set_trace_context(0xdef);
        assert_eq!(prev2, 0xabc);
        set_trace_context(prev2);
        assert_eq!(trace_context(), 0xabc);
        set_trace_context(prev);
        assert_eq!(trace_context(), 0);
    }
}
