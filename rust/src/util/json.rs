//! Minimal JSON value, parser, and writer.
//!
//! Replaces `serde_json` (unavailable offline). Used for the artifact
//! manifest (`artifacts/manifest.json`), experiment reports, and config
//! files. Supports the full JSON grammar minus `\u` surrogate pairs beyond
//! the BMP (sufficient for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if not an object.
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && self.bytes[self.pos] & 0xc0 == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let mut obj = Json::obj();
        obj.set("name", "baechi")
            .set("n", 4u64)
            .set("ratio", 0.375)
            .set("ok", true)
            .set("tags", Json::Arr(vec!["a".into(), "b".into()]));
        let text = obj.pretty();
        assert_eq!(Json::parse(&text).unwrap(), obj);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::Str("héllo → ⊕".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
