//! In-repo substrates for crates unavailable in the offline registry.
//!
//! The build is fully dependency-free (no registry access in the build
//! image; even the `xla` PJRT bindings are stubbed in
//! [`crate::runtime::xla`]), so this module provides the small,
//! well-tested pieces a production repo would normally pull from
//! crates.io:
//!
//! * [`rng`] — PCG-64 pseudo-random generator (replaces `rand`).
//! * [`json`] — minimal JSON value, parser and writer (replaces `serde_json`).
//! * [`cli`] — flag/option argument parser (replaces `clap`).
//! * [`stats`] — summary statistics for measurements.
//! * [`table`] — ASCII table rendering for bench/report output.
//! * [`bench`] — warmup+iteration measurement harness (replaces `criterion`).
//! * [`prop`] — seeded property-testing harness (replaces `proptest`).
//! * [`log`] — leveled stderr logger.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
