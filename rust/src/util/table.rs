//! ASCII table rendering for benchmark and report output.
//!
//! Every `cargo bench` target prints the paper's table/figure rows through
//! this so EXPERIMENTS.md can paste output verbatim.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render the table to a string.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cells[i])),
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds human-readably (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.3} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "time"]);
        t.row_strs(&["inception", "1.0"]);
        t.row_strs(&["gnmt", "12.5"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("| inception |"));
        // right alignment on numeric column
        assert!(r.contains("|  1.0 |"));
    }

    #[test]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row_strs(&["only-one"]);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
        assert!(fmt_secs(2e-7).contains("ns"));
    }
}
