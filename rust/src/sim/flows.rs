//! Bandwidth-sharing flow network for parallel-comm simulation.
//!
//! Each in-flight transfer becomes a [`Flow`] holding every link on its
//! route. Between events the network is in steady state: rates are the
//! max-min fair allocation over link capacities, computed by
//! water-filling with per-flow rate caps (a flow never exceeds the
//! end-to-end bandwidth of its pair model, so an uncontended flow
//! finishes exactly when the closed-form `CommModel::time` says).
//!
//! Rates are recomputed on every flow arrival and departure. A rate
//! change bumps the flow's generation counter and schedules a fresh
//! drain event; stale events (older generation) are skipped at pop
//! time — see [`super::events`].
//!
//! Contention accounting: over an interval `dt`, a flow whose rate is
//! held below its cap by a bottleneck link accrues
//! `dt * (1 - rate / cap)` of *slowdown* on that link. Integrated over
//! the flow's lifetime this equals exactly the extra seconds the
//! transfer spent in flight versus running alone, which is what
//! `ContentionReport::blocked_seconds` means in parallel-comm mode.

use super::engine::ContentionReport;

/// One in-flight transfer, as seen by the flow network.
#[derive(Debug, Clone)]
pub struct Flow {
    /// Index into the simulator's transfer table.
    pub transfer: usize,
    /// Link indices this flow holds, in route order.
    pub path: Vec<usize>,
    /// Rate cap: the pair model's end-to-end bandwidth (bytes/s).
    pub cap: f64,
    /// Path latency, paid as a tail after the last byte drains.
    pub latency: f64,
    /// Bytes not yet drained.
    pub remaining: f64,
    /// Current allocated rate (bytes/s).
    pub rate: f64,
    /// The link holding this flow below its cap, if any.
    pub bottleneck: Option<usize>,
    /// Bumped on every rate change; stale drain events carry older values.
    pub gen: u64,
    /// False once the flow drained and was removed.
    pub alive: bool,
}

/// The set of active flows over the topology's links.
#[derive(Debug, Default)]
pub struct FlowNet {
    /// Per-link capacity in bytes/s (may be infinite).
    capacity: Vec<f64>,
    /// All flows ever created this run; drained flows stay (alive=false)
    /// so generation checks remain O(1).
    flows: Vec<Flow>,
    /// Indices of alive flows, in insertion order (deterministic ties).
    active: Vec<usize>,
    /// Number of active flows crossing each link.
    on_link: Vec<usize>,
    /// Simulated time up to which flow state has been integrated.
    last_t: f64,
}

impl FlowNet {
    pub fn new(capacity: Vec<f64>) -> FlowNet {
        let n = capacity.len();
        FlowNet {
            capacity,
            flows: Vec::new(),
            active: Vec::new(),
            on_link: vec![0; n],
            last_t: 0.0,
        }
    }

    /// How many active flows currently cross link `l`.
    pub fn active_on(&self, l: usize) -> usize {
        self.on_link[l]
    }

    /// Is a drain event for (`flow`, `gen`) still current?
    pub fn valid(&self, flow: usize, gen: u64) -> bool {
        self.flows
            .get(flow)
            .map_or(false, |f| f.alive && f.gen == gen)
    }

    /// Advance flow state to time `t`: drain bytes at current rates and
    /// book busy/slowdown seconds into the report.
    pub fn integrate_to(&mut self, t: f64, report: &mut ContentionReport) {
        let dt = t - self.last_t;
        self.last_t = self.last_t.max(t);
        if dt <= 0.0 || self.active.is_empty() {
            return;
        }
        for (l, &c) in self.on_link.iter().enumerate() {
            if c > 0 {
                report.links[l].busy += dt;
            }
        }
        for &f in &self.active {
            let flow = &mut self.flows[f];
            flow.remaining = (flow.remaining - flow.rate * dt).max(0.0);
            if let Some(l) = flow.bottleneck {
                let slow = dt * (1.0 - flow.rate / flow.cap);
                if slow > 0.0 {
                    report.links[l].blocked += slow;
                    report.blocked_seconds += slow;
                }
            }
        }
    }

    /// Register a new flow. The caller must `reallocate` afterwards.
    pub fn add(
        &mut self,
        transfer: usize,
        path: Vec<usize>,
        cap: f64,
        latency: f64,
        bytes: u64,
    ) -> usize {
        debug_assert!(cap.is_finite() && cap > 0.0, "flow cap must be finite");
        debug_assert!(!path.is_empty(), "flow must hold at least one link");
        for &l in &path {
            self.on_link[l] += 1;
        }
        let id = self.flows.len();
        self.flows.push(Flow {
            transfer,
            path,
            cap,
            latency,
            remaining: bytes as f64,
            rate: 0.0,
            bottleneck: None,
            gen: 0,
            alive: true,
        });
        self.active.push(id);
        id
    }

    /// Retire a drained flow; returns its transfer index and path
    /// latency (the tail still owed before delivery).
    pub fn remove(&mut self, flow: usize) -> (usize, f64) {
        let pos = self
            .active
            .iter()
            .position(|&f| f == flow)
            .expect("removing a flow that is not active");
        self.active.remove(pos);
        let f = &mut self.flows[flow];
        f.alive = false;
        for &l in &f.path {
            self.on_link[l] -= 1;
        }
        (f.transfer, f.latency)
    }

    /// Recompute the max-min fair allocation and return fresh drain
    /// events `(flow, generation, drain_time)` for every flow whose
    /// rate or bottleneck changed. `t` is the current simulated time;
    /// the caller must have integrated state to `t` first.
    ///
    /// Water-filling with caps: repeatedly take the tightest
    /// constraint — either the smallest per-link fair share
    /// (`residual / crossing_flows`) or the smallest unfrozen cap.
    /// A cap-frozen flow has headroom on every link it crosses
    /// (`bottleneck: None`); a link-frozen flow is held below its cap
    /// by that link (`bottleneck: Some(l)`). On a tie the cap wins, so
    /// flows that fit exactly are not reported as contended. Ties
    /// between links resolve to the lowest index and between flows to
    /// insertion order, keeping replays deterministic.
    pub fn reallocate(&mut self, t: f64) -> Vec<(usize, u64, f64)> {
        let n = self.active.len();
        let mut residual = self.capacity.clone();
        let mut count = vec![0usize; residual.len()];
        for &f in &self.active {
            for &l in &self.flows[f].path {
                count[l] += 1;
            }
        }
        let mut frozen = vec![false; n];
        let mut assigned: Vec<(f64, Option<usize>)> = vec![(0.0, None); n];
        let mut unfrozen = n;
        while unfrozen > 0 {
            let mut best_fair = f64::INFINITY;
            let mut best_link = None;
            for (l, (&res, &c)) in residual.iter().zip(count.iter()).enumerate() {
                if c > 0 && res.is_finite() {
                    let fair = res / c as f64;
                    if fair < best_fair {
                        best_fair = fair;
                        best_link = Some(l);
                    }
                }
            }
            let mut best_cap = f64::INFINITY;
            let mut cap_pos = None;
            for (pos, &f) in self.active.iter().enumerate() {
                if !frozen[pos] && self.flows[f].cap < best_cap {
                    best_cap = self.flows[f].cap;
                    cap_pos = Some(pos);
                }
            }
            if best_cap <= best_fair {
                // This flow tops out below every shared link's fair
                // share: freeze it at its cap, uncontended.
                let pos = cap_pos.expect("an unfrozen flow must exist");
                frozen[pos] = true;
                unfrozen -= 1;
                assigned[pos] = (best_cap, None);
                for &l in &self.flows[self.active[pos]].path {
                    residual[l] = (residual[l] - best_cap).max(0.0);
                    count[l] -= 1;
                }
            } else {
                // The tightest link saturates: every unfrozen flow
                // crossing it is held at the fair share.
                let bl = best_link.expect("a finite fair share names a link");
                for pos in 0..n {
                    if frozen[pos] {
                        continue;
                    }
                    let f = self.active[pos];
                    if !self.flows[f].path.contains(&bl) {
                        continue;
                    }
                    frozen[pos] = true;
                    unfrozen -= 1;
                    assigned[pos] = (best_fair, Some(bl));
                    for &l in &self.flows[f].path {
                        residual[l] = (residual[l] - best_fair).max(0.0);
                        count[l] -= 1;
                    }
                }
            }
        }
        let mut out = Vec::new();
        for (pos, &(rate, bneck)) in assigned.iter().enumerate() {
            let f = self.active[pos];
            let flow = &mut self.flows[f];
            let changed = flow.rate.to_bits() != rate.to_bits() || flow.bottleneck != bneck;
            flow.rate = rate;
            flow.bottleneck = bneck;
            if changed {
                debug_assert!(rate > 0.0, "flow assigned a zero rate");
                flow.gen += 1;
                out.push((f, flow.gen, t + flow.remaining / rate));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(n_links: usize) -> ContentionReport {
        ContentionReport::new(n_links)
    }

    #[test]
    fn flow_two_flows_share_a_trunk_fairly() {
        let mut net = FlowNet::new(vec![1.0]);
        let a = net.add(0, vec![0], 1.0, 0.0, 10);
        let b = net.add(1, vec![0], 1.0, 0.0, 10);
        let evs = net.reallocate(0.0);
        assert_eq!(evs.len(), 2);
        assert_eq!(net.flows[a].rate, 0.5);
        assert_eq!(net.flows[b].rate, 0.5);
        assert_eq!(net.flows[a].bottleneck, Some(0));
        // Drain events at t = 10 / 0.5 = 20.
        for &(_, _, t_done) in &evs {
            assert!((t_done - 20.0).abs() < 1e-12);
        }
    }

    #[test]
    fn flow_cap_limited_flow_leaves_headroom() {
        let mut net = FlowNet::new(vec![10.0]);
        let a = net.add(0, vec![0], 2.0, 0.0, 10);
        let b = net.add(1, vec![0], 10.0, 0.0, 10);
        net.reallocate(0.0);
        // Flow a tops out at its cap (fair share would be 5), flow b
        // soaks up the rest of the trunk.
        assert_eq!(net.flows[a].rate, 2.0);
        assert_eq!(net.flows[a].bottleneck, None);
        assert_eq!(net.flows[b].rate, 8.0);
        assert_eq!(net.flows[b].bottleneck, Some(0));
    }

    #[test]
    fn flow_exact_fit_capacity_shows_no_bottleneck() {
        // Two cap-1 flows on a capacity-2 trunk fit exactly: the tie
        // rule must freeze them at their caps, uncontended.
        let mut net = FlowNet::new(vec![2.0]);
        let a = net.add(0, vec![0], 1.0, 0.0, 10);
        let b = net.add(1, vec![0], 1.0, 0.0, 10);
        net.reallocate(0.0);
        assert_eq!(net.flows[a].rate, 1.0);
        assert_eq!(net.flows[b].rate, 1.0);
        assert_eq!(net.flows[a].bottleneck, None);
        assert_eq!(net.flows[b].bottleneck, None);
    }

    #[test]
    fn flow_integration_drains_and_books_slowdown() {
        let mut net = FlowNet::new(vec![1.0]);
        let a = net.add(0, vec![0], 1.0, 0.0, 10);
        let b = net.add(1, vec![0], 1.0, 0.0, 10);
        net.reallocate(0.0);
        let mut rep = report(1);
        net.integrate_to(4.0, &mut rep);
        assert_eq!(net.flows[a].remaining, 8.0);
        assert_eq!(net.flows[b].remaining, 8.0);
        // Each flow runs at half its cap: 4s * 0.5 slowdown * 2 flows.
        assert!((rep.blocked_seconds - 4.0).abs() < 1e-12);
        assert!((rep.links[0].blocked - 4.0).abs() < 1e-12);
        assert!((rep.links[0].busy - 4.0).abs() < 1e-12);
    }

    #[test]
    fn flow_departure_speeds_up_survivors_and_bumps_generation() {
        let mut net = FlowNet::new(vec![1.0]);
        let a = net.add(7, vec![0], 1.0, 0.25, 10);
        let b = net.add(8, vec![0], 1.0, 0.0, 10);
        net.reallocate(0.0);
        let gen_before = net.flows[b].gen;
        let mut rep = report(1);
        net.integrate_to(10.0, &mut rep);
        let (transfer, latency) = net.remove(a);
        assert_eq!(transfer, 7);
        assert_eq!(latency, 0.25);
        let evs = net.reallocate(10.0);
        assert_eq!(evs.len(), 1);
        assert_eq!(net.flows[b].rate, 1.0);
        assert!(net.flows[b].gen > gen_before);
        // 5 bytes left at full rate: drains at t = 15.
        assert!((evs[0].2 - 15.0).abs() < 1e-12);
    }

    #[test]
    fn flow_stale_generations_are_invalid() {
        let mut net = FlowNet::new(vec![1.0]);
        let a = net.add(0, vec![0], 1.0, 0.0, 10);
        net.reallocate(0.0);
        assert!(net.valid(a, net.flows[a].gen));
        assert!(!net.valid(a, net.flows[a].gen + 1));
        let b = net.add(1, vec![0], 1.0, 0.0, 10);
        net.reallocate(0.0);
        // a's rate halved: its generation moved on.
        assert!(!net.valid(a, 1));
        assert!(net.valid(a, net.flows[a].gen));
        net.remove(b);
        assert!(!net.valid(b, net.flows[b].gen), "dead flows are invalid");
    }
}
