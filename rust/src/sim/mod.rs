//! The Execution Simulator (paper §4.2): evaluates a placement's step
//! time, memory behaviour, and communication profile on the simulated
//! cluster. The placers embed a lighter schedule (placer::sched); this
//! module is the richer evaluation engine used for Tables 4–7 and
//! Figures 7–8.

pub mod engine;
pub mod events;
pub mod flows;
pub mod memory;

pub use engine::{
    simulate, ContentionReport, Framework, LinkUse, OpSpan, SimConfig, SimResult, SimSchedule,
    TransferSpan, QUEUE_DEPTH_BUCKETS,
};
pub use memory::OomError;
