//! Event-driven execution simulator (paper §4.2).
//!
//! Replays a placed graph on the simulated cluster:
//!
//! * each device runs its ops **in topological order** (the order
//!   Baechi's ES prescribes; Baechi-PY enforces it at runtime, §4.4),
//!   one at a time, waiting for input tensors;
//! * outputs are pushed greedily to consumer devices as soon as they are
//!   produced (the Baechi-PY communication protocol, §3.2.2), with
//!   per-destination caching (§4.2); in sequential-comm mode (§3.1.4) a
//!   transfer occupies every interconnect link on its topology path —
//!   one transfer at a time **per link**, so transfers sharing a NIC
//!   trunk queue while disjoint NVLink pairs overlap. Under a uniform
//!   topology the links are exactly the per-device transfer engines of
//!   the paper's testbed, bit-for-bit;
//! * in parallel-comm mode each transfer becomes a bandwidth-shared
//!   *flow* over its path: concurrent flows split link capacity max-min
//!   fairly, rates are recomputed on every arrival/departure event
//!   ([`super::flows`]), and each link enforces a finite queue depth —
//!   arrivals beyond [`SimConfig::queue_limit`] are tallied in
//!   [`ContentionReport::drop_warnings`] (drop-tail accounting; the
//!   payload still flows, the counter flags an unrealistic burst);
//! * with `overlap_comm = false` (Table 7's "without protocol" baseline,
//!   the blocking `.to()` call) a transfer additionally occupies both
//!   endpoints' compute engines;
//! * memory follows the dynamic model of [`super::memory`], with
//!   TensorFlow semantics (outputs freed when consumers finish) or
//!   PyTorch semantics (forward outputs additionally held until the
//!   matching backward finishes);
//! * alongside the step time, the simulator keeps a passive
//!   [`ContentionReport`]: per-link busy/blocked seconds, queue-depth
//!   samples, and the largest transfers per link, populated in **both**
//!   comm modes (serialized waits in sequential mode, flow slowdown in
//!   parallel mode). It never alters the event order — results with and
//!   without it are bit-identical — and feeds the [`crate::feedback`]
//!   re-placement loop.

use super::events::{Event, EventQueue, Timed};
use super::flows::FlowNet;
use super::memory::{DeviceMem, OomError};
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::profile::Cluster;
use crate::topology::contention::LinkQueues;
use std::collections::{BTreeMap, BinaryHeap};

/// Which framework's memory semantics to model (paper Table 2 / §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Framework {
    TensorFlow,
    PyTorch,
}

/// Simulator configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub framework: Framework,
    /// Overlap communication with compute (Baechi-PY protocol). When
    /// false, transfers block both endpoint devices (naive `.to()`).
    pub overlap_comm: bool,
    /// Finite per-link queue depth (drop-tail accounting): a transfer
    /// that arrives at a link already carrying/queueing this many
    /// increments [`ContentionReport::drop_warnings`] instead of being
    /// dropped — the simulated payload still goes through, the counter
    /// flags that a real switch would have shed load.
    pub queue_limit: usize,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            framework: Framework::TensorFlow,
            overlap_comm: true,
            queue_limit: QUEUE_DEPTH_BUCKETS - 1,
        }
    }
}

/// Buckets of [`ContentionReport::queue_depth_hist`]: index = observed
/// queue depth, with the last bucket collecting that depth and deeper.
pub const QUEUE_DEPTH_BUCKETS: usize = 9;

/// Largest transfers remembered per link in [`LinkUse::top_ops`].
const TOP_OPS_PER_LINK: usize = 8;

/// Per-link usage accounting of one simulated step.
#[derive(Debug, Clone, Default)]
pub struct LinkUse {
    /// Link index into [`crate::topology::Topology::links`].
    pub link: usize,
    /// Seconds this link spent mid-transfer.
    pub busy: f64,
    /// Seconds lost to this link. Sequential mode: time transfers
    /// crossing it spent queued before starting — waiting on a busy
    /// link, or (in blocking-communication mode) on a busy endpoint
    /// compute engine. The blocking resource is not attributed
    /// individually: a transfer's wait is split evenly across its
    /// path's links, so summing `blocked` along a path reconstructs the
    /// observed wait once (pairwise costs re-sum per-link latencies,
    /// which would otherwise multiply an injected delay by the path
    /// length — see [`crate::feedback::TopologyAdjustment`]). Parallel
    /// mode: slowdown seconds of flows bottlenecked *on this link* —
    /// `dt * (1 - rate/cap)` integrated while the link holds them below
    /// their uncontended rate.
    pub blocked: f64,
    /// Transfers whose path crossed this link.
    pub transfers: usize,
    /// Payload bytes carried over this link.
    pub bytes: u64,
    /// Largest transfers that crossed this link, as `(bytes, producer
    /// op)`, sorted by bytes descending, at most [`TOP_OPS_PER_LINK`].
    pub top_ops: Vec<(u64, NodeId)>,
}

impl LinkUse {
    /// Fraction of the step this link spent mid-transfer.
    pub fn utilization(&self, makespan: f64) -> f64 {
        if makespan > 0.0 {
            self.busy / makespan
        } else {
            0.0
        }
    }
}

/// What the simulator observed about interconnect contention during one
/// step: the measurement side of the sim → engine → placer feedback
/// loop (see [`crate::feedback`]). Populated in **both** comm modes. In
/// sequential mode a link is an exclusive resource and `blocked` means
/// serialized queueing before a transfer starts; in parallel mode
/// concurrent flows share bandwidth max-min fairly and `blocked` means
/// *slowdown* — the extra in-flight seconds a flow spent below its
/// uncontended rate, attributed to its bottleneck link. Both reduce to
/// "seconds lost to the interconnect versus running alone", which is
/// what the re-placement policy thresholds against.
#[derive(Debug, Clone, Default)]
pub struct ContentionReport {
    /// Step time the link usage is measured against.
    pub makespan: f64,
    /// Per-link accounting, indexed by link id.
    pub links: Vec<LinkUse>,
    /// Queue-depth samples: in sequential mode, taken whenever a link
    /// frees (bucket `d` counts observations of `d` transfers still
    /// waiting); in parallel mode, taken per path link whenever a flow
    /// arrives (bucket `d` counts arrivals seeing `d` concurrent flows,
    /// self included). Last bucket = that depth or deeper.
    pub queue_depth_hist: Vec<u64>,
    /// Total seconds lost to the interconnect: queued-before-start in
    /// sequential mode, below-cap slowdown in parallel mode.
    pub blocked_seconds: f64,
    /// Total link-seconds spent mid-transfer (sum of per-link busy).
    pub busy_seconds: f64,
    /// Flow arrivals that found a link's queue past
    /// [`SimConfig::queue_limit`] (drop-tail events a real switch would
    /// have shed). The payload still flows; non-zero means the burst
    /// was unrealistically deep for the modeled hardware.
    pub drop_warnings: u64,
}

impl ContentionReport {
    pub(crate) fn new(n_links: usize) -> ContentionReport {
        ContentionReport {
            makespan: 0.0,
            links: (0..n_links)
                .map(|link| LinkUse {
                    link,
                    ..LinkUse::default()
                })
                .collect(),
            queue_depth_hist: vec![0; QUEUE_DEPTH_BUCKETS],
            blocked_seconds: 0.0,
            busy_seconds: 0.0,
            drop_warnings: 0,
        }
    }

    /// Record a transfer starting after `waited` seconds in the queue.
    fn on_start(&mut self, path: &[usize], dt: f64, waited: f64, bytes: u64, node: NodeId) {
        if path.is_empty() {
            return;
        }
        let waited = waited.max(0.0);
        self.blocked_seconds += waited;
        self.busy_seconds += dt * path.len() as f64;
        // Split the wait across the path (see LinkUse::blocked).
        let wait_share = waited / path.len() as f64;
        for &l in path {
            let u = &mut self.links[l];
            u.busy += dt;
            u.blocked += wait_share;
            u.transfers += 1;
            u.bytes += bytes;
            u.top_ops.push((bytes, node));
            if u.top_ops.len() > 4 * TOP_OPS_PER_LINK {
                Self::shrink_top_ops(&mut u.top_ops);
            }
        }
    }

    /// Record a flow entering the network (parallel-comm mode). Busy
    /// and slowdown seconds are integrated by the flow network as time
    /// advances ([`FlowNet::integrate_to`]); here we book only the
    /// per-link traffic counters plus any pre-start wait (blocking-comm
    /// mode can still hold a transfer on a busy endpoint).
    fn on_flow_start(&mut self, path: &[usize], waited: f64, bytes: u64, node: NodeId) {
        if path.is_empty() {
            return;
        }
        let waited = waited.max(0.0);
        self.blocked_seconds += waited;
        let wait_share = waited / path.len() as f64;
        for &l in path {
            let u = &mut self.links[l];
            u.blocked += wait_share;
            u.transfers += 1;
            u.bytes += bytes;
            u.top_ops.push((bytes, node));
            if u.top_ops.len() > 4 * TOP_OPS_PER_LINK {
                Self::shrink_top_ops(&mut u.top_ops);
            }
        }
    }

    fn shrink_top_ops(ops: &mut Vec<(u64, NodeId)>) {
        ops.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        ops.truncate(TOP_OPS_PER_LINK);
    }

    /// Record the number of transfers still waiting on a link.
    fn sample_depth(&mut self, depth: usize) {
        let bucket = depth.min(QUEUE_DEPTH_BUCKETS - 1);
        self.queue_depth_hist[bucket] += 1;
    }

    fn finalize(&mut self, makespan: f64) {
        self.makespan = makespan;
        for u in &mut self.links {
            // Busy time is booked in full when a transfer starts; an
            // OOM-truncated step can end before in-flight transfers do,
            // so cap at the truncated makespan to keep utilization ≤ 1.
            u.busy = u.busy.min(makespan);
            Self::shrink_top_ops(&mut u.top_ops);
        }
        self.busy_seconds = self.links.iter().map(|u| u.busy).sum();
    }

    /// Utilization of one link over the whole step.
    pub fn utilization(&self, link: usize) -> f64 {
        self.links[link].utilization(self.makespan)
    }

    /// Highest per-link utilization (0 when nothing was transferred).
    pub fn max_utilization(&self) -> f64 {
        self.links
            .iter()
            .map(|u| u.utilization(self.makespan))
            .fold(0.0, f64::max)
    }

    /// Queued seconds as a fraction of the step time. Can exceed 1 when
    /// many transfers wait concurrently.
    pub fn blocked_fraction(&self) -> f64 {
        if self.makespan > 0.0 {
            self.blocked_seconds / self.makespan
        } else {
            0.0
        }
    }

    /// Links whose utilization reaches `threshold`, ascending by id.
    pub fn saturated_links(&self, threshold: f64) -> Vec<usize> {
        self.links
            .iter()
            .filter(|u| u.utilization(self.makespan) >= threshold)
            .map(|u| u.link)
            .collect()
    }

    /// The `k` busiest links that carried traffic, busiest first (ties
    /// broken by link id).
    pub fn top_saturated(&self, k: usize) -> Vec<&LinkUse> {
        let mut used: Vec<&LinkUse> = self.links.iter().filter(|u| u.busy > 0.0).collect();
        used.sort_by(|a, b| {
            b.busy
                .partial_cmp(&a.busy)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.link.cmp(&b.link))
        });
        used.truncate(k);
        used
    }
}

/// One op's execution interval on a device, as replayed by the
/// simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct OpSpan {
    pub node: NodeId,
    pub device: usize,
    /// Seconds into the simulated step.
    pub start: f64,
    pub end: f64,
}

/// One tensor transfer's in-flight interval (from the moment it holds
/// links / joins the flow network until delivery at the destination).
#[derive(Debug, Clone, PartialEq)]
pub struct TransferSpan {
    /// Producer op of the transferred tensor.
    pub node: NodeId,
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
    /// Topology links on the transfer's path (empty for same-device).
    pub links: Vec<usize>,
    pub start: f64,
    pub end: f64,
}

/// The full timeline of one simulated step: what ran where, when, and
/// what moved over which links. Recorded unconditionally (it is a
/// by-product of the event loop, not a second schedule computation) and
/// exported to Chrome trace-event JSON by
/// [`crate::telemetry::chrome`]. For a non-OOM step [`max_end`] equals
/// [`SimResult::makespan`] bit-for-bit: every event that advances the
/// makespan closes a span at the same instant.
///
/// [`max_end`]: SimSchedule::max_end
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimSchedule {
    pub ops: Vec<OpSpan>,
    pub transfers: Vec<TransferSpan>,
}

impl SimSchedule {
    /// Latest interval end across ops and transfers (0 when empty).
    pub fn max_end(&self) -> f64 {
        let op_end = self.ops.iter().map(|s| s.end).fold(0.0, f64::max);
        self.transfers
            .iter()
            .map(|s| s.end)
            .fold(op_end, f64::max)
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Step time (seconds); meaningful only when `oom.is_none()`.
    pub makespan: f64,
    pub peak_memory: Vec<u64>,
    pub oom: Option<OomError>,
    pub transfers: usize,
    pub transfer_bytes: u64,
    /// Per-device compute busy time, seconds.
    pub busy: Vec<f64>,
    pub events: usize,
    /// Per-link contention observations (feeds re-placement).
    pub contention: ContentionReport,
    /// Executed timeline (per-device op intervals, per-link transfer
    /// intervals); an OOM-truncated step keeps what ran before the
    /// failure.
    pub schedule: SimSchedule,
}

impl SimResult {
    pub fn ok(&self) -> bool {
        self.oom.is_none()
    }
}

#[derive(Debug, Clone)]
struct Transfer {
    node: NodeId,
    src: usize,
    dst: usize,
    bytes: u64,
    /// When the producer finished and the transfer joined the queue.
    enqueued_at: f64,
    /// When the transfer actually began (valid once `started`).
    started_at: f64,
    started: bool,
    done: bool,
}

/// Simulate one training step of `graph` under `placement`.
pub fn simulate(
    graph: &OpGraph,
    cluster: &Cluster,
    placement: &BTreeMap<NodeId, DeviceId>,
    cfg: SimConfig,
) -> SimResult {
    let n = cluster.n();
    let topo = cluster.effective_topology();
    let cap = graph.capacity();
    let dev_of = |id: NodeId| placement[&id].0;

    // Each device runs the lowest-topo-rank *ready* op among its
    // assigned ops (the paper's global ready queue, partitioned by
    // placement). Readiness feeds per-device heaps.
    let ranks = graph.topo_ranks();
    let mut ready: Vec<BinaryHeap<std::cmp::Reverse<(usize, NodeId)>>> =
        (0..n).map(|_| BinaryHeap::new()).collect();

    // Consumers of each tensor, grouped by device (small linear maps —
    // the cluster has a handful of devices; §Perf iteration 4 replaced
    // BTreeMaps on the per-event path).
    let mut consumers: Vec<Vec<(usize, Vec<NodeId>)>> = vec![Vec::new(); cap];
    for id in graph.node_ids() {
        for &(s, _) in graph.successors(id) {
            let d = dev_of(s);
            let slot = &mut consumers[id.0];
            match slot.iter_mut().find(|(dd, _)| *dd == d) {
                Some((_, v)) => v.push(s),
                None => slot.push((d, vec![s])),
            }
        }
    }
    let find = |m: &Vec<(usize, Vec<NodeId>)>, d: usize| -> Option<usize> {
        m.iter().position(|(dd, _)| *dd == d)
    };
    // Max bytes needed per (tensor, destination device).
    let mut edge_bytes: Vec<Vec<(usize, u64)>> = vec![Vec::new(); cap];
    for e in graph.edges() {
        let d = dev_of(e.dst);
        let slot = &mut edge_bytes[e.src.0];
        match slot.iter_mut().find(|(dd, _)| *dd == d) {
            Some((_, b)) => *b = (*b).max(e.bytes),
            None => slot.push((d, e.bytes)),
        }
    }
    // PyTorch: backward holds per forward node.
    let mut bwd_holds: Vec<usize> = vec![0; cap];
    if cfg.framework == Framework::PyTorch {
        for nd in graph.iter_nodes() {
            if nd.is_backward {
                if let Some(f) = nd.forward_of {
                    bwd_holds[f.0] += 1;
                }
            }
        }
    }

    // Missing inputs per node (distinct producer tensors on my device).
    let mut missing: Vec<usize> = vec![0; cap];
    for id in graph.node_ids() {
        missing[id.0] = graph.predecessors(id).len();
    }

    let mut mem: Vec<DeviceMem> = cluster.devices.iter().map(|d| DeviceMem::new(d.memory)).collect();
    let mut result = SimResult {
        makespan: 0.0,
        peak_memory: vec![0; n],
        oom: None,
        transfers: 0,
        transfer_bytes: 0,
        busy: vec![0.0; n],
        events: 0,
        contention: ContentionReport::new(topo.n_links()),
        schedule: SimSchedule::default(),
    };
    let finish_with = |mut r: SimResult, mem: &[DeviceMem], oom: Option<OomError>| -> SimResult {
        r.peak_memory = mem.iter().map(|m| m.peak).collect();
        r.oom = oom;
        let makespan = r.makespan;
        r.contention.finalize(makespan);
        r
    };

    // Pre-allocate permanent memory (params + grads) at t = 0.
    for id in graph.node_ids() {
        let nd = graph.node(id);
        let perm = nd.mem.params + nd.mem.param_grad;
        if perm > 0 {
            if let Err(e) = mem[dev_of(id)].alloc_permanent(perm, dev_of(id), 0.0, &nd.name) {
                return finish_with(result, &mem, Some(e));
            }
        }
    }

    let mut events = EventQueue::new();
    let mut compute_busy_until: Vec<f64> = vec![0.0; n]; // for bookkeeping only
    let mut compute_idle: Vec<bool> = vec![true; n];
    // Per-link contention state. Sequential comm: busy flags plus
    // waiter queues (§3.1.4 generalized from per-device engines to
    // topology links). Parallel comm: a max-min fair flow network over
    // the same links.
    let mut links = LinkQueues::new(topo.n_links());
    let mut flownet = FlowNet::new(topo.links().iter().map(|l| l.comm.bandwidth).collect());
    let mut transfers: Vec<Transfer> = Vec::new();
    // Un-started transfers indexed under BOTH endpoint devices, so an
    // engine freeing only rescans its own queue (§Perf iteration 3 —
    // the global pending scan was the ES's top hot spot).
    let mut pend: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut done_ops = 0usize;
    let total_ops = graph.len();

    // Seed the ready queues with source ops.
    for id in graph.node_ids() {
        if missing[id.0] == 0 {
            ready[dev_of(id)].push(std::cmp::Reverse((ranks[id.0], id)));
        }
    }

    // Try to start transfers/ops on the given dirty devices at `now`.
    // Only devices whose engine state or queues changed need a rescan.
    macro_rules! advance {
        ($now:expr, $dirty:expr) => {{
            let now = $now;
            for &d in $dirty.iter() {
                // Transfers touching device d (listed under both ends).
                let mut i = 0;
                while i < pend[d].len() {
                    let idx = pend[d][i];
                    if transfers[idx].started {
                        pend[d].swap_remove(i); // twin entry, already gone
                        continue;
                    }
                    let (src, dst) = (transfers[idx].src, transfers[idx].dst);
                    let path = topo.path(src, dst);
                    let engines_free = if cluster.sequential_comm {
                        links.all_free(path)
                    } else {
                        true
                    };
                    let compute_ok =
                        cfg.overlap_comm || (compute_idle[src] && compute_idle[dst]);
                    if engines_free && compute_ok {
                        pend[d].swap_remove(i);
                        transfers[idx].started = true;
                        transfers[idx].started_at = now;
                        let dt = topo.time(src, dst, transfers[idx].bytes);
                        let waited = now - transfers[idx].enqueued_at;
                        if cluster.sequential_comm {
                            result.contention.on_start(
                                path,
                                dt,
                                waited,
                                transfers[idx].bytes,
                                transfers[idx].node,
                            );
                            links.acquire(path);
                            events.push(now + dt, Event::TransferDone { idx });
                        } else {
                            // Parallel comm: the transfer becomes a
                            // bandwidth-shared flow capped at its pair
                            // model's end-to-end rate, so an uncontended
                            // flow still finishes at exactly `now + dt`.
                            let pairm = *topo.pair(src, dst);
                            if transfers[idx].bytes > 0
                                && pairm.bandwidth.is_finite()
                                && !path.is_empty()
                            {
                                flownet.integrate_to(now, &mut result.contention);
                                result.contention.on_flow_start(
                                    path,
                                    waited,
                                    transfers[idx].bytes,
                                    transfers[idx].node,
                                );
                                for &l in path {
                                    let depth = flownet.active_on(l) + 1;
                                    result.contention.sample_depth(depth);
                                    if depth > cfg.queue_limit {
                                        result.contention.drop_warnings += 1;
                                    }
                                }
                                flownet.add(
                                    idx,
                                    path.to_vec(),
                                    pairm.bandwidth,
                                    pairm.latency,
                                    transfers[idx].bytes,
                                );
                                for (f, gen, t_done) in flownet.reallocate(now) {
                                    events.push(t_done, Event::FlowDrained { flow: f, gen });
                                }
                            } else {
                                // Zero-byte or infinite-bandwidth path:
                                // nothing to share, use the closed form.
                                events.push(now + dt, Event::TransferDone { idx });
                            }
                        }
                        if !cfg.overlap_comm {
                            compute_idle[src] = false;
                            compute_idle[dst] = false;
                        }
                    } else {
                        i += 1;
                    }
                }
                // Next ready op on d.
                if compute_idle[d] {
                    if let Some(std::cmp::Reverse((_, op))) = ready[d].pop() {
                        let nd = graph.node(op);
                        let tmp = nd.mem.temporary_training();
                        if tmp > 0 {
                            if let Err(e) = mem[d].alloc_temp(tmp, d, now, &nd.name) {
                                return finish_with(result, &mem, Some(e));
                            }
                        }
                        compute_idle[d] = false;
                        let dt = nd.compute / cluster.devices[d].speed;
                        result.busy[d] += dt;
                        compute_busy_until[d] = now + dt;
                        events.push(now + dt, Event::ComputeDone { dev: d, node: op });
                    }
                }
            }
        }};
    }

    {
        let all: Vec<usize> = (0..n).collect();
        advance!(0.0, all);
    }

    while let Some(Timed { t, ev, .. }) = events.pop() {
        // Stale drain events (the flow's rate changed since this was
        // scheduled) must be skipped before any bookkeeping: a flow
        // rescheduled *earlier* leaves a later stale event behind that
        // would otherwise inflate the makespan.
        if let Event::FlowDrained { flow, gen } = ev {
            if !flownet.valid(flow, gen) {
                continue;
            }
        }
        result.events += 1;
        result.makespan = result.makespan.max(t);
        match ev {
            Event::ComputeDone { dev, node } => {
                compute_idle[dev] = true;
                let nd = graph.node(node);
                // Timeline: the op ran [t - dt, t] (dt recomputed the
                // same way it was scheduled, so the interval is exact).
                result.schedule.ops.push(OpSpan {
                    node,
                    device: dev,
                    start: t - nd.compute / cluster.devices[dev].speed,
                    end: t,
                });
                let tmp = nd.mem.temporary_training();
                if tmp > 0 {
                    mem[dev].free_temp(tmp);
                }
                done_ops += 1;
                // Materialize the output tensor.
                let local_consumers = find(&consumers[node.0], dev)
                    .map(|k| consumers[node.0][k].1.len())
                    .unwrap_or(0);
                let n_remote = consumers[node.0].iter().filter(|(d, _)| *d != dev).count();
                let refs = local_consumers + n_remote + bwd_holds[node.0];
                if nd.mem.output > 0 && refs > 0 {
                    if let Err(e) = mem[dev].alloc_tensor(node, nd.mem.output, refs, dev, t) {
                        return finish_with(result, &mem, Some(e));
                    }
                }
                // Local consumers become one input closer to ready.
                if let Some(k) = find(&consumers[node.0], dev) {
                    for i in 0..consumers[node.0][k].1.len() {
                        let c = consumers[node.0][k].1[i];
                        missing[c.0] -= 1;
                        if missing[c.0] == 0 {
                            ready[dev].push(std::cmp::Reverse((ranks[c.0], c)));
                        }
                    }
                }
                // Greedy push to each remote consumer device (§3.2.2).
                let mut dirty: Vec<usize> = vec![dev];
                let remote_devs: Vec<usize> = consumers[node.0]
                    .iter()
                    .map(|(d, _)| *d)
                    .filter(|&d| d != dev)
                    .collect();
                for d in remote_devs {
                    let bytes = edge_bytes[node.0]
                        .iter()
                        .find(|(dd, _)| *dd == d)
                        .map(|(_, b)| *b)
                        .unwrap_or(0);
                    transfers.push(Transfer {
                        node,
                        src: dev,
                        dst: d,
                        bytes,
                        enqueued_at: t,
                        started_at: t,
                        started: false,
                        done: false,
                    });
                    let idx = transfers.len() - 1;
                    pend[dev].push(idx);
                    pend[d].push(idx);
                    if cluster.sequential_comm {
                        let path = topo.path(dev, d);
                        links.enqueue(path, idx);
                        // Drop-tail accounting: count live (un-started)
                        // waiters, this arrival included.
                        for &l in path {
                            let depth = links
                                .waiters_mut(l)
                                .iter()
                                .filter(|&&w| !transfers[w].started)
                                .count();
                            if depth > cfg.queue_limit {
                                result.contention.drop_warnings += 1;
                            }
                        }
                    }
                    if !dirty.contains(&d) {
                        dirty.push(d);
                    }
                    result.transfers += 1;
                    result.transfer_bytes += bytes;
                }
                // PyTorch: this backward op releases its forward's output.
                if cfg.framework == Framework::PyTorch && nd.is_backward {
                    if let Some(f) = nd.forward_of {
                        mem[dev_of(f)].release_tensor(f);
                    }
                }
                // Release this op's input tensors on this device.
                for &(p, _) in graph.predecessors(node) {
                    mem[dev].release_tensor(p);
                }
                advance!(t, dirty);
            }
            Event::TransferDone { idx } => {
                let tr = transfers[idx].clone();
                transfers[idx].done = true;
                // Timeline: in flight from link acquisition (or flow
                // admission) until delivery at the destination.
                result.schedule.transfers.push(TransferSpan {
                    node: tr.node,
                    src: tr.src,
                    dst: tr.dst,
                    bytes: tr.bytes,
                    links: topo.path(tr.src, tr.dst).to_vec(),
                    start: tr.started_at,
                    end: t,
                });
                if cluster.sequential_comm {
                    links.release(topo.path(tr.src, tr.dst));
                }
                if !cfg.overlap_comm {
                    // Compute engines unblock unless still running an op
                    // (they were idle when the transfer started).
                    compute_idle[tr.src] = compute_busy_until[tr.src] <= t;
                    compute_idle[tr.dst] = compute_busy_until[tr.dst] <= t;
                }
                // Source side: drop the outgoing-transfer reference.
                mem[tr.src].release_tensor(tr.node);
                // Destination: cache the tensor for its consumers.
                let dst_consumers = find(&consumers[tr.node.0], tr.dst)
                    .map(|k| consumers[tr.node.0][k].1.len())
                    .unwrap_or(0);
                if tr.bytes > 0 && dst_consumers > 0 {
                    if let Err(e) =
                        mem[tr.dst].alloc_tensor(tr.node, tr.bytes, dst_consumers, tr.dst, t)
                    {
                        return finish_with(result, &mem, Some(e));
                    }
                }
                if let Some(k) = find(&consumers[tr.node.0], tr.dst) {
                    for i in 0..consumers[tr.node.0][k].1.len() {
                        let c = consumers[tr.node.0][k].1[i];
                        missing[c.0] -= 1;
                        if missing[c.0] == 0 {
                            ready[tr.dst].push(std::cmp::Reverse((ranks[c.0], c)));
                        }
                    }
                }
                // Rescan the endpoints, plus one endpoint of any pending
                // transfer that waits on a link this one just released
                // but touches neither endpoint — a freed NIC trunk can
                // unblock pairs elsewhere in the cluster. A transfer is
                // rescanned when either of its endpoints is dirty, so on
                // uniform topologies (path = the two endpoint engines,
                // every waiter shares an endpoint) the dirty set stays
                // exactly [src, dst] and the legacy schedule is
                // reproduced bit-for-bit.
                let mut dirty: Vec<usize> = vec![tr.src, tr.dst];
                if cluster.sequential_comm {
                    for &l in topo.path(tr.src, tr.dst) {
                        let waiters = links.waiters_mut(l);
                        let mut k = 0;
                        while k < waiters.len() {
                            let w = waiters[k];
                            if transfers[w].started {
                                waiters.swap_remove(k); // lazy prune
                                continue;
                            }
                            if !dirty.contains(&transfers[w].src)
                                && !dirty.contains(&transfers[w].dst)
                            {
                                dirty.push(transfers[w].src);
                            }
                            k += 1;
                        }
                        // After pruning, every remaining entry is a
                        // still-queued transfer: the queue depth seen as
                        // this link frees.
                        result.contention.sample_depth(waiters.len());
                    }
                }
                advance!(t, dirty);
            }
            Event::FlowDrained { flow, gen: _ } => {
                // The flow's last byte left the source; survivors speed
                // up, and delivery completes after the path latency (a
                // latency holds no bandwidth, so it is not shared).
                flownet.integrate_to(t, &mut result.contention);
                let (idx, latency) = flownet.remove(flow);
                for (f, g, t_done) in flownet.reallocate(t) {
                    events.push(t_done, Event::FlowDrained { flow: f, gen: g });
                }
                events.push(t + latency, Event::TransferDone { idx });
            }
        }
    }

    debug_assert_eq!(done_ops, total_ops, "not all ops executed");
    finish_with(result, &mem, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{MemorySpec, OpKind};
    use crate::profile::CommModel;

    fn place_all(graph: &OpGraph, devs: &[usize]) -> BTreeMap<NodeId, DeviceId> {
        graph
            .node_ids()
            .zip(devs.iter())
            .map(|(id, &d)| (id, DeviceId(d)))
            .collect()
    }

    fn chain3() -> OpGraph {
        let mut g = OpGraph::new("c");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        for (id, t) in [(a, 1.0), (b, 2.0), (c, 3.0)] {
            g.node_mut(id).compute = t;
            g.node_mut(id).mem = MemorySpec {
                output: 10,
                ..Default::default()
            };
            g.node_mut(id).output_bytes = 10;
        }
        g.add_edge(a, b, 10);
        g.add_edge(b, c, 10);
        g
    }

    #[test]
    fn single_device_serializes() {
        let g = chain3();
        let cluster = Cluster::homogeneous(1, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 0, 0]), SimConfig::default());
        assert!(r.ok());
        assert!((r.makespan - 6.0).abs() < 1e-9);
        assert_eq!(r.transfers, 0);
        assert!((r.busy[0] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn cross_device_pays_comm() {
        let g = chain3();
        // bandwidth 1 byte/s → 10 s per hop
        let cluster = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(r.ok());
        // 1 + 10 + 2 + 10 + 3 = 26
        assert!((r.makespan - 26.0).abs() < 1e-9, "{}", r.makespan);
        assert_eq!(r.transfers, 2);
        assert_eq!(r.transfer_bytes, 20);
    }

    #[test]
    fn parallel_branches_overlap() {
        // a → b, a → c with b,c on different devices.
        let mut g = OpGraph::new("d");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        for (id, t) in [(a, 1.0), (b, 5.0), (c, 5.0)] {
            g.node_mut(id).compute = t;
        }
        g.add_edge(a, b, 0);
        g.add_edge(a, c, 0);
        let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1e9).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 0, 1]), SimConfig::default());
        assert!(r.ok());
        assert!((r.makespan - 6.0).abs() < 1e-6, "{}", r.makespan);
    }

    #[test]
    fn oom_on_too_small_device() {
        let mut g = chain3();
        let first = g.node_ids().next().unwrap();
        g.node_mut(first).mem.params = 5000;
        let cluster = Cluster::homogeneous(1, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 0, 0]), SimConfig::default());
        assert!(!r.ok());
        assert_eq!(r.oom.unwrap().device, 0);
    }

    #[test]
    fn blocking_transfers_slower_than_overlapped() {
        // Two independent chains on two devices plus a cross transfer:
        // with blocking comm the unrelated device stalls too.
        let mut g = OpGraph::new("t7");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul); // consumer of a, other dev
        let x = g.add_node("x", OpKind::MatMul); // independent work on dev1
        for (id, t) in [(a, 1.0), (b, 1.0), (x, 8.0)] {
            g.node_mut(id).compute = t;
        }
        g.add_edge(a, b, 10); // 10 s transfer
        let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap());
        let placement = place_all(&g, &[0, 1, 1]);
        let overlapped = simulate(&g, &cluster, &placement, SimConfig::default());
        let blocking = simulate(
            &g,
            &cluster,
            &placement,
            SimConfig {
                overlap_comm: false,
                ..Default::default()
            },
        );
        assert!(overlapped.ok() && blocking.ok());
        assert!(
            blocking.makespan > overlapped.makespan,
            "blocking {} vs overlapped {}",
            blocking.makespan,
            overlapped.makespan
        );
    }

    #[test]
    fn pytorch_holds_forward_outputs() {
        // fwd(out 100) → bwd; PyTorch holds fwd output until bwd done →
        // peak must include it; TF frees it after its consumer (bwd) runs
        // — in this tiny graph both end up equal at peak, so instead we
        // check the tensor is held during an intermediate op.
        let mut g = OpGraph::new("pt");
        let f = g.add_node("f", OpKind::MatMul);
        let m = g.add_node("m", OpKind::MatMul); // consumes f
        let b = g.add_node("b", OpKind::MatMul); // backward of f, after m
        g.node_mut(f).compute = 1.0;
        g.node_mut(f).mem.output = 100;
        g.node_mut(m).compute = 1.0;
        g.node_mut(m).mem.output = 10;
        g.node_mut(b).compute = 1.0;
        g.node_mut(b).is_backward = true;
        g.node_mut(b).forward_of = Some(f);
        g.add_edge(f, m, 100);
        g.add_edge(m, b, 10);
        let cluster = Cluster::homogeneous(1, 1000, CommModel::new(0.0, 1e9).unwrap());
        let placement = place_all(&g, &[0, 0, 0]);
        let tf = simulate(&g, &cluster, &placement, SimConfig::default());
        let pt = simulate(
            &g,
            &cluster,
            &placement,
            SimConfig {
                framework: Framework::PyTorch,
                ..Default::default()
            },
        );
        assert!(tf.ok() && pt.ok());
        // TF: f's output freed after m; peak = 100 + 10 = 110.
        // PyTorch: f's output lives until b; peak = 100 + 10 = same here,
        // but b sees f still alive: pt peak ≥ tf peak.
        assert!(pt.peak_memory[0] >= tf.peak_memory[0]);
    }

    #[test]
    fn tensor_cached_per_destination() {
        // a feeds two consumers on the same remote device → one transfer.
        let mut g = OpGraph::new("cache");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        g.node_mut(a).compute = 1.0;
        g.node_mut(a).mem.output = 10;
        g.node_mut(b).compute = 1.0;
        g.node_mut(c).compute = 1.0;
        g.add_edge(a, b, 10);
        g.add_edge(a, c, 10);
        let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 1]), SimConfig::default());
        assert!(r.ok());
        assert_eq!(r.transfers, 1, "cached second consumer");
    }

    #[test]
    fn islands_cross_transfer_pays_inter_cost() {
        use crate::topology::Topology;
        let g = chain3(); // a(1s) → b(2s) → c(3s), 10-byte edges
        let intra = CommModel::new(0.0, 10.0).unwrap(); // 1 s per edge
        let inter = CommModel::new(0.0, 1.0).unwrap(); // 10 s per edge
        let cluster = Cluster::homogeneous(4, 1000, inter)
            .with_topology(Topology::nvlink_islands(4, 2, intra, inter).unwrap())
            .unwrap();
        // a,b share island 0; c sits across the PCIe boundary.
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(r.ok());
        // 1 + 1 (intra) + 2 + 10 (inter) + 3 = 17
        assert!((r.makespan - 17.0).abs() < 1e-9, "{}", r.makespan);
        assert_eq!(r.transfers, 2);
    }

    #[test]
    fn two_tier_trunk_serializes_but_islands_overlap() {
        use crate::topology::Topology;
        // Two cross-boundary transfers from distinct devices: a(0)→c(2)
        // and b(1)→d(3), 10 s each at the inter rate.
        let mut g = OpGraph::new("trunk");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 10);
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let placement = place_all(&g, &[0, 1, 2, 3]);

        // Two-tier: both transfers queue on the shared NIC trunks.
        let two_tier = Cluster::homogeneous(4, 1000, inter)
            .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
            .unwrap();
        let rt = simulate(&g, &two_tier, &placement, SimConfig::default());
        assert!(rt.ok());
        // first transfer [1, 11], second queued [11, 21], then 1 s compute
        assert!((rt.makespan - 22.0).abs() < 1e-9, "{}", rt.makespan);

        // NVLink islands: disjoint host-links, transfers overlap.
        let islands = Cluster::homogeneous(4, 1000, inter)
            .with_topology(Topology::nvlink_islands(4, 2, intra, inter).unwrap())
            .unwrap();
        let ri = simulate(&g, &islands, &placement, SimConfig::default());
        assert!(ri.ok());
        assert!((ri.makespan - 12.0).abs() < 1e-9, "{}", ri.makespan);
    }

    #[test]
    fn explicit_uniform_topology_is_bit_identical() {
        use crate::topology::Topology;
        let g = crate::models::mlp::mlp(&crate::models::mlp::MlpConfig::default());
        let comm = CommModel::pcie_via_host();
        let base = Cluster::homogeneous(2, 64 << 30, comm);
        let explicit = Cluster::homogeneous(2, 64 << 30, comm)
            .with_topology(Topology::uniform(2, comm))
            .unwrap();
        let placement: BTreeMap<NodeId, DeviceId> = g
            .node_ids()
            .enumerate()
            .map(|(i, id)| (id, DeviceId(i % 2)))
            .collect();
        let ra = simulate(&g, &base, &placement, SimConfig::default());
        let rb = simulate(&g, &explicit, &placement, SimConfig::default());
        assert!(ra.ok() && rb.ok());
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(ra.transfers, rb.transfers);
        assert_eq!(ra.peak_memory, rb.peak_memory);
        assert_eq!(ra.events, rb.events);
    }

    #[test]
    fn contention_busy_time_matches_reserved_intervals() {
        // chain3 across 3 uniform devices: two 10 s transfers, each
        // occupying its 2 endpoint host-links for the full duration.
        let g = chain3();
        let cluster = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(r.ok());
        let c = &r.contention;
        assert_eq!(c.makespan.to_bits(), r.makespan.to_bits());
        // Busy sums match the reserved intervals: 2 transfers × 10 s ×
        // 2 links each.
        let link_sum: f64 = c.links.iter().map(|u| u.busy).sum();
        assert!((link_sum - 40.0).abs() < 1e-9, "{link_sum}");
        assert!((c.busy_seconds - link_sum).abs() < 1e-9);
        // Device 1's host-link carries both transfers (in and out).
        assert!((c.links[1].busy - 20.0).abs() < 1e-9);
        assert_eq!(c.links[1].transfers, 2);
        assert_eq!(c.links[1].bytes, 20);
        // The chain serializes through compute, so nothing ever queues.
        assert_eq!(c.blocked_seconds, 0.0);
        assert_eq!(c.saturated_links(0.9), Vec::<usize>::new());
        assert_eq!(c.top_saturated(1)[0].link, 1);
    }

    #[test]
    fn contention_report_sees_trunk_queueing() {
        use crate::topology::Topology;
        // Same scenario as two_tier_trunk_serializes_but_islands_overlap:
        // transfers 0→2 and 1→3 queue on the shared NIC trunks.
        let mut g = OpGraph::new("trunk");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 10);
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let topo = Topology::two_tier(2, 2, intra, inter).unwrap();
        let trunk: Vec<usize> = topo
            .path(0, 2)
            .iter()
            .filter(|l| topo.path(1, 3).contains(l))
            .copied()
            .collect();
        assert!(!trunk.is_empty(), "cross-machine paths must share trunks");
        let cluster = Cluster::homogeneous(4, 1000, inter)
            .with_topology(topo)
            .unwrap();
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2, 3]), SimConfig::default());
        assert!(r.ok());
        let rep = &r.contention;
        // Second transfer finished compute at t=1, started at t=11.
        assert!((rep.blocked_seconds - 10.0).abs() < 1e-9, "{}", rep.blocked_seconds);
        assert!(rep.blocked_fraction() > 0.4);
        // Every shared trunk link carried both 10 s transfers; the
        // waiter's 10 s are split across its 4-link path.
        for &l in &trunk {
            assert!((rep.links[l].busy - 20.0).abs() < 1e-9);
            assert_eq!(rep.links[l].transfers, 2);
            assert!((rep.links[l].blocked - 2.5).abs() < 1e-9);
        }
        // makespan 22 → trunk utilization ≈ 0.91, and only trunk links
        // pass a 0.5 saturation threshold.
        assert!(rep.max_utilization() > 0.9);
        assert_eq!(rep.saturated_links(0.5), trunk);
        // The queue was observed non-empty while the first transfer held
        // the trunk.
        assert!(rep.queue_depth_hist[1] > 0, "{:?}", rep.queue_depth_hist);
        // Top-op attribution names the producers.
        assert!(rep.links[trunk[0]]
            .top_ops
            .iter()
            .any(|&(bytes, node)| bytes == 10 && (node == a || node == b)));
    }

    #[test]
    fn contended_links_never_overcommit() {
        use crate::topology::Topology;
        // Regression for LinkQueues acquire/release symmetry: a wide
        // fan-out pushes many overlapping transfers over the shared
        // trunks; debug assertions in LinkQueues fire if a path is ever
        // released while not held, and no link may be busy for longer
        // than the whole step.
        let mut g = OpGraph::new("wide");
        let src = g.add_node("src", OpKind::MatMul);
        g.node_mut(src).compute = 1.0;
        g.node_mut(src).mem.output = 8;
        g.node_mut(src).output_bytes = 8;
        for i in 0..12 {
            let id = g.add_node(&format!("w{i}"), OpKind::MatMul);
            g.node_mut(id).compute = 0.5;
            g.add_edge(src, id, 8);
        }
        let intra = CommModel::new(0.0, 100.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let cluster = Cluster::homogeneous(4, 10_000, inter)
            .with_topology(Topology::two_tier(2, 2, intra, inter).unwrap())
            .unwrap();
        let placement: BTreeMap<NodeId, DeviceId> = g
            .node_ids()
            .enumerate()
            .map(|(i, id)| (id, DeviceId(i % 4)))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        assert!(r.contention.blocked_seconds > 0.0, "trunk must queue");
        for u in &r.contention.links {
            assert!(
                u.busy <= r.makespan + 1e-9,
                "link {} busy {} exceeds makespan {}",
                u.link,
                u.busy,
                r.makespan
            );
            assert!(u.top_ops.len() <= 8);
        }
        let hist_samples: u64 = r.contention.queue_depth_hist.iter().sum();
        assert!(hist_samples > 0);
    }

    #[test]
    fn flow_report_populated_under_parallel_comm() {
        // Regression for the parallel-comm blind spot: the report used
        // to stay empty, silently disabling re-placement. Flows must
        // book busy time, and an uncontended chain must still match the
        // sequential makespan (no competing flows ⇒ same schedule).
        let g = chain3();
        let seq = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap());
        let par = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap())
            .with_sequential_comm(false);
        let rs = simulate(&g, &seq, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        let rp = simulate(&g, &par, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(rs.ok() && rp.ok());
        assert_eq!(rp.transfers, 2, "transfers still happen");
        assert!(
            (rp.makespan - rs.makespan).abs() < 1e-9,
            "uncontended parallel {} vs sequential {}",
            rp.makespan,
            rs.makespan
        );
        let c = &rp.contention;
        // Two 10 s flows, each holding its 2 endpoint host-links.
        assert!((c.busy_seconds - 40.0).abs() < 1e-9, "{}", c.busy_seconds);
        assert!((c.links[1].busy - 20.0).abs() < 1e-9);
        assert_eq!(c.links[1].transfers, 2);
        assert_eq!(c.links[1].bytes, 20);
        // Nothing competed: no slowdown, no drops.
        assert_eq!(c.blocked_seconds, 0.0);
        assert_eq!(c.drop_warnings, 0);
        assert!(c.max_utilization() > 0.0);
    }

    /// 4 devices + 2 switches joined by one capacity-1 trunk; every
    /// spoke is infinitely fast so the trunk is the only constraint.
    fn trunk_topology() -> crate::topology::Topology {
        use crate::topology::{Link, LinkKind, Topology};
        let fast = CommModel {
            latency: 0.0,
            bandwidth: f64::INFINITY,
        };
        let trunk = CommModel {
            latency: 0.0,
            bandwidth: 1.0,
        };
        let mk = |a: usize, b: usize, comm: CommModel| Link {
            a,
            b,
            kind: LinkKind::Nic,
            comm,
        };
        Topology::from_links(
            4,
            2,
            vec![
                mk(0, 4, fast),
                mk(1, 4, fast),
                mk(4, 5, trunk),
                mk(5, 2, fast),
                mk(5, 3, fast),
            ],
            None,
            None,
        )
        .unwrap()
    }

    #[test]
    fn flow_two_concurrent_flows_on_shared_trunk_halve_bandwidth() {
        // a(0)→c(2) and b(1)→d(3), 10 bytes each, both crossing the
        // capacity-1 trunk at the same time: each runs at rate 0.5 and
        // takes 20 s instead of 10.
        let mut g = OpGraph::new("share");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 10);
        let cluster = Cluster::homogeneous(4, 1000, CommModel::new(0.0, 1.0).unwrap())
            .with_topology(trunk_topology())
            .unwrap()
            .with_sequential_comm(false);
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2, 3]), SimConfig::default());
        assert!(r.ok());
        // compute [0,1], both flows drain [1,21] at half rate, compute
        // [21,22].
        assert!((r.makespan - 22.0).abs() < 1e-9, "{}", r.makespan);
        let rep = &r.contention;
        // The trunk carried both flows for 20 s; each accrued
        // 20 s × (1 − 0.5/1.0) = 10 s of slowdown there.
        assert!((rep.links[2].busy - 20.0).abs() < 1e-9);
        assert!((rep.links[2].blocked - 20.0).abs() < 1e-9);
        assert!((rep.blocked_seconds - 20.0).abs() < 1e-9);
        assert_eq!(rep.links[2].transfers, 2);
        // The uncontended spokes saw traffic but no slowdown.
        assert_eq!(rep.links[0].blocked, 0.0);
        assert_eq!(rep.links[3].blocked, 0.0);
        assert!(rep.max_utilization() > 0.9);
    }

    #[test]
    fn flow_star_overlapped_cross_island_transfers_finish_later() {
        use crate::topology::{Link, LinkKind, Topology};
        // Three-island star: devices 0 and 1 reach device 2 through its
        // slow spoke. Overlapping both transfers must finish later than
        // running one alone.
        let fat = CommModel {
            latency: 0.0,
            bandwidth: 10.0,
        };
        let thin = CommModel {
            latency: 0.0,
            bandwidth: 1.0,
        };
        let hub = 3;
        let topo = Topology::from_links(
            3,
            1,
            vec![
                Link {
                    a: 0,
                    b: hub,
                    kind: LinkKind::Nic,
                    comm: fat,
                },
                Link {
                    a: 1,
                    b: hub,
                    kind: LinkKind::Nic,
                    comm: fat,
                },
                Link {
                    a: 2,
                    b: hub,
                    kind: LinkKind::Nic,
                    comm: thin,
                },
            ],
            None,
            None,
        )
        .unwrap();
        let mk_cluster = || {
            Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap())
                .with_topology(topo.clone())
                .unwrap()
                .with_sequential_comm(false)
        };
        let mut both = OpGraph::new("both");
        let a = both.add_node("a", OpKind::MatMul);
        let b = both.add_node("b", OpKind::MatMul);
        let c = both.add_node("c", OpKind::MatMul);
        let d = both.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            both.node_mut(id).compute = 1.0;
        }
        both.add_edge(a, c, 10);
        both.add_edge(b, d, 10);
        let r_both = simulate(
            &both,
            &mk_cluster(),
            &place_all(&both, &[0, 1, 2, 2]),
            SimConfig::default(),
        );
        let mut alone = OpGraph::new("alone");
        let a1 = alone.add_node("a", OpKind::MatMul);
        let c1 = alone.add_node("c", OpKind::MatMul);
        alone.node_mut(a1).compute = 1.0;
        alone.node_mut(c1).compute = 1.0;
        alone.add_edge(a1, c1, 10);
        let r_alone = simulate(
            &alone,
            &mk_cluster(),
            &place_all(&alone, &[0, 2]),
            SimConfig::default(),
        );
        assert!(r_both.ok() && r_alone.ok());
        // Alone: 1 + 10·(1/10 + 1/1) + 1 = 13. Overlapped: dev 2's
        // spoke splits 0.5/0.5, both flows drain in 20 s, then the two
        // consumers serialize on device 2: 1 + 20 + 2 = 23.
        assert!((r_alone.makespan - 13.0).abs() < 1e-9, "{}", r_alone.makespan);
        assert!((r_both.makespan - 23.0).abs() < 1e-9, "{}", r_both.makespan);
        assert!(r_both.makespan > r_alone.makespan + 5.0);
        // Slowdown lands on the thin spoke: each flow ran at 0.5 of its
        // 10/11 cap for 20 s → 2 × 20 × (1 − 0.55) = 18 s.
        let rep = &r_both.contention;
        assert!((rep.blocked_seconds - 18.0).abs() < 1e-9, "{}", rep.blocked_seconds);
        assert!((rep.links[2].blocked - rep.blocked_seconds).abs() < 1e-9);
    }

    #[test]
    fn flow_two_tier_trunk_contends_with_concurrent_cross_island_transfers() {
        use crate::topology::Topology;
        // Acceptance scenario: 5 concurrent cross-machine flows on a
        // two-tier topology. The trunk carries 4× the per-pair
        // bandwidth, so 5 flows share it at rate 0.8 each.
        let mut g = OpGraph::new("xisland");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let w34 = g.add_node("w34", OpKind::MatMul);
        let w4 = g.add_node("w4", OpKind::MatMul);
        let w5 = g.add_node("w5", OpKind::MatMul);
        for id in [a, b, c, w34, w4, w5] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, w34, 100);
        g.add_edge(a, w4, 100);
        g.add_edge(b, w34, 100);
        g.add_edge(b, w5, 100);
        g.add_edge(c, w4, 100);
        let intra = CommModel::new(0.0, 1000.0).unwrap();
        let inter = CommModel::new(0.0, 1.0).unwrap();
        let placement = place_all(&g, &[0, 1, 2, 3, 4, 5]);
        let topo = Topology::two_tier(2, 3, intra, inter).unwrap();
        let par = Cluster::homogeneous(6, 10_000, inter)
            .with_topology(topo.clone())
            .unwrap()
            .with_sequential_comm(false);
        let seq = Cluster::homogeneous(6, 10_000, inter)
            .with_topology(topo)
            .unwrap();
        let rp = simulate(&g, &par, &placement, SimConfig::default());
        let rs = simulate(&g, &seq, &placement, SimConfig::default());
        assert!(rp.ok() && rs.ok());
        // 5 flows × 100 bytes at rate 0.8 from t=1: drains at t=126,
        // consumers finish at 127.
        assert!((rp.makespan - 127.0).abs() < 1e-9, "{}", rp.makespan);
        assert!(
            rp.makespan > 102.0 + 1.0,
            "must be slower than 5 independent transfers"
        );
        assert!(
            rp.makespan < rs.makespan,
            "sharing beats serializing: {} vs {}",
            rp.makespan,
            rs.makespan
        );
        // The report is non-empty: every flow lost 125 × 0.2 = 25 s to
        // the shared trunk.
        let rep = &rp.contention;
        assert!((rep.blocked_seconds - 125.0).abs() < 1e-9, "{}", rep.blocked_seconds);
        assert!(rep.busy_seconds > 0.0);
        assert!(rep.blocked_fraction() > 0.5);
        let trunk_blocked: f64 = rep
            .links
            .iter()
            .filter(|u| u.blocked > 0.0)
            .map(|u| u.blocked)
            .sum();
        assert!((trunk_blocked - 125.0).abs() < 1e-9);
        assert!(rep.queue_depth_hist.iter().skip(2).any(|&h| h > 0));
    }

    #[test]
    fn flow_report_zero_makespan_returns_zero_fractions() {
        // Regression: utilization/blocked_fraction must not divide by a
        // zero makespan on an empty graph.
        let g = OpGraph::new("empty");
        for seq in [true, false] {
            let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap())
                .with_sequential_comm(seq);
            let r = simulate(&g, &cluster, &BTreeMap::new(), SimConfig::default());
            assert!(r.ok());
            assert_eq!(r.makespan, 0.0);
            assert_eq!(r.contention.blocked_fraction(), 0.0);
            assert_eq!(r.contention.max_utilization(), 0.0);
            for l in 0..r.contention.links.len() {
                assert_eq!(r.contention.utilization(l), 0.0);
            }
        }
    }

    #[test]
    fn flow_drop_tail_counter_flags_deep_queues() {
        // Same shared-trunk scenario as the halved-bandwidth test: with
        // the default queue limit nothing drops; with a limit of 1 the
        // second flow's arrival at the trunk is flagged.
        let mut g = OpGraph::new("drop");
        let a = g.add_node("a", OpKind::MatMul);
        let b = g.add_node("b", OpKind::MatMul);
        let c = g.add_node("c", OpKind::MatMul);
        let d = g.add_node("d", OpKind::MatMul);
        for id in [a, b, c, d] {
            g.node_mut(id).compute = 1.0;
        }
        g.add_edge(a, c, 10);
        g.add_edge(b, d, 10);
        let cluster = Cluster::homogeneous(4, 1000, CommModel::new(0.0, 1.0).unwrap())
            .with_topology(trunk_topology())
            .unwrap()
            .with_sequential_comm(false);
        let placement = place_all(&g, &[0, 1, 2, 3]);
        let relaxed = simulate(&g, &cluster, &placement, SimConfig::default());
        let strict = simulate(
            &g,
            &cluster,
            &placement,
            SimConfig {
                queue_limit: 1,
                ..Default::default()
            },
        );
        assert!(relaxed.ok() && strict.ok());
        assert_eq!(relaxed.contention.drop_warnings, 0);
        assert!(strict.contention.drop_warnings > 0);
        // Accounting never alters the schedule.
        assert_eq!(relaxed.makespan.to_bits(), strict.makespan.to_bits());
    }

    #[test]
    fn schedule_records_ops_and_transfers_and_reconstructs_makespan() {
        let g = chain3();
        let cluster = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap());
        let r = simulate(&g, &cluster, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(r.ok());
        let sched = &r.schedule;
        assert_eq!(sched.ops.len(), 3, "one span per executed op");
        assert_eq!(sched.transfers.len(), r.transfers);
        // a on dev 0 over [0, 1]; the a→b transfer holds its 2-link
        // path over [1, 11]; b on dev 1 over [11, 13]; etc.
        let a = &sched.ops[0];
        assert_eq!(a.device, 0);
        assert!((a.start - 0.0).abs() < 1e-12 && (a.end - 1.0).abs() < 1e-12);
        let t0 = &sched.transfers[0];
        assert_eq!((t0.src, t0.dst, t0.bytes), (0, 1, 10));
        assert_eq!(t0.links.len(), 2);
        assert!((t0.start - 1.0).abs() < 1e-12 && (t0.end - 11.0).abs() < 1e-12);
        // The timeline reconstructs the makespan exactly.
        assert_eq!(sched.max_end().to_bits(), r.makespan.to_bits());
        for s in &sched.ops {
            assert!(s.start >= 0.0 && s.end >= s.start && s.end <= r.makespan);
        }
        for s in &sched.transfers {
            assert!(s.start >= 0.0 && s.end >= s.start && s.end <= r.makespan);
        }
    }

    #[test]
    fn schedule_parallel_comm_matches_makespan_too() {
        let g = chain3();
        let par = Cluster::homogeneous(3, 1000, CommModel::new(0.0, 1.0).unwrap())
            .with_sequential_comm(false);
        let r = simulate(&g, &par, &place_all(&g, &[0, 1, 2]), SimConfig::default());
        assert!(r.ok());
        assert_eq!(r.schedule.ops.len(), 3);
        assert_eq!(r.schedule.transfers.len(), 2);
        assert_eq!(r.schedule.max_end().to_bits(), r.makespan.to_bits());
    }

    #[test]
    fn makespan_at_least_critical_path_and_work_bound() {
        let g = crate::models::mlp::mlp(&crate::models::mlp::MlpConfig::default());
        let cluster = Cluster::homogeneous(2, 64 << 30, CommModel::pcie_via_host());
        let placement: BTreeMap<NodeId, DeviceId> = g
            .node_ids()
            .enumerate()
            .map(|(i, id)| (id, DeviceId(i % 2)))
            .collect();
        let r = simulate(&g, &cluster, &placement, SimConfig::default());
        assert!(r.ok());
        let cp = g.critical_path(|_| 0.0).unwrap();
        let work_bound = g.total_compute() / 2.0;
        assert!(r.makespan >= cp - 1e-9);
        assert!(r.makespan >= work_bound - 1e-9);
    }
}
