//! Time-ordered event core of the execution simulator.
//!
//! The simulator is a discrete-event loop over one global heap:
//! [`EventQueue`] orders [`Timed`] events earliest-first, breaking time
//! ties by insertion order so replays are deterministic. Flow events
//! carry a generation counter ([`Event::FlowDrained`]): when a flow's
//! rate changes, the flow network bumps the generation and schedules a
//! fresh drain event, and any older event for that flow is recognized as
//! stale at pop time and skipped — lazy invalidation, so the heap never
//! needs random-access deletion.
//!
//! Ordering is NaN-safe: a NaN timestamp (a corrupted cost model, a
//! 0/0 somewhere upstream) sorts deterministically *last* instead of
//! panicking inside `BinaryHeap`, mirroring the hardened
//! `placer::QueueEntry` ordering.

use crate::graph::NodeId;
use std::collections::BinaryHeap;

/// What can happen next in the simulated step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A device finished computing an op.
    ComputeDone { dev: usize, node: NodeId },
    /// A transfer delivered its tensor at the destination.
    TransferDone { idx: usize },
    /// A bandwidth-shared flow drained its payload (parallel-comm mode).
    /// `gen` must match the flow's current generation; rate changes bump
    /// the generation, turning previously scheduled drains stale.
    FlowDrained { flow: usize, gen: u64 },
}

/// An event stamped with its simulated time and insertion sequence.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timed {
    pub t: f64,
    pub seq: u64,
    pub ev: Event,
}

impl Eq for Timed {}

impl Ord for Timed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        // NaN timestamps order below every finite time (popped last),
        // deterministically — same total order as placer::QueueEntry.
        let t_ord = match other.t.partial_cmp(&self.t) {
            Some(o) => o,
            None => match (self.t.is_nan(), other.t.is_nan()) {
                (true, false) => std::cmp::Ordering::Less,
                (false, true) => std::cmp::Ordering::Greater,
                _ => std::cmp::Ordering::Equal,
            },
        };
        t_ord.then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The global event heap: earliest time first, FIFO within a time.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Timed>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    pub fn push(&mut self, t: f64, ev: Event) {
        self.seq += 1;
        self.heap.push(Timed {
            t,
            seq: self.seq,
            ev,
        });
    }

    pub fn pop(&mut self) -> Option<Timed> {
        self.heap.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn marker(i: usize) -> Event {
        Event::TransferDone { idx: i }
    }

    fn drain(q: &mut EventQueue) -> Vec<usize> {
        let mut order = Vec::new();
        while let Some(e) = q.pop() {
            match e.ev {
                Event::TransferDone { idx } => order.push(idx),
                _ => unreachable!(),
            }
        }
        order
    }

    #[test]
    fn flow_events_pop_earliest_first_fifo_within_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, marker(0));
        q.push(1.0, marker(1));
        q.push(1.0, marker(2));
        q.push(3.0, marker(3));
        // t=1 events in insertion order, then t=2, then t=3.
        assert_eq!(drain(&mut q), vec![1, 2, 0, 3]);
    }

    #[test]
    fn flow_event_nan_timestamps_sort_last_without_panicking() {
        // Regression: the old `Timed` ordering unwrapped `partial_cmp`
        // and panicked on the first NaN timestamp. NaN must instead be
        // popped after every finite event, in insertion order.
        let mut q = EventQueue::new();
        q.push(f64::NAN, marker(10));
        q.push(1.0, marker(0));
        q.push(f64::NAN, marker(11));
        q.push(0.5, marker(1));
        assert_eq!(drain(&mut q), vec![1, 0, 10, 11]);
    }

    #[test]
    fn flow_event_ordering_is_a_total_order_under_nan() {
        // Antisymmetry/consistency spot checks the heap relies on.
        let ev = marker(0);
        let a = Timed {
            t: f64::NAN,
            seq: 1,
            ev,
        };
        let b = Timed { t: 1.0, seq: 2, ev };
        let c = Timed {
            t: f64::NAN,
            seq: 3,
            ev,
        };
        assert_eq!(a.cmp(&b), std::cmp::Ordering::Less);
        assert_eq!(b.cmp(&a), std::cmp::Ordering::Greater);
        assert_eq!(a.cmp(&c), std::cmp::Ordering::Greater, "lower seq pops first");
        assert_eq!(a.cmp(&a), std::cmp::Ordering::Equal);
    }
}
