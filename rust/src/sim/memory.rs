//! Dynamic per-device memory tracker for the execution simulator
//! (paper §4.2 "Dynamic Memory Allocation").
//!
//! Models the frameworks' allocators: permanent blocks (parameters and
//! their gradients) live for the whole step; temporary blocks live for an
//! op's execution window; output tensors are reference-counted — held
//! until every consumer (local ops, outgoing transfers, and in PyTorch
//! mode the matching backward op) releases them.

use crate::graph::NodeId;

/// Allocation failure → simulated OOM.
#[derive(Debug, Clone, PartialEq)]
pub struct OomError {
    pub device: usize,
    pub needed: u64,
    pub capacity: u64,
    pub in_use: u64,
    pub at_time: f64,
    pub what: String,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM on gpu{} at t={:.4}s allocating {} for {} (in use {Used} of {Cap})",
            self.device,
            self.at_time,
            crate::util::table::fmt_bytes(self.needed),
            self.what,
            Used = crate::util::table::fmt_bytes(self.in_use),
            Cap = crate::util::table::fmt_bytes(self.capacity),
        )
    }
}

/// Memory state of one simulated device.
#[derive(Debug, Clone)]
pub struct DeviceMem {
    pub capacity: u64,
    permanent: u64,
    temp: u64,
    /// Dense per-node-slot tensor table: (bytes, refs). refs == 0 means
    /// absent (§Perf iteration 5 — replaced a BTreeMap on the per-event
    /// path; grows on demand).
    tensors: Vec<(u64, u32)>,
    tensor_bytes: u64,
    pub peak: u64,
}

impl DeviceMem {
    pub fn new(capacity: u64) -> DeviceMem {
        DeviceMem {
            capacity,
            permanent: 0,
            temp: 0,
            tensors: Vec::new(),
            tensor_bytes: 0,
            peak: 0,
        }
    }

    #[inline]
    fn slot(&mut self, node: NodeId) -> &mut (u64, u32) {
        if node.0 >= self.tensors.len() {
            self.tensors.resize(node.0 + 1, (0, 0));
        }
        &mut self.tensors[node.0]
    }

    pub fn in_use(&self) -> u64 {
        self.permanent + self.temp + self.tensor_bytes
    }

    fn check(&mut self, bytes: u64, dev: usize, t: f64, what: &str) -> Result<(), OomError> {
        if self.in_use() + bytes > self.capacity {
            return Err(OomError {
                device: dev,
                needed: bytes,
                capacity: self.capacity,
                in_use: self.in_use(),
                at_time: t,
                what: what.to_string(),
            });
        }
        Ok(())
    }

    fn bump(&mut self) {
        self.peak = self.peak.max(self.in_use());
    }

    /// Permanent allocation (params + grads); never freed.
    pub fn alloc_permanent(
        &mut self,
        bytes: u64,
        dev: usize,
        t: f64,
        what: &str,
    ) -> Result<(), OomError> {
        self.check(bytes, dev, t, what)?;
        self.permanent += bytes;
        self.bump();
        Ok(())
    }

    /// Temporary allocation for an op's execution window.
    pub fn alloc_temp(&mut self, bytes: u64, dev: usize, t: f64, what: &str) -> Result<(), OomError> {
        self.check(bytes, dev, t, what)?;
        self.temp += bytes;
        self.bump();
        Ok(())
    }

    pub fn free_temp(&mut self, bytes: u64) {
        debug_assert!(self.temp >= bytes);
        self.temp -= bytes;
    }

    /// Reference-counted tensor (an op output or a received copy).
    pub fn alloc_tensor(
        &mut self,
        node: NodeId,
        bytes: u64,
        refs: usize,
        dev: usize,
        t: f64,
    ) -> Result<(), OomError> {
        if refs == 0 || bytes == 0 {
            return Ok(());
        }
        debug_assert!(self.slot(node).1 == 0, "tensor {node} exists");
        self.check(bytes, dev, t, &format!("output of {node}"))?;
        *self.slot(node) = (bytes, refs as u32);
        self.tensor_bytes += bytes;
        self.bump();
        Ok(())
    }

    /// Add references to an existing tensor (e.g. PyTorch backward hold).
    pub fn retain_tensor(&mut self, node: NodeId, extra: usize) {
        let s = self.slot(node);
        if s.1 > 0 {
            s.1 += extra as u32;
        }
    }

    /// Drop one reference; frees at zero.
    pub fn release_tensor(&mut self, node: NodeId) {
        let s = self.slot(node);
        if s.1 > 0 {
            s.1 -= 1;
            if s.1 == 0 {
                let bytes = s.0;
                s.0 = 0;
                self.tensor_bytes -= bytes;
            }
        }
    }

    pub fn has_tensor(&mut self, node: NodeId) -> bool {
        self.slot(node).1 > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut m = DeviceMem::new(1000);
        m.alloc_permanent(300, 0, 0.0, "params").unwrap();
        m.alloc_temp(200, 0, 0.0, "scratch").unwrap();
        m.alloc_tensor(NodeId(1), 400, 2, 0, 0.0).unwrap();
        assert_eq!(m.in_use(), 900);
        assert_eq!(m.peak, 900);
        m.free_temp(200);
        m.release_tensor(NodeId(1));
        assert_eq!(m.in_use(), 700, "one ref left");
        m.release_tensor(NodeId(1));
        assert_eq!(m.in_use(), 300);
        assert_eq!(m.peak, 900, "peak sticks");
    }

    #[test]
    fn oom_detected() {
        let mut m = DeviceMem::new(1000);
        m.alloc_permanent(900, 0, 0.0, "params").unwrap();
        let err = m.alloc_tensor(NodeId(0), 200, 1, 0, 1.5).unwrap_err();
        assert_eq!(err.device, 0);
        assert_eq!(err.needed, 200);
        assert_eq!(err.in_use, 900);
        assert!((err.at_time - 1.5).abs() < 1e-12);
        assert!(err.to_string().contains("OOM"));
    }

    #[test]
    fn zero_ref_tensor_is_noop() {
        let mut m = DeviceMem::new(100);
        m.alloc_tensor(NodeId(0), 1000, 0, 0, 0.0).unwrap();
        assert_eq!(m.in_use(), 0);
    }

    #[test]
    fn retain_extends_lifetime() {
        let mut m = DeviceMem::new(1000);
        m.alloc_tensor(NodeId(0), 100, 1, 0, 0.0).unwrap();
        m.retain_tensor(NodeId(0), 1);
        m.release_tensor(NodeId(0));
        assert!(m.has_tensor(NodeId(0)));
        m.release_tensor(NodeId(0));
        assert!(!m.has_tensor(NodeId(0)));
    }
}
