//! Baseline placers (paper §5): single-device, the per-model expert
//! placements, and a REINFORCE-style learning-based placer standing in
//! for HierarchicalRL/Placeto in the Table-3 comparison (DESIGN.md §2).

pub mod expert;
pub mod rl;
pub mod single;

use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::placer::sched::SchedState;
use crate::placer::Placement;
use crate::profile::Cluster;

/// Replay a fixed assignment through the placement scheduler to obtain a
/// `Placement` with a predicted makespan.
///
/// Baseline assignments are *not* memory-checked at placement time — the
/// paper's single-GPU and expert placements fail at runtime (in the ES),
/// not at placement time. The ledger runs against an uncapped cluster so
/// `commit` cannot reject; OOM is the simulator's verdict (Table 5).
pub(crate) fn place_fixed(
    name: &str,
    graph: &OpGraph,
    cluster: &Cluster,
    assign: impl Fn(NodeId) -> DeviceId,
) -> crate::Result<Placement> {
    let t0 = std::time::Instant::now();
    let mut uncapped = cluster.clone();
    for d in &mut uncapped.devices {
        d.memory = u64::MAX / 4;
    }
    let mut st = SchedState::new(graph, &uncapped);
    let order = graph
        .topo_order()
        .ok_or(crate::BaechiError::Cyclic)?;
    for id in order {
        // TF colocation constraints (§3.1.1) override the assignment:
        // once a group member lands somewhere, the rest follow.
        let dev = st.ledger.pinned_device(graph, id).unwrap_or_else(|| assign(id));
        if dev.0 >= cluster.n() {
            return Err(crate::BaechiError::invalid(format!(
                "device {dev} out of range (cluster has {})",
                cluster.n()
            )));
        }
        st.commit(id, dev);
    }
    crate::placer::finish_placement(name, graph, st, t0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    #[test]
    fn place_fixed_roundrobin() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(2, 10, CommModel::new(0.0, 1.0).unwrap());
        let p = place_fixed("rr", &g, &cluster, |id| DeviceId(id.0 % 2)).unwrap();
        assert_eq!(p.device_of.len(), g.len());
        assert!(p.predicted_makespan > 0.0);
    }
}
