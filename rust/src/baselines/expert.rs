//! Expert placements (paper §5.3).
//!
//! * **GNMT** — Wu et al. [77]: each encoder/decoder LSTM layer on its
//!   own GPU (round-robin when layers > GPUs); embeddings with the first
//!   layer; attention and the output projection with the last decoder
//!   layer.
//! * **Transformer** — common practice [21]: encoder stack on one device,
//!   decoder stack + generator on another.
//! * **Inception-V3 / MLP / linreg** — single GPU (the paper's expert for
//!   Inception-V3 is the single-GPU placement, following
//!   HierarchicalRL).
//!
//! Assignment is by module-name prefix, so it works both on original and
//! on fused graphs (fused meta-nodes keep a member's name).

use super::place_fixed;
use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::models::Benchmark;
use crate::placer::{Placement, Placer};
use crate::profile::Cluster;

/// The per-benchmark expert placer.
#[derive(Debug, Clone, Copy)]
pub struct Expert {
    pub benchmark: Benchmark,
}

impl Expert {
    pub fn new(benchmark: Benchmark) -> Expert {
        Expert { benchmark }
    }

    fn assign(&self, graph: &OpGraph, id: NodeId, n: usize) -> DeviceId {
        let name = &graph.node(id).name;
        match self.benchmark {
            Benchmark::Gnmt { .. } => gnmt_expert(name, n),
            Benchmark::Transformer { .. } => transformer_expert(name, n),
            _ => DeviceId(0),
        }
    }
}

/// Extract the layer index from a module path like `enc/l2/t7/fwd0`.
fn layer_of(name: &str, stage: &str) -> Option<usize> {
    let rest = name.strip_prefix(stage)?.strip_prefix("/l")?;
    let end = rest.find(['/', ':']).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn gnmt_expert(name: &str, n: usize) -> DeviceId {
    // 4 enc + 4 dec layers on 4 GPUs: enc l → GPU l%n, dec l → GPU l%n
    // (the paper's expert splits enc and dec across all GPUs).
    if name.starts_with("enc_embed") {
        return DeviceId(0);
    }
    if name.starts_with("dec_embed") {
        return DeviceId(0);
    }
    if let Some(l) = layer_of(name, "enc") {
        return DeviceId(l % n);
    }
    if let Some(l) = layer_of(name, "dec") {
        return DeviceId(l % n);
    }
    // attention, projection, loss: with the last decoder layer
    DeviceId((n - 1).min(3))
}

fn transformer_expert(name: &str, n: usize) -> DeviceId {
    let dec_dev = DeviceId(1 % n);
    if name.starts_with("enc") {
        DeviceId(0)
    } else if name.starts_with("dec")
        || name.starts_with("generator")
        || name.starts_with("loss")
        || name.starts_with("tgt")
    {
        dec_dev
    } else {
        DeviceId(0)
    }
}

impl Placer for Expert {
    fn name(&self) -> String {
        format!("expert({})", self.benchmark.name())
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        place_fixed(&self.name(), graph, cluster, |id| {
            self.assign(graph, id, cluster.n())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    #[test]
    fn transformer_split_enc_dec() {
        let b = Benchmark::Transformer { batch: 8 };
        let g = b.graph();
        let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
        let p = Expert::new(b).place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 2);
        // encoder ops all on device 0
        for nd in g.iter_nodes() {
            if nd.name.starts_with("enc0/") {
                assert_eq!(p.device(nd.id), DeviceId(0), "{}", nd.name);
            }
            if nd.name.starts_with("dec3/") {
                assert_eq!(p.device(nd.id), DeviceId(1), "{}", nd.name);
            }
        }
    }

    #[test]
    fn gnmt_layers_round_robin() {
        let b = Benchmark::Gnmt {
            batch: 32,
            seq_len: 6,
        };
        let g = b.graph();
        let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
        let p = Expert::new(b).place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 4);
        for nd in g.iter_nodes() {
            if nd.name.starts_with("enc/l2/") {
                assert_eq!(p.device(nd.id), DeviceId(2), "{}", nd.name);
            }
        }
    }

    #[test]
    fn inception_expert_is_single_gpu() {
        let b = Benchmark::Mlp; // same single-GPU path as inception
        let g = b.graph();
        let cluster = Cluster::homogeneous(4, 64 << 30, CommModel::pcie_via_host());
        let p = Expert::new(b).place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 1);
    }

    #[test]
    fn layer_parse() {
        assert_eq!(layer_of("enc/l3/t5/fwd0", "enc"), Some(3));
        assert_eq!(layer_of("dec/l0/t1/bwd2", "dec"), Some(0));
        assert_eq!(layer_of("proj/fwd0", "enc"), None);
    }
}
