//! Single-device baseline: the whole graph on GPU 0 (paper Tables 4–5).

use super::place_fixed;
use crate::graph::{DeviceId, OpGraph};
use crate::placer::{Placement, Placer};
use crate::profile::Cluster;

/// Places every operator on device 0. No communication, no parallelism;
/// OOMs in the simulator whenever the model exceeds one device.
#[derive(Debug, Default, Clone, Copy)]
pub struct SingleDevice;

impl Placer for SingleDevice {
    fn name(&self) -> String {
        "single-gpu".to_string()
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        place_fixed(&self.name(), graph, cluster, |_| DeviceId(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;
    use crate::sim::{simulate, SimConfig};

    #[test]
    fn makespan_equals_total_compute() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(4, 1_000, CommModel::new(0.0, 1.0).unwrap());
        let p = SingleDevice.place(&g, &cluster).unwrap();
        assert_eq!(p.devices_used(), 1);
        assert!((p.predicted_makespan - g.total_compute()).abs() < 1e-9);
    }

    #[test]
    fn sim_agrees_no_transfers() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(4, 1_000, CommModel::new(0.0, 1.0).unwrap());
        let p = SingleDevice.place(&g, &cluster).unwrap();
        let r = simulate(&g, &cluster, &p.device_of, SimConfig::default());
        assert!(r.ok());
        assert_eq!(r.transfers, 0);
        assert!((r.makespan - p.predicted_makespan).abs() < 1e-9);
    }

    #[test]
    fn sim_ooms_when_too_small() {
        let g = crate::models::transformer::transformer(
            crate::models::transformer::TransformerConfig::paper(64),
        );
        // Far too small for the transformer.
        let cluster = Cluster::homogeneous(4, 100 << 20, CommModel::pcie_via_host());
        let p = SingleDevice.place(&g, &cluster).unwrap();
        let r = simulate(&g, &cluster, &p.device_of, SimConfig::default());
        assert!(!r.ok(), "100 MiB device must OOM");
        assert_eq!(r.oom.unwrap().device, 0);
    }
}
