//! REINFORCE-style learning-based placer — the Table-3 comparator.
//!
//! HierarchicalRL [50] and Placeto [2] are unavailable (proprietary /
//! incomplete); per the substitution rule we build a policy-gradient
//! placer with the same cost structure: a categorical policy per
//! operator group samples a placement, the placement is *evaluated by
//! executing a training step* (here: the ES — in the real systems, a
//! run on the physical cluster), and the makespan reward updates the
//! policy. Placement cost therefore scales as
//! `episodes × step-evaluation-time`, which is what makes learning-based
//! placement take hours-to-days on real graphs (paper §5.2).

use crate::graph::{DeviceId, NodeId, OpGraph};
use crate::placer::{Placement, Placer};
use crate::profile::Cluster;
use crate::sim::{simulate, SimConfig};
use crate::util::rng::Pcg;
use std::collections::BTreeMap;

/// Policy-gradient placer configuration.
#[derive(Debug, Clone, Copy)]
pub struct RlConfig {
    pub episodes: usize,
    pub lr: f64,
    pub seed: u64,
    /// Penalty multiplier for OOM placements.
    pub oom_penalty: f64,
}

impl Default for RlConfig {
    fn default() -> RlConfig {
        RlConfig {
            episodes: 200,
            lr: 0.5,
            seed: 7,
            oom_penalty: 10.0,
        }
    }
}

/// The learning-based placer.
#[derive(Debug, Clone, Copy, Default)]
pub struct RlPlacer {
    pub cfg: RlConfig,
}

/// Outcome statistics beyond the placement itself.
#[derive(Debug, Clone)]
pub struct RlStats {
    pub episodes: usize,
    pub best_makespan: f64,
    pub first_makespan: f64,
    /// Total simulated step-evaluation time — the cost a *real*
    /// learning-based placer pays in wall-clock on the target cluster
    /// (Table 3's normalized metric: samples × step time).
    pub simulated_step_time_total: f64,
}

impl RlPlacer {
    pub fn new(cfg: RlConfig) -> RlPlacer {
        RlPlacer { cfg }
    }

    /// Run the policy-gradient search, returning placement + stats.
    pub fn place_with_stats(
        &self,
        graph: &OpGraph,
        cluster: &Cluster,
    ) -> crate::Result<(Placement, RlStats)> {
        let t0 = std::time::Instant::now();
        let n = cluster.n();
        let ids: Vec<NodeId> = graph.node_ids().collect();
        let idx_of: BTreeMap<NodeId, usize> =
            ids.iter().enumerate().map(|(k, &v)| (v, k)).collect();
        let mut rng = Pcg::seed(self.cfg.seed);
        // Logits per op × device.
        let mut logits = vec![vec![0.0f64; n]; ids.len()];
        let mut baseline: Option<f64> = None;
        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut first_makespan = f64::NAN;
        let mut sim_time_total = 0.0;

        for _ep in 0..self.cfg.episodes {
            // Sample a placement from the softmax policy.
            let mut choice = vec![0usize; ids.len()];
            for (k, l) in logits.iter().enumerate() {
                let mx = l.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let ws: Vec<f64> = l.iter().map(|v| (v - mx).exp()).collect();
                choice[k] = rng.weighted(&ws);
            }
            let placement: BTreeMap<NodeId, DeviceId> = ids
                .iter()
                .enumerate()
                .map(|(k, &id)| (id, DeviceId(choice[k])))
                .collect();
            // Evaluate: one simulated training step.
            let r = simulate(graph, cluster, &placement, SimConfig::default());
            let cost = if r.ok() {
                sim_time_total += r.makespan;
                r.makespan
            } else {
                sim_time_total += r.makespan; // partial step before OOM
                // strongly discourage OOM
                (r.makespan + graph.total_compute()) * self.cfg.oom_penalty
            };
            if first_makespan.is_nan() {
                first_makespan = cost;
            }
            if best.as_ref().map(|(b, _)| cost < *b).unwrap_or(r.ok()) && r.ok() {
                best = Some((cost, choice.clone()));
            }
            // REINFORCE with moving-average baseline.
            let b = baseline.unwrap_or(cost);
            let advantage = b - cost; // lower cost ⇒ positive advantage
            baseline = Some(0.9 * b + 0.1 * cost);
            let scale = self.cfg.lr * advantage / (b.abs() + 1e-12);
            for (k, &ch) in choice.iter().enumerate() {
                // ∇ log softmax: +1 on chosen, -p on all (approximated by
                // a simple chosen-logit bump, which suffices for a
                // baseline comparator).
                logits[k][ch] += scale;
            }
        }

        let (best_cost, best_choice) = best.ok_or_else(|| {
            crate::BaechiError::Infeasible(format!(
                "RL placer found no feasible placement in {} episodes",
                self.cfg.episodes
            ))
        })?;
        let device_of: BTreeMap<NodeId, DeviceId> = ids
            .iter()
            .map(|&id| (id, DeviceId(best_choice[idx_of[&id]])))
            .collect();
        let placement = Placement {
            algorithm: "rl-reinforce".to_string(),
            predicted_makespan: best_cost,
            placement_time: t0.elapsed().as_secs_f64(),
            peak_memory: vec![0; n],
            device_of,
        };
        let stats = RlStats {
            episodes: self.cfg.episodes,
            best_makespan: best_cost,
            first_makespan,
            simulated_step_time_total: sim_time_total,
        };
        Ok((placement, stats))
    }
}

impl Placer for RlPlacer {
    fn name(&self) -> String {
        "rl-reinforce".to_string()
    }

    fn place(&self, graph: &OpGraph, cluster: &Cluster) -> crate::Result<Placement> {
        self.place_with_stats(graph, cluster).map(|(p, _)| p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::CommModel;

    #[test]
    fn improves_over_episodes() {
        let g = crate::models::mlp::mlp(&crate::models::mlp::MlpConfig::default());
        let cluster = Cluster::homogeneous(2, 64 << 30, CommModel::pcie_via_host());
        let rl = RlPlacer::new(RlConfig {
            episodes: 120,
            ..Default::default()
        });
        let (p, stats) = rl.place_with_stats(&g, &cluster).unwrap();
        assert_eq!(p.device_of.len(), g.len());
        assert!(stats.best_makespan <= stats.first_makespan * 1.001);
        assert!(stats.simulated_step_time_total > 0.0);
    }

    #[test]
    fn respects_feasibility_eventually() {
        // Cluster where a random placement usually works; RL must return
        // a feasible (non-OOM) placement.
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap());
        let rl = RlPlacer::new(RlConfig {
            episodes: 30,
            ..Default::default()
        });
        let (p, _) = rl.place_with_stats(&g, &cluster).unwrap();
        let r = simulate(&g, &cluster, &p.device_of, SimConfig::default());
        assert!(r.ok());
    }

    #[test]
    fn placement_cost_scales_with_episodes() {
        let g = crate::models::linreg::linreg_graph();
        let cluster = Cluster::homogeneous(2, 1000, CommModel::new(0.0, 1.0).unwrap());
        let short = RlPlacer::new(RlConfig {
            episodes: 10,
            ..Default::default()
        });
        let long = RlPlacer::new(RlConfig {
            episodes: 100,
            ..Default::default()
        });
        let (_, s1) = short.place_with_stats(&g, &cluster).unwrap();
        let (_, s2) = long.place_with_stats(&g, &cluster).unwrap();
        assert!(s2.simulated_step_time_total > s1.simulated_step_time_total * 5.0);
    }
}
